"""Prefill throughput: hoisted-GEMM sequence executor vs the per-step scan.

The PR-4 perf gate.  For each (B, T, d_in, d_h) problem it times

  * ``stepwise``: the pre-hoist executor (``ops.quant_lstm_seq_stepwise``,
    input GEMM inside the scan body -- one small ``(B, d_in)`` matmul per
    timestep), and
  * ``hoisted``:  the two-stage executor (``ops.quant_lstm_seq``, ONE
    time-batched ``(B*T, d_in)`` input GEMM outside the recurrent scan),

on the ``xla`` backend, reports prefill tokens/s for both, verifies the two
are bit-exact on the benchmarked shape, and writes a ``BENCH_prefill.json``
artifact so the perf trajectory is recorded across PRs.

``--check-speedup X`` turns the gate hard: the primary shape (first row,
default B=8 T=64) must reach at least X times the stepwise tokens/s or the
process exits non-zero.  Problem sizes default small enough for 2-core CI
boxes; scale with --d-in/--d-h/--seq for real measurements.

    PYTHONPATH=src python benchmarks/prefill_throughput.py --check-speedup 1.5
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import cell as C
from repro.core import recipe as R
from repro.core.calibrate import Stats, TapCollector
from repro.kernels import ops
from repro.models import gru as GR
from repro.models import lstm as L
from repro.models import quant_lstm as QL


def _quantize(cell, d_in, d_h, b, t, seed=0):
    xs = 0.8 * jax.random.normal(jax.random.PRNGKey(seed + 1), (b, t, d_in))
    col = TapCollector()
    # calibrate on a short prefix: stats only need representative ranges
    if cell == "gru":
        cfg = GR.GRUConfig(d_in, d_h, GR.GRUVariant())
        params = GR.init_gru_params(jax.random.PRNGKey(seed), cfg)
        GR.gru_layer(params, cfg, xs[:, :4], collector=col)
        quantize_layer = R.quantize_gru_layer
    else:
        cfg = L.LSTMConfig(d_in, d_h, 0, L.LSTMVariant())
        params = L.init_lstm_params(jax.random.PRNGKey(seed), cfg)
        L.lstm_layer(params, cfg, xs[:, :4], collector=col)
        quantize_layer = R.quantize_lstm_layer
    stats = Stats()
    stats.merge(jax.device_get(col.snapshot()))
    arrays, spec = quantize_layer(params, cfg, stats)
    return QL.quantize_input(xs, spec.s_x, spec.zp_x), arrays, spec


def _bench_tokens_per_s(fn, arrays, xs_q, iters):
    out = fn(arrays, xs_q)
    jax.block_until_ready(out)  # compile + warm
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(arrays, xs_q)
    jax.block_until_ready(out)
    dt = (time.perf_counter() - t0) / iters
    b, t = xs_q.shape[0], xs_q.shape[1]
    return b * t / dt, dt


def run(shapes, iters, backend="xla", cell="lstm"):
    """Returns one result dict per (B, T, d_in, d_h) shape."""
    results = []
    for (b, t, d_in, d_h) in shapes:
        xs_q, arrays, spec = _quantize(cell, d_in, d_h, b, t)
        state0 = C.get_cell(spec).init_state(spec, b)
        step_fn = jax.jit(lambda a, x: ops.quant_recurrent_seq_stepwise(
            a, spec, x, state0, backend=backend))
        hoist_fn = jax.jit(lambda a, x: ops.quant_recurrent_seq(
            a, spec, x, state0, backend=backend))
        ys_s, st_s = step_fn(arrays, xs_q)
        ys_h, st_h = hoist_fn(arrays, xs_q)
        exact = bool(jnp.array_equal(ys_s, ys_h)) and all(
            bool(jnp.array_equal(a, b_)) for a, b_ in zip(st_s, st_h))
        tps_s, dt_s = _bench_tokens_per_s(step_fn, arrays, xs_q, iters)
        tps_h, dt_h = _bench_tokens_per_s(hoist_fn, arrays, xs_q, iters)
        results.append({
            "B": b, "T": t, "d_in": d_in, "d_h": d_h, "backend": backend,
            "cell": cell,
            "stepwise_tokens_per_s": tps_s, "hoisted_tokens_per_s": tps_h,
            "stepwise_ms": dt_s * 1e3, "hoisted_ms": dt_h * 1e3,
            "speedup": tps_h / tps_s, "bitexact": exact,
        })
    return results


def main() -> int:
    ap = argparse.ArgumentParser()
    # default shape: the acceptance gate's (B=8, T=64) with a wide input
    # (2048 -> 4H packed GEMM dwarfs the carry-dependent recurrent+cell
    # work, which is what the hoist accelerates; at narrow d_in the CPU
    # runtime is transcendental-bound and the two executors converge)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--d-in", type=int, default=2048)
    ap.add_argument("--d-h", type=int, default=128)
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--backend", default="xla",
                    choices=["xla", "pallas", "interpret"])
    ap.add_argument("--cell", default="lstm", choices=["lstm", "gru"],
                    help="recurrent cell under test (noLN/noProj topology "
                         "either way)")
    ap.add_argument("--extra-shapes", action="store_true",
                    help="also sweep a small and a square shape")
    ap.add_argument("--check-speedup", type=float, default=None, metavar="X",
                    help="hard gate: primary-shape hoisted/stepwise tokens/s "
                         "must be >= X (exit 1 otherwise)")
    ap.add_argument("--out", default="BENCH_prefill.json",
                    help="JSON artifact path ('' disables)")
    args = ap.parse_args()

    shapes = [(args.batch, args.seq, args.d_in, args.d_h)]
    if args.extra_shapes:
        shapes += [(4, 32, 128, 64), (8, 64, 256, 256)]
    results = run(shapes, args.iters, backend=args.backend, cell=args.cell)

    print("bench/prefill,cell,B,T,d_in,d_h,stepwise_tok_s,hoisted_tok_s,"
          "speedup,bitexact")
    for r in results:
        print(f"bench/prefill,{r['cell']},{r['B']},{r['T']},{r['d_in']},"
              f"{r['d_h']},"
              f"{r['stepwise_tokens_per_s']:.0f},"
              f"{r['hoisted_tokens_per_s']:.0f},"
              f"{r['speedup']:.2f}x,{r['bitexact']}")

    if args.out:
        with open(args.out, "w") as f:
            json.dump({"benchmark": "prefill_throughput",
                       "backend": args.backend, "cell": args.cell,
                       "iters": args.iters,
                       "results": results}, f, indent=2)
        print(f"bench/prefill_artifact,{args.out}")

    primary = results[0]
    if not all(r["bitexact"] for r in results):
        print("bench/prefill_gate,FAIL,bit-exactness violated")
        return 1
    if args.check_speedup is not None:
        ok = primary["speedup"] >= args.check_speedup
        print(f"bench/prefill_gate,{'OK' if ok else 'FAIL'},"
              f"speedup={primary['speedup']:.2f}x "
              f"(required >= {args.check_speedup:.2f}x at "
              f"B={primary['B']} T={primary['T']})")
        if not ok:
            return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
