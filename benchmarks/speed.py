"""Paper sec 6 deployment speed: float vs hybrid vs integer LSTM execution,
and the zero-point-folding optimization on/off.

On this CPU host the relative ordering (integer < hybrid < float runtime on
memory-bound shapes, folding saves the per-call zp correction) mirrors the
paper's RT-factor claims; absolute numbers are host-specific.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import recipe as R
from repro.core.calibrate import Stats, TapCollector
from repro.models import lstm as L
from repro.models import quant_lstm as QL
from repro.core import integer_ops as iops
from repro.core import fixedpoint as fpx

B, T, D = 8, 32, 512


def _bench(fn, *args, iters=20):
    fn(*args)  # compile
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def _quantize_variant(variant, d_in, d_h, d_p, B, T, seed=0):
    cfg = L.LSTMConfig(d_in, d_h, d_p if variant.use_projection else 0,
                       variant)
    params = L.init_lstm_params(jax.random.PRNGKey(seed), cfg)
    xs = 0.8 * jax.random.normal(jax.random.PRNGKey(seed + 1), (B, T, d_in))
    col = TapCollector()
    L.lstm_layer(params, cfg, xs, collector=col)
    stats = Stats()
    stats.merge(jax.device_get(col.snapshot()))
    arrays, spec = R.quantize_lstm_layer(params, cfg, stats)
    return QL.quantize_input(xs, spec.s_x, spec.zp_x), arrays, spec


def fused_parity_table(B=4, T=8, d_in=16, d_h=24, d_p=12, iters=5):
    """xla-vs-pallas(interpret) fused step latency + bit-exactness, all 16
    topology variants (acceptance gate for the packed [i|f|z|o] executor)."""
    print("speed/fused_table,variant,xla_us,pallas_interpret_us,bitexact")
    all_exact = True
    for variant in L.ALL_VARIANTS:
        xs_q, arrays, spec = _quantize_variant(variant, d_in, d_h, d_p, B, T)
        run_x = jax.jit(lambda a, x: QL.quant_lstm_layer(
            a, spec, x, backend="xla")[0])
        run_p = jax.jit(lambda a, x: QL.quant_lstm_layer(
            a, spec, x, backend="interpret")[0])
        x_us = _bench(run_x, arrays, xs_q, iters=iters) / T
        p_us = _bench(run_p, arrays, xs_q, iters=iters) / T
        exact = bool(jnp.array_equal(run_x(arrays, xs_q),
                                     run_p(arrays, xs_q)))
        all_exact &= exact
        print(f"speed/fused,{x_us:.1f},{variant.name};"
              f"interpret_us={p_us:.1f};bitexact={exact}")
    status = "OK" if all_exact else "MISMATCH"
    print(f"speed/fused_parity,0.0,all_16_variants_bitexact={status}")
    return all_exact


def main():
    variant = L.LSTMVariant()
    cfg = L.LSTMConfig(D, D, 0, variant)
    params = L.init_lstm_params(jax.random.PRNGKey(0), cfg)
    xs = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (B, T, D))

    col = TapCollector()
    L.lstm_layer(params, cfg, xs[:, :4], collector=col)
    stats = Stats()
    stats.merge(jax.device_get(col.snapshot()))
    arrays, spec = R.quantize_lstm_layer(params, cfg, stats)

    # float
    f_us = _bench(jax.jit(lambda p, x: L.lstm_layer(p, cfg, x)[0]), params, xs)
    print(f"speed/lstm_float,{f_us:.1f},B={B};T={T};D={D}")

    # hybrid
    wq, scales = QL.hybrid_weights(params)

    @jax.jit
    def hybrid(x):
        h = jnp.zeros((B, D))
        c = jnp.zeros((B, D))
        def step(carry, x_t):
            h, c = carry
            acc = {g: QL.hybrid_matmul(x_t, wq["W"][g], scales[f"W_{g}"])
                   + QL.hybrid_matmul(h, wq["R"][g], scales[f"R_{g}"])
                   + params["b"][g] for g in ("i", "f", "z", "o")}
            c = jax.nn.sigmoid(acc["i"]) * jnp.tanh(acc["z"]) + \
                jax.nn.sigmoid(acc["f"]) * c
            h = jax.nn.sigmoid(acc["o"]) * jnp.tanh(c)
            return (h, c), h
        (_, _), ys = jax.lax.scan(step, (h, c), jnp.swapaxes(x, 0, 1))
        return ys

    h_us = _bench(hybrid, xs)
    print(f"speed/lstm_hybrid,{h_us:.1f},dynamic-range int8 weights")

    # integer-only (zero point folded -- the paper's deployed form), via the
    # fused executor: one packed [i|f|z|o] matmul pair per step
    xs_q = QL.quantize_input(xs, spec.s_x, spec.zp_x)
    i_us = _bench(jax.jit(
        lambda a, x: QL.quant_lstm_layer(a, spec, x)[0]), arrays, xs_q)
    print(f"speed/lstm_integer_folded,{i_us:.1f},"
          "sec-6 zp folding ON; packed 2-matmul step")

    # same integer math through the reference per-gate executor (8 matmuls)
    r_us = _bench(jax.jit(
        lambda a, x: QL.quant_lstm_layer_ref(a, spec, x)[0]), arrays, xs_q)
    print(f"speed/lstm_integer_unpacked,{r_us:.1f},"
          f"per-gate 8-matmul step; packing_gain={r_us / i_us:.2f}x")

    # integer with runtime zero-point correction (folding OFF)
    @jax.jit
    def unfolded(a, x_q):
        def step(carry, x_t):
            h, c = carry
            gates = {}
            for g in ("i", "f", "z", "o"):
                gs = spec.gate_spec(g)
                sl = spec.gate_block(g)
                W_g, R_g = a["W_cat"][:, sl], a["R_cat"][:, sl]
                # runtime zp correction: colsum(W) * zp computed per call
                acc_x = iops.matmul_i8_i32(x_t, W_g) - (
                    jnp.sum(W_g.astype(jnp.int32), 0) * spec.zp_x)
                acc_h = iops.matmul_i8_i32(h, R_g) - (
                    jnp.sum(R_g.astype(jnp.int32), 0) * spec.zp_h
                ) + a["fold_hb_cat"][sl] * 0
                gate = fpx.saturating_add_i32(
                    fpx.multiply_by_quantized_multiplier(acc_x, *gs.eff_x),
                    fpx.multiply_by_quantized_multiplier(acc_h, *gs.eff_h))
                gates[g] = fpx.saturate_i16(gate)
            f_a = fpx.sigmoid_q15(gates["f"], 3).astype(jnp.int32)
            z_a = fpx.tanh_q15(gates["z"], 3).astype(jnp.int32)
            i_a = fpx.sigmoid_q15(gates["i"], 3).astype(jnp.int32)
            n_c = 15 - spec.cell_int_bits
            c = fpx.saturate_i16(fpx.saturating_add_i32(
                fpx.rounding_divide_by_pot(i_a * z_a, 30 - n_c),
                fpx.rounding_divide_by_pot(f_a * c.astype(jnp.int32), 15)))
            o_a = fpx.sigmoid_q15(gates["o"], 3).astype(jnp.int32)
            m_raw = o_a * fpx.tanh_q15(c, spec.cell_int_bits).astype(jnp.int32)
            h = fpx.saturate_i8(
                fpx.multiply_by_quantized_multiplier(m_raw, *spec.eff_m)
                + jnp.int32(spec.zp_m))
            return (h, c), h
        h0 = jnp.full((B, D), spec.zp_h, jnp.int8)
        c0 = jnp.zeros((B, D), jnp.int16)
        _, ys = jax.lax.scan(step, (h0, c0), jnp.swapaxes(x_q, 0, 1))
        return ys

    u_us = _bench(unfolded, arrays, xs_q)
    print(f"speed/lstm_integer_unfolded,{u_us:.1f},sec-6 zp folding OFF")
    print(f"speed/summary,0.0,int_vs_float={f_us/i_us:.2f}x;"
          f"folding_gain={u_us/i_us:.2f}x;packing_gain={r_us/i_us:.2f}x")
    fused_parity_table()
    return {"float": f_us, "hybrid": h_us, "integer": i_us,
            "unpacked": r_us, "unfolded": u_us}


if __name__ == "__main__":
    main()
