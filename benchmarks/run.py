"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only table1,act_error,...]

Prints ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: table1,table2,act_error,"
                         "speed,roofline")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    def want(name):
        return only is None or name in only

    if want("act_error"):
        from benchmarks import act_error
        act_error.main()
    if want("table2"):
        from benchmarks import table2_recipe
        table2_recipe.main()
    if want("speed"):
        from benchmarks import speed
        speed.main()
    if want("table1"):
        from benchmarks import table1_accuracy
        table1_accuracy.main()
    if want("roofline"):
        from benchmarks import roofline_report
        roofline_report.main()


if __name__ == "__main__":
    main()
