"""Open-loop SLO benchmark for the fault-tolerant fleet tier.

A Poisson arrival process with heavy-tailed prompt/generation lengths is
served through ``launch/fleet.py``'s admission router over N shards, twice:

  * **no-fault leg** -- the capacity baseline;
  * **faulted leg** (``--kill-frac``) -- the SAME workload and seed, with a
    seeded ``FaultInjector`` killing one shard once fleet generation
    progress passes the given fraction (optionally restarting it
    ``--kill-restart`` fleet steps later).  In-flight streams on the dead
    shard migrate with state or replay their prefix onto survivors.

Reported per leg: p50/p99 TTFT (fleet steps -- arrival to first token, so
queueing and recovery delay are inside the number -- plus wall seconds),
tokens/s, and the deterministic goodput **tokens per fleet step** the
retention gate uses (wall-clock goodput is too noisy on shared CI runners).
Every completed stream in BOTH legs is asserted bit-identical to
``decode_single`` of its original request -- shard kills, migrations, and
replays included -- with a hard exit (not an assert) after the artifact is
written, so a drifting run still leaves numbers to debug with.

    PYTHONPATH=src python benchmarks/fleet_load.py --shards 2 --slots 2 \
        --requests 24 --kill-frac 0.5 --check-retention 0.7 \
        --out BENCH_fleet.json

    # multi-device CPU meshes (flag is read BEFORE jax initializes):
    PYTHONPATH=src python benchmarks/fleet_load.py --shards 2 \
        --host-devices 4 ...
"""
from __future__ import annotations

import argparse
import json
import os
import sys

# --host-devices must land in XLA_FLAGS before jax ever initializes, so it
# is scanned from argv ahead of any jax-importing module
if "--host-devices" in sys.argv:
    _n = sys.argv[sys.argv.index("--host-devices") + 1]
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={int(_n)}")

import numpy as np  # noqa: E402

sys.path.insert(0, "src")

from repro.launch import engine as E  # noqa: E402
from repro.launch import fleet as F  # noqa: E402
from repro.runtime import sharding as shlib  # noqa: E402

from engine_throughput import build_quantized_lm  # noqa: E402


def open_loop_trace(cfg, *, n, rate, seed, prompt_med=6, gen_med=8,
                    prompt_cap=24, gen_cap=32):
    """Poisson arrivals (exponential inter-arrival, mean ``1/rate`` fleet
    steps) with lognormal prompt/generation lengths clipped to caps --
    mostly short streams plus an occasional long one, the heavy tail that
    makes a mid-flight shard kill actually strand work."""
    rng = np.random.default_rng(seed)
    t = 0.0
    out = []
    for rid in range(n):
        t += rng.exponential(1.0 / rate)
        plen = int(np.clip(round(rng.lognormal(np.log(prompt_med), 0.6)),
                           1, prompt_cap))
        gen = int(np.clip(round(rng.lognormal(np.log(gen_med), 0.6)),
                          1, gen_cap))
        toks = rng.integers(0, cfg.vocab_size, size=(plen,), dtype=np.int64)
        out.append(E.Request(rid=rid, prompt=toks.astype(np.int32),
                             max_new_tokens=gen, arrival=float(int(t))))
    return out


def pctl(xs, q):
    if not xs:
        return None
    xs = sorted(xs)
    return xs[min(len(xs) - 1, int(len(xs) * q))]


def run_leg(params, qlayers, cfg, requests, args, injector):
    meshes = shlib.fleet_meshes(args.shards)
    router = F.FleetRouter(
        params, qlayers, cfg, n_shards=args.shards,
        slots_per_shard=args.slots, backend=args.backend, chunk=args.chunk,
        policy=args.policy, oversubscribe=args.oversubscribe,
        injector=injector, meshes=meshes)
    router.warmup()
    router.submit_all([
        E.Request(rid=r.rid, prompt=r.prompt,
                  max_new_tokens=r.max_new_tokens, arrival=r.arrival)
        for r in requests])
    results, stats = router.run()
    return results, stats, sum(m is not None for m in meshes)


def leg_summary(results, stats):
    done = [r for r in results.values()
            if not r.rejected and not r.truncated]
    ttft_steps = [r.ttft_steps for r in done if r.ttft_steps is not None]
    ttft_s = [r.ttft_s for r in done if r.ttft_s is not None]
    return {
        "completed": stats.completed,
        "rejected": stats.rejected,
        "lost": stats.lost,
        "fleet_steps": stats.fleet_steps,
        "generated_tokens": stats.generated_tokens,
        "goodput_tokens_per_step": round(stats.goodput_tokens_per_step, 4),
        "tokens_per_s": round(stats.tokens_per_s, 1),
        "ttft_p50_steps": pctl(ttft_steps, 0.50),
        "ttft_p99_steps": pctl(ttft_steps, 0.99),
        "ttft_p50_s": round(pctl(ttft_s, 0.50), 4) if ttft_s else None,
        "ttft_p99_s": round(pctl(ttft_s, 0.99), 4) if ttft_s else None,
        "kills": stats.kills,
        "restarts": stats.restarts,
        "migrated_streams": stats.migrated_streams,
        "replayed_streams": stats.replayed_streams,
        "rerouted_pending": stats.rerouted_pending,
        "admit_retries": stats.admit_retries,
        "shard_occupancy": [round(s.occupancy(stats.n_slots), 3)
                            for s in stats.shards],
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--shards", type=int, default=2)
    ap.add_argument("--slots", type=int, default=2,
                    help="decode-batch rows per shard")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--rate", type=float, default=0.5,
                    help="mean arrivals per fleet step (Poisson)")
    ap.add_argument("--policy", default="srf")
    ap.add_argument("--oversubscribe", type=float, default=2.0)
    ap.add_argument("--chunk", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", default="xla",
                    choices=["xla", "pallas", "interpret"])
    ap.add_argument("--host-devices", type=int, default=None,
                    help="force N host CPU devices (XLA_FLAGS; must be set "
                         "before jax starts, which this flag guarantees) so "
                         "each shard gets a real disjoint mesh")
    ap.add_argument("--kill-frac", type=float, default=None,
                    help="run a second, faulted leg: kill one shard once "
                         "this fraction of all requested tokens has been "
                         "generated (0.5 = mid-flight)")
    ap.add_argument("--kill-shard", type=int, default=0)
    ap.add_argument("--kill-restart", type=int, default=24,
                    help="restart the killed shard after this many fleet "
                         "steps (-1 = never; it stays dead)")
    ap.add_argument("--graceful", action="store_true",
                    help="graceful drain instead of a hard kill (every "
                         "stream migrates with state; none replay)")
    ap.add_argument("--out", default=None,
                    help="write the JSON artifact here (BENCH_fleet.json)")
    ap.add_argument("--check-retention", type=float, default=None,
                    help="exit nonzero unless faulted goodput (tokens per "
                         "fleet step) / no-fault goodput >= this")
    args = ap.parse_args()
    if args.kill_frac is not None and not 0.0 <= args.kill_frac <= 1.0:
        ap.error("--kill-frac must be in [0, 1]")
    if args.kill_frac is not None and \
            not 0 <= args.kill_shard < args.shards:
        ap.error("--kill-shard out of range")

    params, qlayers, cfg = build_quantized_lm(args.backend)
    requests = open_loop_trace(cfg, n=args.requests, rate=args.rate,
                               seed=args.seed)
    offered = sum(r.max_new_tokens for r in requests)

    base_results, base_stats, meshed = run_leg(
        params, qlayers, cfg, requests, args, injector=None)
    base = leg_summary(base_results, base_stats)

    faulted = None
    fault_results = {}
    if args.kill_frac is not None:
        inj = F.FaultInjector(seed=args.seed, kills=[F.KillSpec(
            shard=args.kill_shard, at_frac=args.kill_frac,
            graceful=args.graceful,
            restart_after=(None if args.kill_restart < 0
                           else args.kill_restart))])
        fault_results, fault_stats, _ = run_leg(
            params, qlayers, cfg, requests, args, injector=inj)
        faulted = leg_summary(fault_results, fault_stats)

    # bit-exactness: every COMPLETED stream in both legs must match
    # decode_single of its original request -- migrations and replays
    # included (verdict computed now, enforced after the artifact lands)
    drifted = []
    ref = {}
    for r in requests:
        ref[r.rid] = E.decode_single(params, qlayers, cfg, r.prompt,
                                     r.max_new_tokens,
                                     backend=args.backend)
        for leg, res in (("nofault", base_results),
                         ("faulted", fault_results)):
            fr = res.get(r.rid)
            if fr is not None and not fr.rejected and not fr.truncated \
                    and fr.tokens != ref[r.rid]:
                drifted.append((leg, r.rid))

    retention = None
    if faulted is not None and base["goodput_tokens_per_step"]:
        retention = (faulted["goodput_tokens_per_step"]
                     / base["goodput_tokens_per_step"])

    print(f"fleet_load,arch={cfg.name},backend={args.backend},"
          f"shards={args.shards},slots={args.slots},"
          f"requests={len(requests)},offered_tokens={offered},"
          f"rate={args.rate},policy={args.policy},"
          f"oversubscribe={args.oversubscribe},meshes={meshed}")
    for name, leg in (("nofault", base), ("faulted", faulted)):
        if leg is None:
            continue
        print(f"fleet_load/{name},completed={leg['completed']},"
              f"rejected={leg['rejected']},lost={leg['lost']},"
              f"goodput={leg['goodput_tokens_per_step']},"
              f"tok_s={leg['tokens_per_s']},"
              f"ttft_p50={leg['ttft_p50_steps']},"
              f"ttft_p99={leg['ttft_p99_steps']},"
              f"kills={leg['kills']},restarts={leg['restarts']},"
              f"migrated={leg['migrated_streams']},"
              f"replayed={leg['replayed_streams']}")
    if retention is not None:
        print(f"fleet_load/retention,{retention:.3f}")

    if args.out:
        artifact = {
            "bench": "fleet_load",
            "arch": cfg.name,
            "backend": args.backend,
            "shards": args.shards,
            "slots_per_shard": args.slots,
            "requests": len(requests),
            "offered_tokens": offered,
            "rate": args.rate,
            "policy": args.policy,
            "oversubscribe": args.oversubscribe,
            "meshed_shards": meshed,
            "kill": (None if args.kill_frac is None else {
                "shard": args.kill_shard, "at_frac": args.kill_frac,
                "graceful": args.graceful,
                "restart_after": (None if args.kill_restart < 0
                                  else args.kill_restart)}),
            "nofault": base,
            "faulted": faulted,
            "goodput_retention": (round(retention, 3)
                                  if retention is not None else None),
            "bitexact": not drifted,
        }
        with open(args.out, "w") as f:
            json.dump(artifact, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.out}")

    # hard exits, not asserts, so `python -O` can't skip the gates
    if drifted:
        leg, rid = drifted[0]
        raise SystemExit(f"FAIL: {leg} leg drifted from decode_single on "
                         f"stream {rid} ({len(drifted)} drifting streams)")
    if args.kill_frac is not None and faulted["kills"] < 1:
        raise SystemExit("FAIL: faulted leg never killed a shard (workload "
                         "finished before --kill-frac progress; raise "
                         "--requests or lower --kill-frac)")
    if args.check_retention is not None:
        if retention is None:
            raise SystemExit("FAIL: --check-retention needs --kill-frac "
                             "(no faulted leg was run)")
        if retention < args.check_retention:
            print(f"FAIL: goodput retention {retention:.3f} < required "
                  f"{args.check_retention:.3f}")
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
