"""Continuous-batching engine throughput vs naive sequential serving.

Serves the SAME mixed prompt-length / generation-budget workload two ways:

  * **sequential** -- one stream at a time through the batch-1 jitted
    prefill + decode loop (``launch.engine.decode_single``), the way
    ``serve.py`` served before the engine existed;
  * **engine**     -- all requests queued into the slot-based
    continuous-batching engine (one fused decode step drives every active
    slot per iteration), optionally with chunked prefill (``--chunk K``:
    up to K prompt tokens per slot per step as one masked (S, K) dispatch).

Both paths are warmed up first so compile time is excluded; the engine's
integer outputs are bit-identical to sequential decode (asserted here on
EVERY stream), so the speedup is pure scheduling.  Engine wall/throughput
numbers come from the engine's own ``EngineStats`` (the loop it actually
timed), not an external stopwatch.

    PYTHONPATH=src python benchmarks/engine_throughput.py --slots 8
    # chunked prefill on a prompt-heavy trace (where chunking pays):
    PYTHONPATH=src python benchmarks/engine_throughput.py \
        --slots 8 --chunk 4 --prompt-heavy

Acceptance gates: >= 2x generated-tokens/sec at 8 slots (ISSUE 2,
``--check-speedup``); with ``--chunk K > 1`` the mean TTFT vs a chunk-1
engine on the same trace is also reported (ISSUE 3: >= 2x lower on a
prompt-heavy trace, ``--check-ttft-speedup``).
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

sys.path.insert(0, "src")

from repro.configs.registry import get_config  # noqa: E402
from repro.launch import engine as E  # noqa: E402
from repro.models import lstm_lm, model_zoo  # noqa: E402


def build_quantized_lm(backend: str, cell: str = "lstm"):
    cfg = get_config(f"{cell}-rnnt", smoke=True)
    bundle = model_zoo.build(cfg)
    params, _ = bundle.init(jax.random.PRNGKey(0))
    calib = jax.random.randint(jax.random.PRNGKey(2), (4, 16), 0,
                               cfg.vocab_size)
    qlayers = lstm_lm.quantize_stack(params, cfg, calib)
    return params, qlayers, cfg


def run_sequential(params, qlayers, cfg, requests, backend):
    t0 = time.perf_counter()
    out = {}
    for r in requests:
        out[r.rid] = E.decode_single(params, qlayers, cfg, r.prompt,
                                     r.max_new_tokens, backend=backend)
    wall = time.perf_counter() - t0
    tokens = sum(len(v) for v in out.values())
    return out, tokens / wall, wall


def run_engine(params, qlayers, cfg, requests, slots, backend, chunk,
               policy="fifo", oversubscribe=1.0):
    eng = E.ContinuousBatchingEngine(params, qlayers, cfg, n_slots=slots,
                                     backend=backend, chunk=chunk,
                                     policy=policy,
                                     oversubscribe=oversubscribe)
    eng.submit_all(list(requests))
    results, stats = eng.run()
    return results, stats


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--chunk", type=int, default=1,
                    help="engine prefill chunk size K (bit-exact vs 1)")
    ap.add_argument("--prompt-heavy", action="store_true",
                    help="prompt lens >= 16 with short generations: the "
                         "regime where chunked prefill pays (TTFT is "
                         "prefill-dominated)")
    ap.add_argument("--backend", default="xla",
                    choices=["xla", "pallas", "interpret"])
    ap.add_argument("--cell", default="lstm", choices=["lstm", "gru"],
                    help="recurrent cell of the served stack (lstm-rnnt / "
                         "gru-rnnt smoke config)")
    ap.add_argument("--policy", default="fifo",
                    help="engine scheduling policy (launch/scheduler.py); "
                         "every policy stays bit-exact, so the gates apply "
                         "unchanged")
    ap.add_argument("--oversubscribe", type=float, default=1.0,
                    help="engine admission headroom (live streams <= "
                         "ceil(ratio * slots))")
    ap.add_argument("--check-speedup", type=float, default=None,
                    help="exit nonzero unless engine/sequential >= this")
    ap.add_argument("--check-ttft-speedup", type=float, default=None,
                    help="exit nonzero unless chunk-1 TTFT / chunk-K TTFT "
                         ">= this (needs --chunk > 1)")
    args = ap.parse_args()

    # decode-dominant mixed workload by default (LM serving: short contexts,
    # long generations) -- generation steps are one dispatch each either
    # way, and that is where slot-batching pays.  --prompt-heavy flips the
    # ratio (long prompts, short generations): TTFT is then dominated by
    # teacher-forced prefill dispatches, which is where --chunk pays.
    params, qlayers, cfg = build_quantized_lm(args.backend, args.cell)
    if args.prompt_heavy:
        prompt_lens, gen_lens = (16, 20, 24, 32), (4, 8)
    else:
        prompt_lens, gen_lens = (2, 4, 6, 8), (8, 16, 24)
    requests = E.synthetic_trace(
        args.requests, cfg.vocab_size, seed=args.seed,
        prompt_lens=prompt_lens, gen_lens=gen_lens)

    # warmup: compile batch-1 prefill (per distinct prompt length), batch-1
    # decode, and the slot-batch engine step / chunked step + reset
    warm = [E.Request(rid=-1 - i, prompt=r.prompt, max_new_tokens=1)
            for i, r in enumerate(requests)]
    for r in {r.prompt.size: r for r in warm}.values():
        E.decode_single(params, qlayers, cfg, r.prompt, 2,
                        backend=args.backend)
    for k in sorted({1, args.chunk}):
        weng = E.ContinuousBatchingEngine(params, qlayers, cfg,
                                          n_slots=args.slots,
                                          backend=args.backend, chunk=k)
        weng.submit_all(warm[:args.slots])
        weng.run()

    seq_out, seq_tps, seq_wall = run_sequential(
        params, qlayers, cfg, requests, args.backend)
    eng_out, stats = run_engine(
        params, qlayers, cfg, requests, args.slots, args.backend, args.chunk,
        args.policy, args.oversubscribe)

    # scheduling (and chunking) must not change a single token, on ANY
    # stream -- a hard exit, not an assert, so `python -O` can't skip it
    for r in requests:
        if eng_out[r.rid].tokens != seq_out[r.rid]:
            raise SystemExit(
                f"FAIL: engine drifted from sequential on stream {r.rid}")

    speedup = stats.tokens_per_s / seq_tps if seq_tps else float("inf")
    gen_tokens = sum(len(v) for v in seq_out.values())
    print(f"engine_throughput,arch={cfg.name},cell={args.cell},"
          f"backend={args.backend},"
          f"requests={args.requests},slots={args.slots},chunk={args.chunk},"
          f"policy={stats.policy},oversubscribe={stats.oversubscribe},"
          f"prompt_heavy={int(args.prompt_heavy)}")
    print(f"engine_throughput/sequential_tok_s,{seq_tps:.1f},"
          f"wall_s={seq_wall:.2f},gen_tokens={gen_tokens}")
    print(f"engine_throughput/engine_tok_s,{stats.tokens_per_s:.1f},"
          f"wall_s={stats.wall_s:.2f},steps={stats.steps},"
          f"occupancy={stats.occupancy:.2f},max_active={stats.max_active}")
    print(f"engine_throughput/engine_ttft,mean_steps={stats.mean_ttft_steps:.2f},"
          f"mean_ms={stats.mean_ttft_s * 1e3:.1f},"
          f"mean_stream_tok_s={stats.mean_stream_tokens_per_s:.1f}")
    print(f"engine_throughput/speedup,{speedup:.2f},slots={args.slots}")

    ttft_speedup = None
    if args.chunk > 1:
        # same trace through a chunk-1 engine: the TTFT win is pure chunking
        _, base = run_engine(params, qlayers, cfg, requests, args.slots,
                             args.backend, 1)
        ttft_speedup = (base.mean_ttft_s / stats.mean_ttft_s
                        if stats.mean_ttft_s else float("inf"))
        print(f"engine_throughput/ttft_speedup,{ttft_speedup:.2f},"
              f"chunk1_mean_ms={base.mean_ttft_s * 1e3:.1f},"
              f"chunk{args.chunk}_mean_ms={stats.mean_ttft_s * 1e3:.1f},"
              f"chunk1_mean_steps={base.mean_ttft_steps:.2f},"
              f"chunk{args.chunk}_mean_steps={stats.mean_ttft_steps:.2f}")

    fail = False
    if args.check_speedup is not None and speedup < args.check_speedup:
        print(f"FAIL: speedup {speedup:.2f} < required "
              f"{args.check_speedup:.2f}")
        fail = True
    if args.check_ttft_speedup is not None:
        if ttft_speedup is None:
            print("FAIL: --check-ttft-speedup needs --chunk > 1")
            fail = True
        elif ttft_speedup < args.check_ttft_speedup:
            print(f"FAIL: TTFT speedup {ttft_speedup:.2f} < required "
                  f"{args.check_ttft_speedup:.2f}")
            fail = True
    return 1 if fail else 0


if __name__ == "__main__":
    sys.exit(main())
