"""Continuous-batching engine throughput vs naive sequential serving.

Serves the SAME mixed prompt-length / generation-budget workload two ways:

  * **sequential** -- one stream at a time through the batch-1 jitted
    prefill + decode loop (``launch.engine.decode_single``), the way
    ``serve.py`` served before the engine existed;
  * **engine**     -- all requests queued into the slot-based
    continuous-batching engine (one fused decode step drives every active
    slot per iteration).

Both paths are warmed up first so compile time is excluded; the engine's
integer outputs are bit-identical to sequential decode (asserted here too,
on the first/last streams), so the speedup is pure scheduling.

    PYTHONPATH=src python benchmarks/engine_throughput.py --slots 8

Acceptance gate (ISSUE 2): >= 2x generated-tokens/sec at 8 slots.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

sys.path.insert(0, "src")

from repro.configs.registry import get_config  # noqa: E402
from repro.launch import engine as E  # noqa: E402
from repro.models import lstm_lm, model_zoo  # noqa: E402


def build_quantized_lm(backend: str):
    cfg = get_config("lstm-rnnt", smoke=True)
    bundle = model_zoo.build(cfg)
    params, _ = bundle.init(jax.random.PRNGKey(0))
    calib = jax.random.randint(jax.random.PRNGKey(2), (4, 16), 0,
                               cfg.vocab_size)
    qlayers = lstm_lm.quantize_stack(params, cfg, calib)
    return params, qlayers, cfg


def run_sequential(params, qlayers, cfg, requests, backend):
    t0 = time.perf_counter()
    out = {}
    for r in requests:
        out[r.rid] = E.decode_single(params, qlayers, cfg, r.prompt,
                                     r.max_new_tokens, backend=backend)
    wall = time.perf_counter() - t0
    tokens = sum(len(v) for v in out.values())
    return out, tokens / wall, wall


def run_engine(params, qlayers, cfg, requests, slots, backend):
    eng = E.ContinuousBatchingEngine(params, qlayers, cfg, n_slots=slots,
                                     backend=backend)
    eng.submit_all(list(requests))
    t0 = time.perf_counter()
    results, stats = eng.run()
    wall = time.perf_counter() - t0
    return results, stats.generated_tokens / wall, wall, stats


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", default="xla",
                    choices=["xla", "pallas", "interpret"])
    ap.add_argument("--check-speedup", type=float, default=None,
                    help="exit nonzero unless engine/sequential >= this")
    args = ap.parse_args()

    # decode-dominant mixed workload (LM serving: short contexts, long
    # generations).  Sequential serving prefills a whole prompt in ONE
    # scanned dispatch while the engine teacher-forces one token per step,
    # so prompt-heavy traces understate the engine win; generation steps are
    # one dispatch each either way, and that is where batching pays.
    params, qlayers, cfg = build_quantized_lm(args.backend)
    requests = E.synthetic_trace(
        args.requests, cfg.vocab_size, seed=args.seed,
        prompt_lens=(2, 4, 6, 8), gen_lens=(8, 16, 24))

    # warmup: compile batch-1 prefill (per distinct prompt length), batch-1
    # decode, and the slot-batch engine step + reset
    warm = [E.Request(rid=-1 - i, prompt=r.prompt, max_new_tokens=1)
            for i, r in enumerate(requests)]
    for r in {r.prompt.size: r for r in warm}.values():
        E.decode_single(params, qlayers, cfg, r.prompt, 2,
                        backend=args.backend)
    weng = E.ContinuousBatchingEngine(params, qlayers, cfg,
                                      n_slots=args.slots,
                                      backend=args.backend)
    weng.submit_all(warm[:args.slots])
    weng.run()

    seq_out, seq_tps, seq_wall = run_sequential(
        params, qlayers, cfg, requests, args.backend)
    eng_out, eng_tps, eng_wall, stats = run_engine(
        params, qlayers, cfg, requests, args.slots, args.backend)

    # scheduling must not change a single token
    for r in (requests[0], requests[-1]):
        assert eng_out[r.rid].tokens == seq_out[r.rid], \
            f"engine drifted from sequential on stream {r.rid}"

    speedup = eng_tps / seq_tps if seq_tps else float("inf")
    gen_tokens = sum(len(v) for v in seq_out.values())
    print(f"engine_throughput,arch={cfg.name},backend={args.backend},"
          f"requests={args.requests},slots={args.slots}")
    print(f"engine_throughput/sequential_tok_s,{seq_tps:.1f},"
          f"wall_s={seq_wall:.2f},gen_tokens={gen_tokens}")
    print(f"engine_throughput/engine_tok_s,{eng_tps:.1f},"
          f"wall_s={eng_wall:.2f},steps={stats.steps},"
          f"occupancy={stats.occupancy:.2f},max_active={stats.max_active}")
    print(f"engine_throughput/speedup,{speedup:.2f},slots={args.slots}")
    if args.check_speedup is not None and speedup < args.check_speedup:
        print(f"FAIL: speedup {speedup:.2f} < required "
              f"{args.check_speedup:.2f}")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
