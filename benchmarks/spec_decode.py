"""Speculative decoding: accepted tokens/step + throughput vs greedy.

Serves the SAME repetitive-text workload through the continuous-batching
engine twice:

  * **greedy**      -- ``speculate=0``: one token per slot per step (the
    pre-speculation engine, and the bit-exactness oracle);
  * **speculative** -- ``speculate=k``: each generating slot's n-gram
    drafter proposes up to k continuation tokens and ONE masked ``(S, k+1)``
    verify dispatch emits every greedy-confirmed token (1..k+1 per slot per
    step, with per-row state rollback to the accepted length).

The workload tiles a short random motif into each prompt (repetitive text:
the regime speculation targets -- served text is self-repetitive, and
greedy integer LSTM decode falls into cycles), so the suffix-cache drafter
has real signal.  Both runs are verified **bit-identical per stream** to
``decode_single`` (and to each other): a hard exit, not an assert, so
``python -O`` can't skip it -- taken only after the metrics and the JSON
artifact are out, so a failing CI leg still uploads its numbers.

Reported: engine steps, generated tokens/s for both runs, draft accept
rate, and **accepted tokens per verify step** (the multi-token decode win;
1.0 = speculation never beat greedy, k+1 = every draft accepted).  The
acceptance gate (``--check-accept X``) requires accepted tokens/verify-step
>= X -- step-count based, so it is deterministic for a given seed/model and
safe to enforce on noisy 2-core CI runners.  Wall-clock tokens/s is
reported but NOT gated, and on CPU it is expected to be LOWER under
speculation (flagged in the output): the (S, k+1) verify block plus its
rollback pass cost real compute per step, while the win is fewer
sequential steps/dispatches -- the quantity that matters on the
dispatch-bound accelerator serving path this engine targets.  A JSON
artifact records the trajectory across PRs.

    PYTHONPATH=src python benchmarks/spec_decode.py --check-accept 1.3
    # CI baseline leg (greedy only, still bit-exactness-checked):
    PYTHONPATH=src python benchmarks/spec_decode.py --speculate 0
"""
from __future__ import annotations

import argparse
import json
import sys

import numpy as np

sys.path.insert(0, "src")

from repro.launch import engine as E  # noqa: E402

# the model/calibration recipe is shared with the engine benchmark so the
# two baselines can never drift apart (both scripts live in benchmarks/,
# which `python benchmarks/spec_decode.py` puts on sys.path)
from engine_throughput import build_quantized_lm  # noqa: E402


def repetitive_trace(n_requests, vocab_size, *, seed, motif_len, prompt_len,
                     gen):
    """Prompts that tile a short per-request random motif: repetitive text,
    where a suffix-cache drafter (and real serving) should accept well."""
    rng = np.random.default_rng(seed)
    out = []
    for rid in range(n_requests):
        motif = rng.integers(0, vocab_size, size=(motif_len,), dtype=np.int64)
        reps = -(-prompt_len // motif_len)  # ceil
        prompt = np.tile(motif, reps)[:prompt_len].astype(np.int32)
        out.append(E.Request(rid=rid, prompt=prompt, max_new_tokens=gen))
    return out


def run_engine(params, qlayers, cfg, requests, *, slots, backend, speculate):
    eng = E.ContinuousBatchingEngine(
        params, qlayers, cfg, n_slots=slots, backend=backend,
        speculate=speculate)
    eng.submit_all([E.Request(rid=r.rid, prompt=r.prompt,
                              max_new_tokens=r.max_new_tokens)
                    for r in requests])
    return eng.run()


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--motif-len", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--gen", type=int, default=32)
    # default trace seed picked for draft-friendliness headroom over the
    # 1.3 gate (seeds 0..3 span 1.32-1.46 accepted tokens/slot-step; the
    # gate is deterministic either way, this just keeps the committed
    # baseline comfortably inside it)
    ap.add_argument("--seed", type=int, default=3)
    ap.add_argument("--speculate", type=int, default=4,
                    help="draft budget k (0: greedy baseline only -- "
                         "bit-exactness vs decode_single still enforced)")
    ap.add_argument("--backend", default="xla",
                    choices=["xla", "pallas", "interpret"])
    ap.add_argument("--check-accept", type=float, default=None, metavar="X",
                    help="hard gate: accepted tokens per verify step must "
                         "be >= X (exit 1 otherwise; needs --speculate > 0)")
    ap.add_argument("--out", default="BENCH_spec.json",
                    help="JSON artifact path ('' disables)")
    args = ap.parse_args()
    if args.check_accept is not None and args.speculate < 1:
        print("FAIL: --check-accept needs --speculate > 0")
        return 1

    params, qlayers, cfg = build_quantized_lm(args.backend)
    requests = repetitive_trace(
        args.requests, cfg.vocab_size, seed=args.seed,
        motif_len=args.motif_len, prompt_len=args.prompt_len, gen=args.gen)

    # the per-stream greedy oracle (also compiles the batch-1 programs)
    ref = {r.rid: E.decode_single(params, qlayers, cfg, r.prompt,
                                  r.max_new_tokens, backend=args.backend)
           for r in requests}

    # warm both engine configurations so compile time stays out of the walls
    for k in sorted({0, args.speculate}):
        run_engine(params, qlayers, cfg, requests[:args.slots],
                   slots=args.slots, backend=args.backend, speculate=k)

    greedy_out, greedy = run_engine(
        params, qlayers, cfg, requests, slots=args.slots,
        backend=args.backend, speculate=0)
    spec_out, spec = (greedy_out, greedy) if args.speculate == 0 else \
        run_engine(params, qlayers, cfg, requests, slots=args.slots,
                   backend=args.backend, speculate=args.speculate)

    # speculation must not change a single token on ANY stream.  The
    # verdict is a hard exit (python -O safe) -- but only AFTER the metrics
    # print and the JSON artifact are written, so a failing CI leg still
    # uploads the numbers to debug with (bitexact: false in the artifact).
    drift = None
    for r in requests:
        if greedy_out[r.rid].tokens != ref[r.rid]:
            drift = (f"FAIL: greedy engine drifted from decode_single on "
                     f"stream {r.rid}")
            break
        if spec_out[r.rid].tokens != ref[r.rid]:
            drift = (f"FAIL: speculative engine drifted from greedy on "
                     f"stream {r.rid}")
            break

    gen_tokens = sum(len(v) for v in ref.values())
    accept_per_step = spec.accepted_tokens_per_spec_step
    print(f"bench/spec_decode,arch={cfg.name},backend={args.backend},"
          f"slots={args.slots},requests={args.requests},"
          f"speculate={args.speculate},gen_tokens={gen_tokens}")
    print(f"bench/spec_decode/greedy,steps={greedy.steps},"
          f"tok_s={greedy.tokens_per_s:.1f},wall_s={greedy.wall_s:.2f}")
    print(f"bench/spec_decode/spec,steps={spec.steps},"
          f"tok_s={spec.tokens_per_s:.1f},wall_s={spec.wall_s:.2f},"
          f"spec_steps={spec.spec_steps}")
    print(f"bench/spec_decode/accept,rate={spec.accept_rate:.3f},"
          f"accepted_tok_per_spec_step={accept_per_step:.3f},"
          f"spec_slot_steps={spec.spec_slot_steps},"
          f"drafted={spec.drafted_tokens},"
          f"accepted={spec.accepted_draft_tokens}")
    print(f"bench/spec_decode/step_reduction,"
          f"{greedy.steps / spec.steps if spec.steps else 0.0:.2f}x")
    if 0 < spec.tokens_per_s < greedy.tokens_per_s:
        # honest flag, not a failure: per-step compute grows with the wide
        # block, so CPU wall-clock regresses even as steps/dispatches drop
        print(f"bench/spec_decode/note,wall-clock tokens/s below greedy "
              f"({spec.tokens_per_s:.0f} < {greedy.tokens_per_s:.0f}): "
              f"expected on CPU -- the win is the "
              f"{greedy.steps / spec.steps if spec.steps else 0.0:.2f}x "
              f"step/dispatch reduction, which pays on dispatch-bound "
              f"serving hardware")

    if args.out:
        with open(args.out, "w") as f:
            json.dump({
                "benchmark": "spec_decode", "backend": args.backend,
                "slots": args.slots, "requests": args.requests,
                "speculate": args.speculate, "gen": args.gen,
                "motif_len": args.motif_len, "prompt_len": args.prompt_len,
                "results": {
                    "bitexact": drift is None,
                    "gen_tokens": gen_tokens,
                    "greedy_steps": greedy.steps,
                    "greedy_tokens_per_s": greedy.tokens_per_s,
                    "spec_steps": spec.steps,
                    "spec_tokens_per_s": spec.tokens_per_s,
                    "verify_steps": spec.spec_steps,
                    "spec_slot_steps": spec.spec_slot_steps,
                    "accept_rate": spec.accept_rate,
                    "accepted_tokens_per_spec_step": accept_per_step,
                    "drafted_tokens": spec.drafted_tokens,
                    "accepted_draft_tokens": spec.accepted_draft_tokens,
                },
            }, f, indent=2)
        print(f"bench/spec_artifact,{args.out}")

    if drift is not None:
        raise SystemExit(drift)
    if args.check_accept is not None:
        ok = accept_per_step >= args.check_accept
        print(f"bench/spec_gate,{'OK' if ok else 'FAIL'},"
              f"accepted_tok_per_spec_step={accept_per_step:.3f} "
              f"(required >= {args.check_accept:.2f})")
        if not ok:
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
