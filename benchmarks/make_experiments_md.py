"""Generate the EXPERIMENTS.md dry-run + roofline sections from the JSONs."""
from __future__ import annotations

import glob
import json
import os

OUT_DIR = "experiments/dryrun"
ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCHS = ["qwen3-4b", "stablelm-1.6b", "yi-34b", "qwen1.5-0.5b", "whisper-tiny",
         "recurrentgemma-9b", "internvl2-2b", "grok-1-314b", "kimi-k2-1t-a32b",
         "falcon-mamba-7b", "lstm-rnnt"]


def fmt_s(x):
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.0f}µs"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def load(arch, shape, mesh, quant="none"):
    tag = f"{arch}__{shape}__{mesh}" + ("" if quant == "none" else f"__{quant}")
    path = os.path.join(OUT_DIR, tag + ".json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def dryrun_section():
    lines = ["### Multi-pod dry-run (2x16x16 = 512 chips, scan-mode compile)",
             "",
             "| arch | shape | compile | peak HBM/dev | collectives | status |",
             "|---|---|---|---|---|---|"]
    for arch in ARCHS:
        for shape in ORDER:
            d = load(arch, shape, "multi")
            if d is None:
                continue
            if "error" in d:
                lines.append(f"| {arch} | {shape} | - | - | - | "
                             f"ERROR: {d['error'][:80]} |")
                continue
            pd = d["per_device"]
            ck = d["collectives"]["by_kind_count"]
            abbr = {"all-reduce": "ar", "all-gather": "ag",
                    "reduce-scatter": "rs", "all-to-all": "a2a",
                    "collective-permute": "cp"}
            cks = ",".join(f"{abbr.get(k, k)}:{v}" for k, v in ck.items())
            lines.append(
                f"| {arch} | {shape} | {d['compile_s']}s | "
                f"{pd['peak_hbm_gb']} GB | {cks or '-'} | ok |")
    return "\n".join(lines)


def roofline_section():
    lines = ["### Roofline baselines (single pod, 16x16 = 256 chips)",
             "",
             "| arch | shape | compute | memory | collective | dominant | "
             "useful | peak GB | method |",
             "|---|---|---|---|---|---|---|---|---|"]
    for arch in ARCHS:
        for shape in ORDER:
            d = load(arch, shape, "single")
            if d is None:
                continue
            if "error" in d:
                lines.append(f"| {arch} | {shape} | - | - | - | ERROR | - | - "
                             f"| {d['error'][:60]} |")
                continue
            r = d["roofline"]
            lines.append(
                f"| {arch} | {shape} | {fmt_s(r['compute_s'])} | "
                f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
                f"{r['dominant'].replace('_s','')} | "
                f"{d.get('useful_ratio', 0):.2f} | "
                f"{d['per_device']['peak_hbm_gb']} | "
                f"{str(d.get('method','?')).split('+')[0]} |")
    return "\n".join(lines)


def skipped_section():
    return (
        "Skipped cells: `long_500k` for the 8 full-attention archs "
        "(qwen3-4b, stablelm-1.6b, yi-34b, qwen1.5-0.5b, whisper-tiny, "
        "internvl2-2b, grok-1-314b, kimi-k2-1t-a32b) -- O(S^2) attention at "
        "524k context is not a meaningful cell for them (per the assignment "
        "note); the two sub-quadratic archs (recurrentgemma-9b, "
        "falcon-mamba-7b) run it.")


def main():
    print(dryrun_section())
    print()
    print(skipped_section())
    print()
    print(roofline_section())


if __name__ == "__main__":
    main()
