"""Paper Table 2: the full quantization recipe for all 8 LSTM variants.

Builds each (LN x Proj x PH) variant, calibrates on random data, applies the
recipe, and prints every derived scale/format -- the machine-checkable form
of the paper's appendix table.
"""
from __future__ import annotations

import jax

from repro.core import recipe as R
from repro.core.calibrate import Stats, TapCollector
from repro.models import lstm as L


def main():
    rows = []
    for ln in (False, True):
        for proj in (False, True):
            for ph in (False, True):
                variant = L.LSTMVariant(ln, proj, ph, False)
                cfg = L.LSTMConfig(16, 24, 12 if proj else 0, variant)
                params = L.init_lstm_params(jax.random.PRNGKey(0), cfg)
                xs = jax.random.normal(jax.random.PRNGKey(1), (2, 6, 16))
                col = TapCollector()
                L.lstm_layer(params, cfg, xs, collector=col)
                stats = Stats()
                stats.merge(jax.device_get(col.snapshot()))
                _, spec = R.quantize_lstm_layer(params, cfg, stats)
                table = R.recipe_table(spec)
                for tensor, desc in table.items():
                    print(f"table2/{variant.name}/{tensor},0.00,{desc}")
                rows.append((variant.name, table))
    return rows


if __name__ == "__main__":
    main()
