"""Paper sec 3.2.1 analysis: clamping vs resolution error across Q_{m.15-m}.

Reproduces the design decision that Q3.12 minimizes total error for the
sigmoid/tanh input format: clamping error f(inf)-f(2^m) falls with m while
resolution error 2^-n * max f' grows with m; the implementation's measured
max error over the full int16 grid confirms the analytic optimum.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import fixedpoint as fp


def analytic_errors(m: int):
    n = 15 - m
    clamp_tanh = 1.0 - np.tanh(2.0**m)
    res_tanh = 2.0**-n * 1.0  # max d/dx tanh = 1 at 0
    clamp_sig = 1.0 - 1.0 / (1.0 + np.exp(-(2.0**m)))
    res_sig = 2.0**-n * 0.25
    return clamp_tanh + res_tanh, clamp_sig + res_sig


def measured_errors(m: int):
    xs = np.arange(-32768, 32768, dtype=np.int16)
    scale = 2.0 ** -(15 - m)
    # measured over the representable grid + clamping at the format edges
    dense = np.linspace(-16, 16, 20001)
    t = np.asarray(fp.tanh_q15(jnp.array(xs), m), np.float64) / 32768
    # map each dense x to its quantized input bucket
    q_in = np.clip(np.round(dense / scale), -32768, 32767).astype(np.int64)
    t_dense = t[q_in + 32768]
    err_t = np.abs(t_dense - np.tanh(dense)).max()
    s = np.asarray(fp.sigmoid_q15(jnp.array(xs), m), np.float64) / 32768
    s_dense = s[q_in + 32768]
    err_s = np.abs(s_dense - 1 / (1 + np.exp(-dense))).max()
    return err_t, err_s


def main():
    rows = []
    for m in range(0, 8):
        at, as_ = analytic_errors(m)
        mt, ms = measured_errors(m)
        rows.append((m, at, as_, mt, ms))
        print(f"act_error/Q{m}.{15-m},0.00,"
              f"analytic_tanh={at:.3e};analytic_sig={as_:.3e};"
              f"measured_tanh={mt:.3e};measured_sig={ms:.3e}")
    best_t = min(rows, key=lambda r: r[3])[0]
    print(f"act_error/optimum,0.00,best_m_tanh={best_t} (paper: m=3)")
    return rows


if __name__ == "__main__":
    main()
