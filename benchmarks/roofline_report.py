"""Aggregate the dry-run JSONs into the roofline table (EXPERIMENTS.md data).

Prints one CSV row per (arch x shape x mesh) with the three terms, dominant
bottleneck, MODEL_FLOPS/HLO ratio, and memory footprint.
"""
from __future__ import annotations

import glob
import json
import os

OUT_DIR = "experiments/dryrun"


def load_all(pattern="*.json"):
    rows = []
    for path in sorted(glob.glob(os.path.join(OUT_DIR, pattern))):
        with open(path) as f:
            d = json.load(f)
        rows.append(d)
    return rows


def main():
    rows = load_all()
    ok = 0
    for d in rows:
        tag = f"{d.get('arch')}/{d.get('shape')}/{d.get('mesh')}"
        if d.get("quant", "none") != "none":
            tag += f"/{d['quant']}"
        if "error" in d:
            print(f"roofline/{tag},0.00,ERROR={d['error'][:120]}")
            continue
        ok += 1
        r = d["roofline"]
        pd = d["per_device"]
        print(
            f"roofline/{tag},{r['roofline_bound_s'] * 1e6:.1f},"
            f"compute_s={r['compute_s']:.4g};memory_s={r['memory_s']:.4g};"
            f"collective_s={r['collective_s']:.4g};dominant={r['dominant']};"
            f"useful_ratio={d.get('useful_ratio', 0):.3f};"
            f"peak_gb={pd['peak_hbm_gb']};method={d.get('method', '?')}"
        )
    print(f"roofline/summary,0.00,cells_ok={ok};cells_total={len(rows)}")
    return rows


if __name__ == "__main__":
    main()
