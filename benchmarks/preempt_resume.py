"""Preemption/resume cost + oversubscribed-scheduling goodput benchmark.

Two measurements, both cashing in the paper's tiny-integer-state property:

* **Swap microbenchmark** -- the wall cost of parking one stream's
  quantized ``(h, c, len)`` state into the host-side pool
  (``slice_state`` + device_get + page write) and restoring it
  (page read + jitted slot write), against the cost of one fused engine
  decode step.  An integer LSTM stream is a few KB, so a full
  preempt+resume round trip should cost on the order of a single step --
  THE reason aggressive scheduling policies are affordable at all (a
  transformer's per-stream KV cache is MBs and grows with context).

* **Bursty goodput** -- the same bursty open-loop trace (bursts of
  ``burst_size`` requests arriving every ``period`` engine steps) served
  two ways:

    - ``fifo-reject`` at ``oversubscribe=1``: an arrival that finds no
      free slot is refused outright -- the classic admission-control
      baseline.  Rejected work is gone; between bursts the surviving
      streams drain and slots sit idle.
    - a preempting policy (default ``srf``) with ``oversubscribe > 1``:
      every arrival is admitted, overflow parks in the state pool, and the
      backlog keeps slots full between bursts.

  A partially-occupied step costs the same fused dispatch as a full one,
  so sustained tokens/s tracks occupancy: the oversubscribed engine must
  win.  Both legs' outputs stay bit-identical per stream to decoding it
  alone (asserted here on every served stream, hard exit on drift).

    PYTHONPATH=src python benchmarks/preempt_resume.py --slots 4
    # CI smoke gate:
    PYTHONPATH=src python benchmarks/preempt_resume.py --slots 4 \
        --bursts 3 --check-speedup 1.05 --out BENCH_preempt.json
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")

from repro.launch import engine as E  # noqa: E402
from repro.launch.state_pool import StatePool  # noqa: E402
from repro.models import lstm_lm  # noqa: E402

from engine_throughput import build_quantized_lm  # noqa: E402


def bursty_trace(cfg, *, bursts, burst_size, period, seed):
    """``bursts`` waves of ``burst_size`` requests, one wave every
    ``period`` engine steps -- short prompts, heavy-tailed generation
    budgets (mostly short streams plus the occasional very long one, the
    mix where admission control hurts most: a long survivor pins a slot
    through several burst periods while every arrival it displaced was
    already refused, so the reject leg pays full fused-dispatch steps at
    1/slots occupancy)."""
    rng = np.random.default_rng(seed)
    out = []
    rid = 0
    for b in range(bursts):
        for _ in range(burst_size):
            p = int(rng.choice((2, 3, 4)))
            g = int(rng.choice((4, 6, 8, 40)))
            out.append(E.Request(
                rid=rid, prompt=rng.integers(0, cfg.vocab_size, size=(p,)),
                max_new_tokens=g, arrival=float(b * period)))
            rid += 1
    return out


def swap_microbench(params, qlayers, cfg, slots, backend, reps=50):
    """Mean wall cost of preempt (slice+host copy+pool write), resume
    (pool read+jitted slot write), and one fused decode step."""
    state = lstm_lm.init_quant_decode_state(qlayers, slots,
                                            per_slot_len=True)
    step, _, _, _, _, write = E._engine_step_fns(qlayers, cfg, backend)
    pool = StatePool()
    toks = jnp.zeros((slots,), jnp.int32)
    active = jnp.ones((slots,), bool)
    # warm every program (compile outside the timed region)
    _, state = step(params, toks, state, active)
    pool.put(-1, jax.device_get(lstm_lm.slice_state(state, 0)))
    state = write(state, jnp.int32(0), pool.take(-1))
    jax.block_until_ready(state["h"][0])

    t0 = time.perf_counter()
    for i in range(reps):
        pool.put(i, jax.device_get(lstm_lm.slice_state(state, i % slots)))
    preempt_us = (time.perf_counter() - t0) / reps * 1e6
    t0 = time.perf_counter()
    for i in range(reps):
        state = write(state, jnp.int32(i % slots), pool.take(i))
    jax.block_until_ready(state["h"][0])
    resume_us = (time.perf_counter() - t0) / reps * 1e6
    t0 = time.perf_counter()
    for _ in range(reps):
        _, state = step(params, toks, state, active)
    jax.block_until_ready(state["h"][0])
    step_us = (time.perf_counter() - t0) / reps * 1e6
    return {
        "preempt_us": round(preempt_us, 1),
        "resume_us": round(resume_us, 1),
        "step_us": round(step_us, 1),
        "roundtrip_over_step": round((preempt_us + resume_us) /
                                     max(step_us, 1e-9), 3),
        "state_bytes_per_stream": pool.state_bytes_per_stream,
    }


def run_leg(params, qlayers, cfg, requests, *, slots, backend, policy,
            oversubscribe):
    eng = E.ContinuousBatchingEngine(
        params, qlayers, cfg, n_slots=slots, backend=backend,
        policy=policy, oversubscribe=oversubscribe)
    eng.submit_all([E.Request(rid=r.rid, prompt=r.prompt,
                              max_new_tokens=r.max_new_tokens,
                              priority=r.priority, arrival=r.arrival)
                    for r in requests])
    return eng.run()


def leg_summary(results, stats):
    served = [r for r in results.values() if not r.rejected]
    return {
        "policy": stats.policy,
        "oversubscribe": stats.oversubscribe,
        "tok_s": round(stats.tokens_per_s, 1),
        "generated_tokens": stats.generated_tokens,
        "steps": stats.steps,
        "occupancy": round(stats.occupancy, 3),
        "served": len(served),
        "rejected": stats.rejected,
        "preemptions": stats.preemptions,
        "resumes": stats.resumes,
        "peak_live": stats.peak_live,
        "mean_ttft_steps": round(stats.mean_ttft_steps, 2),
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--bursts", type=int, default=4)
    ap.add_argument("--burst-size", type=int, default=None,
                    help="requests per burst (default 3 * slots)")
    ap.add_argument("--period", type=int, default=24,
                    help="engine steps between bursts")
    ap.add_argument("--policy", default="srf",
                    help="preempting policy for the oversubscribed leg")
    ap.add_argument("--oversubscribe", type=float, default=3.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", default="xla",
                    choices=["xla", "pallas", "interpret"])
    ap.add_argument("--out", default=None,
                    help="write the JSON artifact here (BENCH_preempt.json)")
    ap.add_argument("--check-speedup", type=float, default=None,
                    help="exit nonzero unless oversubscribed tokens/s / "
                         "reject-baseline tokens/s >= this")
    args = ap.parse_args()
    burst_size = args.burst_size or 3 * args.slots

    params, qlayers, cfg = build_quantized_lm(args.backend)
    requests = bursty_trace(cfg, bursts=args.bursts, burst_size=burst_size,
                            period=args.period, seed=args.seed)

    # warm the compiled programs on a throwaway workload (both legs share
    # them: same slot count, chunk=1) and batch-1 reference shapes
    for p in (2, 3, 4):
        E.decode_single(params, qlayers, cfg, np.zeros((p,), np.int32), 2,
                        backend=args.backend)
    warm = [E.Request(rid=-1 - i, prompt=np.zeros((2,), np.int32),
                      max_new_tokens=2) for i in range(args.slots + 1)]
    run_leg(params, qlayers, cfg, warm, slots=args.slots,
            backend=args.backend, policy=args.policy,
            oversubscribe=args.oversubscribe)

    swap = swap_microbench(params, qlayers, cfg, args.slots, args.backend)

    rej_results, rej_stats = run_leg(
        params, qlayers, cfg, requests, slots=args.slots,
        backend=args.backend, policy="fifo-reject", oversubscribe=1.0)
    ovs_results, ovs_stats = run_leg(
        params, qlayers, cfg, requests, slots=args.slots,
        backend=args.backend, policy=args.policy,
        oversubscribe=args.oversubscribe)

    # bit-exactness: every served stream identical to decoding it alone
    # (verdict computed here, enforced after the artifact is written so a
    # drifting run still leaves numbers to debug with)
    drifted = []
    for r in requests:
        ref = E.decode_single(params, qlayers, cfg, r.prompt,
                              r.max_new_tokens, backend=args.backend)
        if ovs_results[r.rid].tokens != ref:
            drifted.append(("oversub", r.rid))
        if not rej_results[r.rid].rejected and \
                rej_results[r.rid].tokens != ref:
            drifted.append(("reject", r.rid))

    rej = leg_summary(rej_results, rej_stats)
    ovs = leg_summary(ovs_results, ovs_stats)
    speedup = ovs["tok_s"] / rej["tok_s"] if rej["tok_s"] else float("inf")
    served_gain = ovs["served"] / max(rej["served"], 1)

    print(f"preempt_resume,arch={cfg.name},backend={args.backend},"
          f"slots={args.slots},bursts={args.bursts},"
          f"burst_size={burst_size},period={args.period}")
    print(f"preempt_resume/swap,preempt_us={swap['preempt_us']},"
          f"resume_us={swap['resume_us']},step_us={swap['step_us']},"
          f"roundtrip_over_step={swap['roundtrip_over_step']},"
          f"state_bytes={swap['state_bytes_per_stream']}")
    for name, leg in (("reject", rej), ("oversub", ovs)):
        print(f"preempt_resume/{name},policy={leg['policy']},"
              f"tok_s={leg['tok_s']},occupancy={leg['occupancy']},"
              f"served={leg['served']},rejected={leg['rejected']},"
              f"preemptions={leg['preemptions']},resumes={leg['resumes']},"
              f"peak_live={leg['peak_live']}")
    print(f"preempt_resume/speedup,{speedup:.2f},"
          f"served_gain={served_gain:.2f}")

    if args.out:
        artifact = {
            "bench": "preempt_resume",
            "arch": cfg.name,
            "backend": args.backend,
            "slots": args.slots,
            "bursts": args.bursts,
            "burst_size": burst_size,
            "period": args.period,
            "requests": len(requests),
            "swap": swap,
            "reject": rej,
            "oversub": ovs,
            "speedup": round(speedup, 3),
            "served_gain": round(served_gain, 3),
            "bitexact": not drifted,
        }
        with open(args.out, "w") as f:
            json.dump(artifact, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.out}")

    # a hard exit, not an assert, so `python -O` can't skip it
    if drifted:
        leg, rid = drifted[0]
        raise SystemExit(f"FAIL: {leg} leg drifted from decode_single on "
                         f"stream {rid} ({len(drifted)} drifting streams)")
    if args.check_speedup is not None and speedup < args.check_speedup:
        print(f"FAIL: oversubscribed/reject tokens/s {speedup:.2f} < "
              f"required {args.check_speedup:.2f}")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
