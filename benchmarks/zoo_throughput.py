"""Cell zoo throughput: integer GRU vs LSTM at matched hidden size.

The PR-8 perf gate.  Both cells run the same hoisted two-stage executor on
the same (B, T, d_in, d_h) problem (noLN/noProj topology so the comparison
is pure cell math); the GRU's packed GEMM is 3 gate blocks against the
LSTM's 4 and it carries a single int8 ``h`` instead of ``(h, c)``, so its
sequence throughput should come out at least as high.

Writes ``BENCH_zoo.json`` and exits non-zero if GRU hoisted tokens/s falls
below ``--min-ratio`` (default 1.0) times LSTM's on the primary shape.

    PYTHONPATH=src python benchmarks/zoo_throughput.py
"""
from __future__ import annotations

import argparse
import json
import sys

sys.path.insert(0, "src")

from prefill_throughput import run  # noqa: E402


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--d-in", type=int, default=256)
    ap.add_argument("--d-h", type=int, default=128)
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--backend", default="xla",
                    choices=["xla", "pallas", "interpret"])
    ap.add_argument("--min-ratio", type=float, default=1.0,
                    help="hard gate: GRU/LSTM hoisted tokens/s must be >= "
                         "this (exit 1 otherwise)")
    ap.add_argument("--out", default="BENCH_zoo.json",
                    help="JSON artifact path ('' disables)")
    args = ap.parse_args()

    shapes = [(args.batch, args.seq, args.d_in, args.d_h)]
    by_cell = {
        cell: run(shapes, args.iters, backend=args.backend, cell=cell)[0]
        for cell in ("lstm", "gru")
    }

    print("bench/zoo,cell,B,T,d_in,d_h,hoisted_tok_s,stepwise_tok_s,"
          "bitexact")
    for cell, r in by_cell.items():
        print(f"bench/zoo,{cell},{r['B']},{r['T']},{r['d_in']},{r['d_h']},"
              f"{r['hoisted_tokens_per_s']:.0f},"
              f"{r['stepwise_tokens_per_s']:.0f},{r['bitexact']}")

    ratio = (by_cell["gru"]["hoisted_tokens_per_s"]
             / by_cell["lstm"]["hoisted_tokens_per_s"])
    print(f"bench/zoo_ratio,gru/lstm,{ratio:.2f}")

    if args.out:
        with open(args.out, "w") as f:
            json.dump({"benchmark": "zoo_throughput",
                       "backend": args.backend, "iters": args.iters,
                       "gru_over_lstm_hoisted": ratio,
                       "results": by_cell}, f, indent=2)
        print(f"bench/zoo_artifact,{args.out}")

    if not all(r["bitexact"] for r in by_cell.values()):
        print("bench/zoo_gate,FAIL,bit-exactness violated")
        return 1
    if ratio < args.min_ratio:
        print(f"bench/zoo_gate,FAIL,gru/lstm={ratio:.2f} < "
              f"required {args.min_ratio:.2f}")
        return 1
    print(f"bench/zoo_gate,OK,gru/lstm={ratio:.2f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
