"""Paper Table 1 proxy: {LSTM, Sparse LSTM, Sparse CIFG} x {Float, Hybrid,
Integer} accuracy + model size on a synthetic sequence task.

The paper's WER table needs proprietary speech data; the reproduction trains
a small LSTM LM on the synthetic affine-rule corpus and reports next-token
accuracy for the same 9 cells, plus serialized model bytes -- validating the
paper's claims: (a) integer ~= hybrid ~= float accuracy, (b) ~4x smaller,
(c) CIFG loses a little capacity but quantizes fine.
"""
from __future__ import annotations

import time
from typing import Dict, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import recipe as R
from repro.core.calibrate import Stats, TapCollector
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import lstm as L
from repro.models import quant_lstm as QL

D_IN, D_H, VOCAB, SEQ = 32, 64, 64, 24


def _embed(tokens, vocab=VOCAB, d=D_IN):
    # fixed random projection embedding (kept float; it's not part of the LSTM)
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.standard_normal((vocab, d)) * 0.5, jnp.float32)
    return table[tokens]


def _train_float(variant: L.LSTMVariant, sparsity: float, steps: int = 150):
    cfg = L.LSTMConfig(D_IN, D_H, 0, variant)
    params = L.init_lstm_params(jax.random.PRNGKey(0), cfg)
    head = jnp.zeros((D_H, VOCAB), jnp.float32)
    data = SyntheticLM(DataConfig(vocab_size=VOCAB, seq_len=SEQ,
                                  global_batch=16, noise=0.0))

    def loss_fn(p, h, batch):
        xs = _embed(batch["tokens"])
        ys, _ = L.lstm_layer(p, cfg, xs)
        logits = ys @ h
        ll = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(
            ll, batch["labels"][..., None], axis=-1))

    grad_fn = jax.jit(jax.value_and_grad(loss_fn, argnums=(0, 1)))
    lr = 0.08
    for step, batch in data.iterate():
        if step >= steps:
            break
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        _, (gp, gh) = grad_fn(params, head, batch)
        params = jax.tree_util.tree_map(lambda a, g: a - lr * g, params, gp)
        head = head - lr * gh
    if sparsity > 0:
        params = L.sparsify_params(params, sparsity)
        # brief fine-tune after pruning
        for step, batch in data.iterate(steps):
            if step >= steps + 30:
                break
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            _, (gp, gh) = grad_fn(params, head, batch)
            params = jax.tree_util.tree_map(lambda a, g: a - lr * g, params, gp)
            params = L.sparsify_params(params, sparsity)
            head = head - lr * gh
    return cfg, params, head, data


def _accuracy(logits, labels):
    return float(jnp.mean(jnp.argmax(logits, -1) == labels))


def _nbytes(tree):
    return sum(np.asarray(x).nbytes for x in jax.tree_util.tree_leaves(tree))


def run() -> Dict[str, Tuple[float, float]]:
    rows = {}
    cells = [
        ("LSTM", L.LSTMVariant(use_layernorm=True), 0.0),
        ("SparseLSTM", L.LSTMVariant(use_layernorm=True), 0.5),
        ("SparseCIFG", L.LSTMVariant(use_layernorm=True, use_cifg=True), 0.5),
    ]
    for name, variant, sparsity in cells:
        cfg, params, head, data = _train_float(variant, sparsity)
        eval_batch = {k: jnp.asarray(v)
                      for k, v in data.batch_at(10_000).items()}
        xs = _embed(eval_batch["tokens"])
        labels = eval_batch["labels"]

        # float
        ys, _ = L.lstm_layer(params, cfg, xs)
        acc_f = _accuracy(ys @ head, labels)
        size_f = _nbytes(params)

        # hybrid (dynamic-range int8 weights, float activations)
        wq, scales = QL.hybrid_weights(params)
        h = jnp.zeros((xs.shape[0], D_H))
        c = jnp.zeros((xs.shape[0], D_H))
        outs = []
        for t in range(xs.shape[1]):
            acc = {}
            gates = {}
            for g in variant.gates:
                a = (QL.hybrid_matmul(xs[:, t], wq["W"][g], scales[f"W_{g}"])
                     + QL.hybrid_matmul(h, wq["R"][g], scales[f"R_{g}"]))
                from repro.models.lstm import _layernorm_stats
                a = _layernorm_stats(a) * params["L"][g] + params["b"][g]
                gates[g] = a
            f_t = jax.nn.sigmoid(gates["f"])
            z_t = jnp.tanh(gates["z"])
            i_t = (1.0 - f_t) if variant.use_cifg else jax.nn.sigmoid(gates["i"])
            c = i_t * z_t + f_t * c
            o_t = jax.nn.sigmoid(gates["o"])
            h = o_t * jnp.tanh(c)
            outs.append(h)
        ys_h = jnp.stack(outs, 1)
        acc_h = _accuracy(ys_h @ head, labels)
        size_h = _nbytes(wq) + _nbytes(params["b"]) + _nbytes(params["L"])

        # integer-only (paper)
        col = TapCollector()
        L.lstm_layer(params, cfg, xs[:8], collector=col)  # ~100-sample calib
        stats = Stats()
        stats.merge(jax.device_get(col.snapshot()))
        arrays, spec = R.quantize_lstm_layer(params, cfg, stats)
        xs_q = QL.quantize_input(xs, spec.s_x, spec.zp_x)
        ys_q, _ = QL.quant_lstm_layer(arrays, spec, xs_q)
        ys_i = QL.dequantize_output(ys_q, spec.s_h, spec.zp_h_out)
        acc_i = _accuracy(ys_i @ head, labels)
        size_i = _nbytes(arrays)

        rows[f"{name}/float"] = (acc_f, size_f)
        rows[f"{name}/hybrid"] = (acc_h, size_h)
        rows[f"{name}/integer"] = (acc_i, size_i)
    return rows


def main(csv=True):
    rows = run()
    for name, (acc, size) in rows.items():
        print(f"table1/{name},{0.0:.2f},acc={acc:.4f};bytes={size}")
    return rows


if __name__ == "__main__":
    main()
