"""All assigned architectures (10) + the paper's own LSTM RNN-T stack.

Every entry carries the exact table config from the assignment plus a
REDUCED smoke-test config of the same family.  ``head_dim`` follows the
family's published value where the assignment table omits it (noted inline).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

from .base import ArchConfig


def _smoke(cfg: ArchConfig, **kw) -> ArchConfig:
    """Reduced same-family config: small widths/layers/experts/vocab."""
    base = dict(
        n_layers=2,
        d_model=64,
        vocab_size=256,
        n_heads=4 if cfg.n_heads else 0,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        head_dim=16 if cfg.head_dim else 0,
        d_ff=128 if cfg.d_ff else 0,
        n_experts=4 if cfg.n_experts else 0,
        topk=min(cfg.topk, 2) if cfg.topk else 0,
        moe_d_ff=64 if cfg.moe_d_ff else 0,
        dense_d_ff=128 if cfg.dense_d_ff else 0,
        n_dense_layers=1 if cfg.n_dense_layers else 0,
        n_shared_experts=cfg.n_shared_experts and 1,
        d_state=cfg.d_state and 8,
        d_rnn=cfg.d_rnn and 64,
        enc_layers=cfg.enc_layers and 2,
        n_frontend_tokens=cfg.n_frontend_tokens and 16,
        attn_window=cfg.attn_window and 32,
        expand=cfg.expand,
        remat="none",
    )
    base.update(kw)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **base)


CONFIGS: Dict[str, ArchConfig] = {}

# --- dense LM family --------------------------------------------------------

CONFIGS["qwen3-4b"] = ArchConfig(
    name="qwen3-4b", family="dense", n_layers=36, d_model=2560,
    n_heads=32, n_kv_heads=8, head_dim=128,  # head_dim 128 per Qwen3 family
    d_ff=9728, vocab_size=151936, qk_norm=True, rope_theta=1e6,
    mlp_type="swiglu", norm_type="rmsnorm", shard_profile="dense_fsdp",
)

CONFIGS["stablelm-1.6b"] = ArchConfig(
    name="stablelm-1.6b", family="dense", n_layers=24, d_model=2048,
    n_heads=32, n_kv_heads=32, head_dim=64, d_ff=5632, vocab_size=100352,
    mlp_type="swiglu", norm_type="layernorm", shard_profile="dense_small",
)

CONFIGS["yi-34b"] = ArchConfig(
    name="yi-34b", family="dense", n_layers=60, d_model=7168,
    n_heads=56, n_kv_heads=8, head_dim=128, d_ff=20480, vocab_size=64000,
    mlp_type="swiglu", norm_type="rmsnorm", rope_theta=5e6,
    shard_profile="dense_fsdp", optimizer="adafactor",
)

CONFIGS["qwen1.5-0.5b"] = ArchConfig(
    name="qwen1.5-0.5b", family="dense", n_layers=24, d_model=1024,
    n_heads=16, n_kv_heads=16, head_dim=64, d_ff=2816, vocab_size=151936,
    qkv_bias=True, mlp_type="swiglu", norm_type="rmsnorm",
    tie_embeddings=True, shard_profile="dense_small",
)

# --- audio (enc-dec, frontend stub) ----------------------------------------

CONFIGS["whisper-tiny"] = ArchConfig(
    name="whisper-tiny", family="encdec", n_layers=4, enc_layers=4,
    d_model=384, n_heads=6, n_kv_heads=6, head_dim=64, d_ff=1536,
    vocab_size=51865, mlp_type="gelu", norm_type="layernorm",
    n_frontend_tokens=1500, shard_profile="tiny", scan_layers=False,
)

# --- hybrid recurrent -------------------------------------------------------

CONFIGS["recurrentgemma-9b"] = ArchConfig(
    name="recurrentgemma-9b", family="hybrid", n_layers=38, d_model=4096,
    n_heads=16, n_kv_heads=1, head_dim=256, d_ff=12288, vocab_size=256000,
    mlp_type="geglu", norm_type="rmsnorm", attn_window=2048,
    block_pattern=("rec", "rec", "attn"), d_rnn=4096,
    shard_profile="dense_fsdp", scan_layers=False,
)

# --- VLM (ViT stub + InternLM2 LM) ------------------------------------------

CONFIGS["internvl2-2b"] = ArchConfig(
    name="internvl2-2b", family="vlm", n_layers=24, d_model=2048,
    n_heads=16, n_kv_heads=8, head_dim=128, d_ff=8192, vocab_size=92553,
    mlp_type="swiglu", norm_type="rmsnorm", n_frontend_tokens=256,
    shard_profile="dense_small",
)

# --- MoE ---------------------------------------------------------------------

CONFIGS["grok-1-314b"] = ArchConfig(
    name="grok-1-314b", family="moe", n_layers=64, d_model=6144,
    n_heads=48, n_kv_heads=8, head_dim=128, d_ff=32768, vocab_size=131072,
    n_experts=8, topk=2, moe_d_ff=32768, mlp_type="gelu",
    norm_type="rmsnorm", shard_profile="moe_fsdp", optimizer="adafactor",
)

CONFIGS["kimi-k2-1t-a32b"] = ArchConfig(
    name="kimi-k2-1t-a32b", family="moe", n_layers=61, d_model=7168,
    n_heads=64, n_kv_heads=8, head_dim=112, d_ff=2048, vocab_size=163840,
    n_experts=384, topk=8, n_shared_experts=1, n_dense_layers=1,
    moe_d_ff=2048, dense_d_ff=18432, mlp_type="swiglu", norm_type="rmsnorm",
    shard_profile="moe_fsdp", optimizer="adafactor",
)

# --- SSM ---------------------------------------------------------------------

CONFIGS["falcon-mamba-7b"] = ArchConfig(
    name="falcon-mamba-7b", family="ssm", n_layers=64, d_model=4096,
    vocab_size=65024, d_state=16, d_conv=4, expand=2, mlp_type="swiglu",
    norm_type="rmsnorm", shard_profile="dense_fsdp",
)

# --- the paper's own architecture (RNN-T encoder stack proxy) ---------------

CONFIGS["lstm-rnnt"] = ArchConfig(
    name="lstm-rnnt", family="lstm", n_layers=10, d_model=2048,
    d_ff=0, vocab_size=4096, d_rnn=2048, shard_profile="tiny",
)

# Same stack, GRU cell: 3 packed gates, single h carry, no projection
# (so the inter-layer width is d_rnn, not the LSTM's 640 projection).
CONFIGS["gru-rnnt"] = ArchConfig(
    name="gru-rnnt", family="lstm", n_layers=10, d_model=2048,
    d_ff=0, vocab_size=4096, d_rnn=2048, rnn_cell="gru",
    shard_profile="tiny",
)

SMOKE_CONFIGS: Dict[str, ArchConfig] = {
    k: _smoke(v) for k, v in CONFIGS.items()
}
# recurrentgemma's smoke must exercise the attention member of the pattern
SMOKE_CONFIGS["recurrentgemma-9b"] = _smoke(
    CONFIGS["recurrentgemma-9b"], n_layers=3)

# the paper-repro recurrent LMs (family="lstm": lstm-rnnt, gru-rnnt, ...)
# are not part of the assigned model set
ASSIGNED = tuple(k for k in CONFIGS if CONFIGS[k].family != "lstm")


def get_config(name: str, smoke: bool = False) -> ArchConfig:
    table = SMOKE_CONFIGS if smoke else CONFIGS
    if name not in table:
        raise KeyError(f"unknown arch '{name}'; have {sorted(table)}")
    return table[name]
