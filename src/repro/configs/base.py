"""Architecture + run configuration dataclasses.

Every assigned architecture is an ``ArchConfig`` in its own module under
``repro/configs``; shapes are the four assigned input-shape cells.  The config
is deliberately a flat superset across families -- a single dataclass keeps
the launcher, dry-run, and sharding rules uniform.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm | lstm
    n_layers: int
    d_model: int
    vocab_size: int
    # attention
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    attn_window: int = 0  # 0 = global
    # ffn
    d_ff: int = 0
    mlp_type: str = "swiglu"  # swiglu | geglu | gelu
    norm_type: str = "rmsnorm"  # rmsnorm | layernorm
    tie_embeddings: bool = False
    # moe
    n_experts: int = 0
    topk: int = 0
    n_shared_experts: int = 0
    n_dense_layers: int = 0
    moe_d_ff: int = 0
    dense_d_ff: int = 0
    capacity_factor: float = 1.25
    # ssm (mamba)
    d_state: int = 0
    d_conv: int = 4
    expand: int = 2
    # hybrid (recurrentgemma): pattern unit, e.g. ("rec", "rec", "attn")
    block_pattern: Tuple[str, ...] = ()
    d_rnn: int = 0
    # lstm family: which QuantRecurrentCell the stack uses (lstm | gru)
    rnn_cell: str = "lstm"
    # enc-dec / multimodal frontend stubs
    enc_layers: int = 0
    n_frontend_tokens: int = 0  # audio frames / image patches (precomputed)
    # distribution
    shard_profile: str = "default"
    remat: str = "full"  # none | full | dots
    optimizer: str = "adamw"  # adamw | adafactor
    scan_layers: bool = True

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (attention-free or windowed attention)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs have an autoregressive decoder

    def dt_rank(self) -> int:
        return max(self.d_model // 16, 1)

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def applicable_shapes(cfg: ArchConfig):
    """long_500k only for sub-quadratic archs (full-attention skip is noted
    in DESIGN.md); decode shapes skipped for encoder-only archs (none here)."""
    for s in SHAPES.values():
        if s.name == "long_500k" and not cfg.sub_quadratic:
            continue
        yield s
