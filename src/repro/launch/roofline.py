"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), all in seconds:
    compute    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
    memory     = HLO_bytes_per_device / HBM_bandwidth
    collective = wire_bytes_per_device / ICI_bandwidth

HLO FLOPs/bytes come from ``compiled.cost_analysis()`` (per-device, post-SPMD
partitioning).  Collective wire bytes are parsed from ``compiled.as_text()``
using ring-algorithm cost models:
    all-reduce          2 * size * (n-1)/n
    all-gather          size_out * (n-1)/n
    reduce-scatter      size_out * (n-1)          (== input*(n-1)/n)
    all-to-all          size * (n-1)/n
    collective-permute  size

Hardware constants (TPU v5e, per chip):
    197 TFLOP/s bf16  (394 TOP/s int8), 819 GB/s HBM,
    ICI: 4 links x ~50 GB/s; same-axis ring uses 2 links bidirectionally
    -> 100 GB/s effective per chip; cross-pod (the "pod" axis) uses DCN at
    ~25 GB/s per chip (documented assumption).
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS_BF16 = 197e12
PEAK_FLOPS_INT8 = 394e12
HBM_BW = 819e9
ICI_BW = 100e9  # 2 x 50 GB/s links per ring axis
DCN_BW = 25e9  # pod axis

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_COLL_RE = re.compile(
    r"=\s+(?:\()?([a-z0-9]+)\[([0-9,]*)\][^\s]*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
_TUPLE_COLL_RE = re.compile(
    r"=\s+\(([^)]*)\)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_SRC_TGT_RE = re.compile(r"source_target_pairs=\{")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclasses.dataclass
class CollectiveStats:
    by_kind_bytes: Dict[str, float]
    by_kind_count: Dict[str, int]
    wire_bytes: float  # ring-model wire bytes per device (ICI-equivalent)
    pod_wire_bytes: float  # portion crossing the pod axis (DCN)


def parse_collectives(hlo_text: str, n_pods: int = 1,
                      devices_per_pod: int = 256,
                      region_trip_hint: int = 1) -> CollectiveStats:
    """Collectives inside non-ENTRY computations (scan/while bodies) execute
    ``region_trip_hint`` times but appear once in the HLO text; the dry-run
    unrolls the layer dimension so the hint only covers inner loops."""
    by_bytes: Dict[str, float] = {}
    by_count: Dict[str, int] = {}
    wire = 0.0
    pod_wire = 0.0
    in_entry = True
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY "):
            in_entry = True
        elif line and not line[0].isspace() and line.rstrip().endswith("{"):
            in_entry = False
        if ("all-reduce" not in line and "all-gather" not in line
                and "reduce-scatter" not in line and "all-to-all" not in line
                and "collective-permute" not in line):
            continue
        if "-done" in line or "fusion" in line:
            continue
        m = _COLL_RE.search(line)
        sizes: List[int] = []
        kind = None
        if m:
            kind = m.group(3)
            sizes = [_shape_bytes(m.group(1), m.group(2))]
        else:
            mt = _TUPLE_COLL_RE.search(line)
            if not mt:
                continue
            kind = mt.group(2)
            for part in mt.group(1).split(", "):
                sm = re.match(r"([a-z0-9]+)\[([0-9,]*)\]", part.strip())
                if sm:
                    sizes.append(_shape_bytes(sm.group(1), sm.group(2)))
        if kind is None or not sizes:
            continue
        size = float(sum(sizes))
        if not in_entry:
            size *= max(region_trip_hint, 1)
        # group size n
        n = 1
        g = _GROUPS_RE.search(line)
        if g:
            n = len(g.group(1).split(","))
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            if gi:
                n = int(gi.group(2))
        crosses_pod = n > devices_per_pod and n_pods > 1
        if kind == "all-reduce":
            w = 2.0 * size * (n - 1) / max(n, 1)
        elif kind == "all-gather":
            w = size * (n - 1) / max(n, 1)
        elif kind == "reduce-scatter":
            w = size * (n - 1)
        elif kind == "all-to-all":
            w = size * (n - 1) / max(n, 1)
        else:  # collective-permute
            w = size
        by_bytes[kind] = by_bytes.get(kind, 0.0) + w
        by_count[kind] = by_count.get(kind, 0) + 1
        wire += w
        if crosses_pod:
            # fraction of the ring crossing pods ~ (n_pods-1)/n_pods of hops
            pod_wire += w / n_pods
    return CollectiveStats(by_bytes, by_count, wire, pod_wire)


def roofline_terms(
    flops: float,
    bytes_accessed: float,
    coll: CollectiveStats,
    *,
    int8_compute: bool = False,
) -> Dict[str, float]:
    peak = PEAK_FLOPS_INT8 if int8_compute else PEAK_FLOPS_BF16
    t_compute = flops / peak
    t_memory = bytes_accessed / HBM_BW
    t_coll = (coll.wire_bytes - coll.pod_wire_bytes) / ICI_BW + (
        coll.pod_wire_bytes / DCN_BW)
    terms = {
        "compute_s": t_compute,
        "memory_s": t_memory,
        "collective_s": t_coll,
    }
    dom = max(terms, key=terms.get)
    terms["dominant"] = dom
    total = max(t_compute, t_memory, t_coll)
    terms["roofline_bound_s"] = total
    terms["roofline_fraction"] = (t_compute / total) if total > 0 else 0.0
    return terms


def model_flops(n_params: int, n_active_params: int, tokens: int,
                kind: str) -> float:
    """6*N*D for training, 2*N*D forward-only (N = active params for MoE)."""
    n = n_active_params or n_params
    if kind == "train":
        return 6.0 * n * tokens
    return 2.0 * n * tokens


def _triangular_flash() -> bool:
    import os

    return os.environ.get("REPRO_TRIANGULAR_FLASH", "0") == "1"


def attention_flops(cfg, seq_len: int, batch: int, kind: str,
                    executed: bool = True) -> float:
    """Analytic attention FLOPs (QK^T + PV), excluded from 6N*D/2N*D.

    ``executed=True`` models what the code actually runs: the default flash
    schedule visits the full rectangular chunk grid (S^2 work for causal);
    with REPRO_TRIANGULAR_FLASH=1 it runs the triangular schedule (S^2/2).
    ``executed=False`` returns the *useful* (triangular) FLOPs regardless --
    used for the useful_ratio numerator.
    Decode: 2 * 2 * B * H * hd * S_cache per layer (one query position).
    """
    if cfg.n_heads == 0:
        return 0.0  # attention-free (mamba)
    H, hd = cfg.n_heads, cfg.head_dim
    n_attn_layers = cfg.n_layers
    if cfg.family == "hybrid":
        pat = cfg.block_pattern or ("rec", "rec", "attn")
        n_attn_layers = sum(
            1 for i in range(cfg.n_layers) if pat[i % len(pat)] == "attn")
    eff_s = seq_len if cfg.attn_window == 0 else min(seq_len, cfg.attn_window)
    if kind in ("train", "prefill"):
        causal_frac = 0.5 if (not executed or _triangular_flash()
                              or cfg.attn_window > 0) else 1.0
        per_layer = 4.0 * batch * H * hd * seq_len * eff_s * causal_frac
        if kind == "train":
            per_layer *= 3.0  # fwd + bwd(2x)
    else:
        per_layer = 4.0 * batch * H * hd * eff_s
    return per_layer * n_attn_layers


def attention_layer_count(cfg) -> int:
    if cfg.n_heads == 0:
        return 0
    if cfg.family == "hybrid":
        pat = cfg.block_pattern or ("rec", "rec", "attn")
        return sum(1 for i in range(cfg.n_layers) if pat[i % len(pat)] == "attn")
    return cfg.n_layers


def inner_scan_corrections(cfg, cell) -> Tuple[float, float]:
    """(add_flops, add_bytes), GLOBAL, for compute that lives inside inner
    scans (flash-attention KV chunks, SSM/RG-LRU/LSTM time recurrences) --
    XLA's cost_analysis counts those bodies once.

    Memory corrections model the TPU-target FUSED kernels (Pallas): attention
    logits/exp temps stay in VMEM (only Q/K/V/O hit HBM, with K/V re-read per
    q-chunk pass); recurrences stream inputs once with state resident in VMEM.
    The XLA fallback path would materialize more -- documented in DESIGN.md.
    """
    add_flops = 0.0
    add_bytes = 0.0
    B, S, kind = cell.global_batch, cell.seq_len, cell.kind
    train_mult = 3.0 if kind == "train" else 1.0
    if kind == "decode":
        return 0.0, 0.0  # decode is fully unrolled; HLO counts everything
    if cfg.n_heads:
        add_flops += attention_flops(cfg, S, B, kind, executed=True)
        nq = max(S // 512, 1)
        if _triangular_flash() and cfg.attn_window == 0:
            nq = max(nq // 2, 1)  # triangular: half the K/V re-read passes
        eff_s = S if cfg.attn_window == 0 else min(S, cfg.attn_window)
        l_attn = attention_layer_count(cfg)
        kv_bytes = 2 * eff_s * cfg.n_kv_heads * cfg.head_dim * 2  # K+V bf16
        add_bytes += l_attn * B * nq * kv_bytes * train_mult
    if cfg.family == "ssm":
        di, n = cfg.d_inner, cfg.d_state
        add_flops += 7.0 * B * S * di * n * cfg.n_layers * train_mult
        add_bytes += B * S * (3 * di + 2 * n) * 4 * cfg.n_layers * train_mult
    if cfg.family == "hybrid":
        n_rec = cfg.n_layers - attention_layer_count(cfg)
        add_flops += 8.0 * B * S * cfg.d_rnn * n_rec * train_mult
        add_bytes += B * S * 3 * cfg.d_rnn * 4 * n_rec * train_mult
    if cfg.family == "lstm":
        # per-step gate matmuls live inside the time scan: weights re-read
        # every step (the memory wall the paper's int8 weights attack)
        d_h, d_p = cfg.d_rnn, max(cfg.d_rnn * 5 // 16, 8)
        per_layer_params = 4 * (d_p * d_h + d_p * d_h) + d_h * d_p
        flops = 2.0 * B * S * per_layer_params * cfg.n_layers
        add_flops += flops * train_mult
        add_bytes += (S * per_layer_params * 4 * cfg.n_layers) * train_mult
    return add_flops, add_bytes


def active_params(cfg, n_params: int) -> int:
    """Approximate active-per-token parameter count for MoE archs."""
    if cfg.n_experts == 0:
        return n_params
    # expert params per layer
    n_moe_layers = cfg.n_layers - cfg.n_dense_layers
    per_expert = 3 * cfg.d_model * cfg.moe_d_ff
    total_expert = n_moe_layers * cfg.n_experts * per_expert
    active_expert = n_moe_layers * (cfg.topk + cfg.n_shared_experts) * per_expert
    return n_params - total_expert + active_expert
