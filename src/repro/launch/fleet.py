"""Fault-tolerant sharded serving tier: admission router over N engines.

``FleetRouter`` fronts N per-shard :class:`ContinuousBatchingEngine` s (each
optionally on its own disjoint device mesh, ``runtime.sharding.fleet_meshes``)
with least-loaded admission, bounded retry/backoff on transient admission
failures, and graceful degradation to fifo-reject when every shard is
saturated.  The fault plane is injectable and fully deterministic: a seeded
:class:`FaultInjector` can

  * **kill a shard** mid-flight (``at_step`` / ``at_frac`` of total requested
    generation progress), hard or graceful, with an optional scheduled
    restart;
  * **hang an engine step** (a ``step_hook`` sleep inside the shard
    watchdog's timed window -- the wired-in ``runtime.fault.StepWatchdog``
    must flag it, and ``on_hang="kill"`` turns the verdict into a
    drain-and-migrate fault-plane event);
  * **fail an admission** (per-rid schedules and/or a hash-seeded rate),
    exercising the router's capped exponential backoff.

Recovery leans on the paper's deployment property: an integer LSTM stream's
whole recurrent state is a few hundred host bytes, slice/stackable and
bit-exact through the paged pool.  So when a shard dies the router drains it
(``engine.export_streams``) and

  * streams whose state survived (host pool pages; or any resident stream on
    a *graceful* drain) are **migrated**: re-admitted to a surviving shard
    WITH their state via ``engine.adopt_stream`` -- the same
    ``pool.take -> jitted slot write`` path preemption uses, so they continue
    bit-exactly as if the shard never died;
  * hard-killed residents (device state lost) are **replayed**: their
    generated prefix is folded into a fresh request's prompt and
    teacher-forced back (bit-exact by determinism), the router stitching the
    prefix onto the continuation at finish;
  * never-started requests are simply re-routed.

Every completed stream -- migrated, replayed, or undisturbed -- is therefore
bit-identical to ``decode_single`` of its original request, which
``tests/test_fleet.py`` and ``benchmarks/fleet_load.py`` assert stream by
stream.  That recovery-correctness property is what a KV-cache transformer
cannot offer cheaply, and it is the reason this tier exists (ROADMAP item 1).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.launch.engine import (ContinuousBatchingEngine, MigratedStream,
                                 Request, StreamResult)
from repro.runtime.fault import StepWatchdog

__all__ = [
    "KillSpec", "HangSpec", "FaultInjector",
    "ShardStats", "FleetStats", "FleetStreamResult", "FleetRouter",
]


# ---------------------------------------------------------------------------
# Fault plane
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class KillSpec:
    """Kill shard ``shard`` when the fleet clock passes ``at_step`` OR fleet
    generation progress (completed / total requested tokens) passes
    ``at_frac`` -- exactly one must be given.  ``graceful=False`` models an
    accelerator death (resident device state lost -> replay); ``True`` a
    planned drain (every stream migrates with state).  ``restart_after``
    (fleet steps) schedules a fresh engine on the same devices; ``None``
    leaves the shard dead."""

    shard: int
    at_step: Optional[int] = None
    at_frac: Optional[float] = None
    graceful: bool = False
    restart_after: Optional[int] = None
    fired: bool = dataclasses.field(default=False, repr=False)

    def __post_init__(self):
        if (self.at_step is None) == (self.at_frac is None):
            raise ValueError(
                f"KillSpec(shard={self.shard}): give exactly one of "
                f"at_step / at_frac")
        if self.at_frac is not None and not 0.0 <= self.at_frac <= 1.0:
            raise ValueError(
                f"KillSpec(shard={self.shard}): at_frac must be in [0, 1], "
                f"got {self.at_frac}")


@dataclasses.dataclass
class HangSpec:
    """Sleep ``sleep_s`` inside shard ``shard``'s step timing window once its
    ENGINE step counter reaches ``at_step``, for ``repeat`` consecutive
    dispatched steps (fired at most ``repeat`` times total, so a restarted
    engine does not re-trigger it)."""

    shard: int
    at_step: int
    sleep_s: float = 0.05
    repeat: int = 1
    fired: int = dataclasses.field(default=0, repr=False)


def _spec_list(entries, cls):
    out = []
    for e in entries or ():
        out.append(e if isinstance(e, cls) else cls(**e))
    return out


class FaultInjector:
    """Deterministic, seeded fault plane for the fleet router.

    ``kills`` / ``hangs`` take :class:`KillSpec` / :class:`HangSpec`
    instances or plain dicts (the ``--fault-spec`` JSON schema).  Admission
    failures come from two deterministic sources: ``admission_fails`` maps
    ``rid -> k`` (that request's first ``k`` admission attempts fail --
    the targeted backoff test) and ``admission_fail_rate`` draws each
    (rid, attempt) from ``default_rng((seed, rid, attempt))`` so a given
    seed yields the same failure pattern on every run, every machine.
    """

    def __init__(self, *, seed: int = 0,
                 kills: Sequence[Any] = (),
                 hangs: Sequence[Any] = (),
                 admission_fails: Optional[Dict[int, int]] = None,
                 admission_fail_rate: float = 0.0):
        if not 0.0 <= admission_fail_rate < 1.0:
            raise ValueError(
                f"admission_fail_rate must be in [0, 1), "
                f"got {admission_fail_rate}")
        self.seed = int(seed)
        self.kills: List[KillSpec] = _spec_list(kills, KillSpec)
        self.hangs: List[HangSpec] = _spec_list(hangs, HangSpec)
        self.admission_fails = dict(admission_fails or {})
        self.admission_fail_rate = float(admission_fail_rate)
        self._sleep = time.sleep  # injectable for tests

    @classmethod
    def from_spec(cls, spec: Dict[str, Any]) -> "FaultInjector":
        """Build from the ``--fault-spec`` JSON object: ``{"seed": 0,
        "kills": [{"shard": 1, "at_frac": 0.5, ...}], "hangs": [...],
        "admission_fails": {"7": 2}, "admission_fail_rate": 0.1}``."""
        known = {"seed", "kills", "hangs", "admission_fails",
                 "admission_fail_rate"}
        extra = set(spec) - known
        if extra:
            raise ValueError(f"unknown fault-spec keys: {sorted(extra)}")
        fails = {int(k): int(v)
                 for k, v in (spec.get("admission_fails") or {}).items()}
        return cls(seed=spec.get("seed", 0), kills=spec.get("kills", ()),
                   hangs=spec.get("hangs", ()), admission_fails=fails,
                   admission_fail_rate=spec.get("admission_fail_rate", 0.0))

    # -- kills ---------------------------------------------------------------

    def kills_due(self, fleet_step: int, progress: float) -> List[KillSpec]:
        due = []
        for k in self.kills:
            if k.fired:
                continue
            if k.at_step is not None and fleet_step >= k.at_step:
                k.fired = True
                due.append(k)
            elif k.at_frac is not None and progress >= k.at_frac:
                k.fired = True
                due.append(k)
        return due

    # -- hangs ---------------------------------------------------------------

    def hook_for(self, shard: int) -> Optional[Callable[[int], None]]:
        """The ``step_hook`` closure wired into shard ``shard``'s engine;
        ``None`` when no hang targets it (hot loop pays nothing)."""
        specs = [h for h in self.hangs if h.shard == shard]
        if not specs:
            return None

        def hook(engine_step: int) -> None:
            for h in specs:
                if h.fired < h.repeat and engine_step >= h.at_step:
                    h.fired += 1
                    self._sleep(h.sleep_s)

        return hook

    # -- admission failures ----------------------------------------------------

    def admission_fails_for(self, rid: int, attempt: int) -> bool:
        """True when admission ``attempt`` (0-based) of request ``rid``
        should fail transiently.  Stateless and deterministic."""
        if attempt < self.admission_fails.get(rid, 0):
            return True
        if self.admission_fail_rate > 0.0:
            r = np.random.default_rng((self.seed, rid, attempt)).random()
            return bool(r < self.admission_fail_rate)
        return False


# ---------------------------------------------------------------------------
# Stats + results
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ShardStats:
    """Per-shard accumulation across every ``run(max_steps=1)`` call."""

    steps: int = 0
    active_slot_steps: int = 0
    generated_tokens: int = 0
    preemptions: int = 0
    resumes: int = 0
    stragglers: int = 0
    hung: int = 0
    adopted: int = 0  # migrated streams this shard took in
    kills: int = 0
    restarts: int = 0
    alive: bool = True

    def occupancy(self, n_slots: int) -> float:
        denom = self.steps * n_slots
        return self.active_slot_steps / denom if denom else 0.0


@dataclasses.dataclass
class FleetStats:
    fleet_steps: int = 0
    n_shards: int = 0
    n_slots: int = 0  # per shard
    submitted: int = 0
    completed: int = 0
    rejected: int = 0
    lost: int = 0  # outstanding at an early stop / dead-fleet deadlock
    generated_tokens: int = 0
    admit_retries: int = 0
    migrated_streams: int = 0  # re-admitted WITH state (adopt path)
    replayed_streams: int = 0  # state lost -> prefix folded + teacher-forced
    rerouted_pending: int = 0  # never-started requests moved off a dead shard
    kills: int = 0
    restarts: int = 0
    hang_events: int = 0  # shard steps the watchdog ruled hung
    wall_s: float = 0.0
    shards: List[ShardStats] = dataclasses.field(default_factory=list)

    @property
    def goodput_tokens_per_step(self) -> float:
        """Generated tokens per fleet step -- the deterministic goodput the
        benchmark gates on (wall-clock goodput is too noisy on shared CI)."""
        return (self.generated_tokens / self.fleet_steps
                if self.fleet_steps else 0.0)

    @property
    def tokens_per_s(self) -> float:
        return self.generated_tokens / self.wall_s if self.wall_s else 0.0


@dataclasses.dataclass
class FleetStreamResult:
    """One request's fate through the fleet: final stitched tokens plus
    router-level latency stamps (fleet steps, arrival -> first token, so
    queueing and recovery delays are inside the number -- the open-loop
    convention)."""

    rid: int
    tokens: List[int]
    prompt_len: int
    arrival_step: int
    admit_step: Optional[int] = None
    first_token_step: Optional[int] = None
    finished_step: Optional[int] = None
    ttft_steps: Optional[int] = None
    ttft_s: Optional[float] = None
    shard: Optional[int] = None  # shard that finished the stream
    migrations: int = 0  # adopt-path moves (state travelled)
    replays: int = 0  # replay-path moves (prefix re-ingested)
    admit_attempts: int = 1
    rejected: bool = False
    truncated: bool = False


@dataclasses.dataclass
class _Shard:
    engine: ContinuousBatchingEngine
    stats: ShardStats
    alive: bool = True
    restart_at: Optional[int] = None
    restart_graceful_pending: bool = False


@dataclasses.dataclass
class _Track:
    """Router-side bookkeeping for one submitted request."""

    request: Request  # the ORIGINAL request (bit-exactness oracle input)
    arrival_step: int
    prefix: List[int] = dataclasses.field(default_factory=list)
    emitted: int = 0  # prefix + tokens generated on the current shard
    shard: Optional[int] = None
    admit_step: Optional[int] = None
    first_token_step: Optional[int] = None
    first_token_wall: Optional[float] = None
    migrations: int = 0
    replays: int = 0
    attempts: int = 0  # admission attempts so far
    retry_at: Optional[int] = None


# ---------------------------------------------------------------------------
# Router
# ---------------------------------------------------------------------------


class FleetRouter:
    """Admission router over ``n_shards`` continuous-batching engines.

    Admission is least-loaded (live + queued streams vs the shard's
    ``max_live``), ties to the lowest shard index so routing is
    deterministic.  A transiently failed admission (injected) retries with
    capped exponential backoff (``backoff_steps * 2**(attempt-1)``, capped
    at ``backoff_cap_steps``, at most ``max_admit_attempts`` attempts) before
    the request is rejected.  When every alive shard is saturated the
    request waits in the fleet queue up to ``max_queue`` waiters
    (``None`` = unbounded); beyond that the router degrades to fifo-reject.

    ``on_hang``: what a shard-step hung verdict (its ``StepWatchdog``) does.
    ``"ignore"`` (default) only counts it; ``"kill"`` gracefully drains the
    shard -- every stream migrates with state to survivors -- and leaves it
    dead unless ``hang_restart_after`` schedules a restart.  Call
    :meth:`warmup` first when reacting to hangs: it runs a throwaway
    request per shard with the watchdog detached, so in-serving EMAs seed
    from post-compile step times instead of compile spikes.

    The router drives shards in lockstep: each :meth:`run` iteration is one
    *fleet step* = at most one engine step per alive shard (``run(max_steps=1,
    keep_live=True)``), which keeps the fault clock, latency stamps, and the
    goodput gate deterministic for a given workload + injector seed.
    """

    def __init__(self, params, qlayers, cfg, *, n_shards: int,
                 slots_per_shard: int, backend: str = "xla", chunk: int = 1,
                 speculate: int = 0, policy="fifo",
                 oversubscribe: float = 1.0, pool_page_size: int = 8,
                 injector: Optional[FaultInjector] = None,
                 meshes: Optional[Sequence[Any]] = None, rules=None,
                 watchdog_factory: Callable[[], StepWatchdog] = StepWatchdog,
                 on_hang: str = "ignore",
                 hang_restart_after: Optional[int] = None,
                 max_admit_attempts: int = 3, backoff_steps: int = 1,
                 backoff_cap_steps: int = 8,
                 max_queue: Optional[int] = None):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        if on_hang not in ("ignore", "kill"):
            raise ValueError(
                f"on_hang must be 'ignore' or 'kill', got {on_hang!r}")
        if max_admit_attempts < 1:
            raise ValueError(
                f"max_admit_attempts must be >= 1, got {max_admit_attempts}")
        if backoff_steps < 1 or backoff_cap_steps < backoff_steps:
            raise ValueError(
                f"need 1 <= backoff_steps <= backoff_cap_steps, got "
                f"{backoff_steps}/{backoff_cap_steps}")
        if meshes is not None and len(meshes) != n_shards:
            raise ValueError(
                f"meshes has {len(meshes)} entries for {n_shards} shards")
        if meshes is not None and rules is None \
                and any(m is not None for m in meshes):
            from repro.runtime import sharding as shlib
            rules = shlib.rules_for("tiny")
        self._model = (params, qlayers, cfg)
        self.n_shards = n_shards
        self.slots_per_shard = slots_per_shard
        self._engine_kw = dict(
            backend=backend, chunk=chunk, speculate=speculate, policy=policy,
            oversubscribe=oversubscribe, pool_page_size=pool_page_size)
        self._meshes = list(meshes) if meshes is not None else [None] * n_shards
        self._rules = rules
        self.injector = injector
        self._watchdog_factory = watchdog_factory
        self.on_hang = on_hang
        self.hang_restart_after = hang_restart_after
        self.max_admit_attempts = max_admit_attempts
        self.backoff_steps = backoff_steps
        self.backoff_cap_steps = backoff_cap_steps
        self.max_queue = max_queue
        self.stats = FleetStats(n_shards=n_shards, n_slots=slots_per_shard)
        self.shards: List[_Shard] = [
            _Shard(engine=self._make_engine(i), stats=ShardStats())
            for i in range(n_shards)]
        self._queue: List[int] = []  # rids waiting for capacity / arrival
        self._orphans: List[Tuple[int, MigratedStream]] = []  # (rid, ms)
        self._tracks: Dict[int, _Track] = {}
        self._results: Dict[int, FleetStreamResult] = {}
        self._all_rids: set = set()
        self._total_requested = 0  # sum of max_new over submitted requests
        self._fleet_step = 0
        self._warm_rid = -1  # negative rids: internal warmup streams

    # -- construction helpers -------------------------------------------------

    def _make_engine(self, i: int) -> ContinuousBatchingEngine:
        params, qlayers, cfg = self._model
        hook = self.injector.hook_for(i) if self.injector else None
        return ContinuousBatchingEngine(
            params, qlayers, cfg, self.slots_per_shard,
            mesh=self._meshes[i], rules=self._rules,
            watchdog=self._watchdog_factory(), step_hook=hook,
            **self._engine_kw)

    def warmup(self) -> None:
        """Run one throwaway request per shard with the watchdog detached:
        compiles the step (and, with ``chunk > 1``, the chunked prefill)
        programs and leaves each watchdog's EMA unseeded until real serving
        steps -- so compile spikes never become the hang baseline."""
        chunk = self._engine_kw["chunk"]
        plen = max(2 * chunk, 2)
        for sh in self.shards:
            if not sh.alive:
                continue
            wd, sh.engine.watchdog = sh.engine.watchdog, None
            hook, sh.engine._step_hook = sh.engine._step_hook, None
            try:
                sh.engine.submit(Request(
                    rid=self._warm_rid, prompt=np.zeros(plen, np.int32),
                    max_new_tokens=2))
                self._warm_rid -= 1
                sh.engine.run()
            finally:
                sh.engine.watchdog = wd
                sh.engine._step_hook = hook

    # -- submission ------------------------------------------------------------

    def submit(self, request: Request) -> None:
        """Queue a request; ``request.arrival`` is the FLEET step it becomes
        admissible (the engine-level arrival clock is not reused -- the
        router re-stamps shard submissions to arrive immediately)."""
        if request.rid < 0:
            raise ValueError(
                f"request ids must be >= 0 (negative rids are reserved "
                f"for router warmup), got {request.rid}")
        if request.rid in self._all_rids:
            raise ValueError(f"duplicate request id {request.rid}")
        self._all_rids.add(request.rid)
        self._tracks[request.rid] = _Track(
            request=request, arrival_step=int(request.arrival))
        self._queue.append(request.rid)
        self._total_requested += request.max_new_tokens
        self.stats.submitted += 1

    def submit_all(self, requests: Sequence[Request]) -> None:
        for r in requests:
            self.submit(r)

    # -- progress / placement ---------------------------------------------------

    def _progress(self) -> float:
        """Fraction of all requested generation tokens emitted so far --
        the ``at_frac`` kill clock."""
        if not self._total_requested:
            return 0.0
        done = sum(t.emitted for t in self._tracks.values())
        done += sum(len(r.tokens) for r in self._results.values())
        return done / self._total_requested

    def _alive(self) -> List[int]:
        return [i for i, sh in enumerate(self.shards) if sh.alive]

    def _load(self, i: int) -> int:
        eng = self.shards[i].engine
        return eng.live + eng.pending

    def _pick_shard(self, *, need_capacity: bool) -> Optional[int]:
        """Least-loaded alive shard; with ``need_capacity`` only shards
        below their admission ceiling qualify (recovery placement passes
        False: a migrated stream beats admission control)."""
        best, best_load = None, None
        for i in self._alive():
            load = self._load(i)
            if need_capacity and load >= self.shards[i].engine.max_live:
                continue
            if best_load is None or load < best_load:
                best, best_load = i, load
        return best

    # -- admission ----------------------------------------------------------------

    def _reject(self, rid: int, *, now: float) -> None:
        t = self._tracks.pop(rid)
        self._results[rid] = FleetStreamResult(
            rid=rid, tokens=[], prompt_len=int(t.request.prompt.size),
            arrival_step=t.arrival_step, finished_step=self._fleet_step,
            admit_attempts=t.attempts, rejected=True, truncated=True)
        self.stats.rejected += 1

    def _try_admissions(self, now: float) -> None:
        """FIFO pass over the fleet queue: place every arrived request that
        a shard has capacity for; inject transient failures; keep the rest
        queued (or fifo-reject past ``max_queue``)."""
        still: List[int] = []
        waiting = 0
        for rid in self._queue:
            t = self._tracks[rid]
            if t.arrival_step > self._fleet_step or \
                    (t.retry_at is not None and
                     t.retry_at > self._fleet_step):
                still.append(rid)
                if t.arrival_step <= self._fleet_step:
                    waiting += 1  # backing off counts against the queue cap
                continue
            target = self._pick_shard(need_capacity=True)
            if target is None:
                # saturated fleet: wait if the queue has room, else degrade
                # to fifo-reject (newest waiters bounce first)
                if self.max_queue is not None and waiting >= self.max_queue:
                    self._reject(rid, now=now)
                else:
                    still.append(rid)
                    waiting += 1
                continue
            attempt = t.attempts
            t.attempts += 1
            if self.injector is not None and \
                    self.injector.admission_fails_for(rid, attempt):
                # transient admission failure: capped exponential backoff,
                # then reject once the attempt budget is spent
                if t.attempts >= self.max_admit_attempts:
                    self._reject(rid, now=now)
                else:
                    pause = min(
                        self.backoff_steps * (2 ** (t.attempts - 1)),
                        self.backoff_cap_steps)
                    t.retry_at = self._fleet_step + pause
                    self.stats.admit_retries += 1
                    still.append(rid)
                    waiting += 1
                continue
            t.retry_at = None
            t.shard = target
            t.admit_step = self._fleet_step
            self.shards[target].engine.submit(
                dataclasses.replace(t.request, arrival=0.0))
        self._queue = still

    # -- fault plane: kills, restarts, hangs ------------------------------------

    def _kill_shard(self, idx: int, *, graceful: bool,
                    restart_after: Optional[int]) -> None:
        sh = self.shards[idx]
        if not sh.alive:
            return
        exported = sh.engine.export_streams(device_alive=graceful)
        sh.alive = False
        sh.stats.alive = False
        sh.stats.kills += 1
        self.stats.kills += 1
        if restart_after is not None:
            sh.restart_at = self._fleet_step + max(int(restart_after), 0)
        self._place_exported(exported)

    def _place_exported(self, exported: List[MigratedStream]) -> None:
        for ms in exported:
            rid = ms.request.rid
            if rid < 0:
                continue  # warmup leftovers die with the shard
            self._orphans.append((rid, ms))
        self._drain_orphans()

    def _drain_orphans(self) -> None:
        """Re-home drained streams onto alive shards.  Streams with state
        migrate (adopt path); hard-killed residents replay (prefix folded
        into a fresh prompt); pending requests re-queue.  Orphans stay
        parked here while no shard is alive -- a scheduled restart picks
        them up."""
        if not self._orphans:
            return
        left: List[Tuple[int, MigratedStream]] = []
        for rid, ms in self._orphans:
            t = self._tracks.get(rid)
            if t is None:
                continue  # rejected/finished while orphaned (should not occur)
            if ms.pending:
                # never started: plain re-route through normal admission
                t.shard = None
                if rid not in self._queue:
                    self._queue.append(rid)
                self.stats.rerouted_pending += 1
                continue
            target = self._pick_shard(need_capacity=False)
            if target is None:
                left.append((rid, ms))
                continue
            eng = self.shards[target].engine
            if ms.state_row is not None:
                # state survived: bit-exact continuation via the pool write
                eng.adopt_stream(
                    ms.request, state_row=ms.state_row, fed=ms.fed,
                    generated=ms.generated, drafter=ms.drafter,
                    preemptions=ms.preemptions)
                t.shard = target
                t.migrations += 1
                self.shards[target].stats.adopted += 1
                self.stats.migrated_streams += 1
            else:
                # device state died: fold the generated prefix into the
                # prompt and teacher-force it back (deterministic integer
                # math makes the replayed state bitwise identical)
                t.prefix.extend(ms.generated)
                remaining = ms.request.max_new_tokens - len(ms.generated)
                folded = Request(
                    rid=rid,
                    prompt=np.concatenate([
                        ms.request.prompt,
                        np.asarray(ms.generated, np.int32)]),
                    max_new_tokens=remaining,
                    priority=ms.request.priority)
                eng.submit(folded)
                t.shard = target
                t.replays += 1
                self.stats.replayed_streams += 1
        self._orphans = left

    def _restarts_due(self) -> None:
        for i, sh in enumerate(self.shards):
            if not sh.alive and sh.restart_at is not None \
                    and sh.restart_at <= self._fleet_step:
                sh.engine = self._make_engine(i)
                sh.alive = True
                sh.stats.alive = True
                sh.restart_at = None
                sh.stats.restarts += 1
                self.stats.restarts += 1
        self._drain_orphans()

    # -- result plumbing -------------------------------------------------------

    def _finish(self, rid: int, r: StreamResult, shard: int,
                now: float) -> None:
        t = self._tracks.pop(rid)
        tokens = t.prefix + r.tokens
        if r.rejected:  # engine-level rejection (fifo-reject policies)
            self._results[rid] = FleetStreamResult(
                rid=rid, tokens=[], prompt_len=int(t.request.prompt.size),
                arrival_step=t.arrival_step, admit_step=t.admit_step,
                finished_step=self._fleet_step, admit_attempts=t.attempts,
                rejected=True, truncated=True)
            self.stats.rejected += 1
            return
        new = len(tokens) - t.emitted
        t.emitted = len(tokens)
        self.stats.generated_tokens += max(new, 0)
        self.shards[shard].stats.generated_tokens += max(new, 0)
        if t.first_token_step is None and tokens:
            t.first_token_step = self._fleet_step
            t.first_token_wall = now
        ttft_steps = ttft_s = None
        if t.first_token_step is not None:
            ttft_steps = t.first_token_step - t.arrival_step + 1
            ttft_s = t.first_token_wall - self._t_arrival_wall
        self._results[rid] = FleetStreamResult(
            rid=rid, tokens=tokens, prompt_len=int(t.request.prompt.size),
            arrival_step=t.arrival_step, admit_step=t.admit_step,
            first_token_step=t.first_token_step,
            finished_step=self._fleet_step,
            ttft_steps=ttft_steps, ttft_s=ttft_s, shard=shard,
            migrations=t.migrations, replays=t.replays,
            admit_attempts=max(t.attempts, 1), truncated=r.truncated)
        self.stats.completed += 1

    def _poll_first_tokens(self, now: float) -> None:
        """Per-step ``live_progress`` poll: stamp fleet-level TTFT the step a
        stream's emitted count first goes positive, and keep the per-stream
        emitted counters (the ``at_frac`` kill clock) current."""
        for i in self._alive():
            sh = self.shards[i]
            for rid, n_gen in sh.engine.live_progress().items():
                t = self._tracks.get(rid)
                if t is None:
                    continue
                total = len(t.prefix) + n_gen
                if total > t.emitted:
                    delta = total - t.emitted
                    t.emitted = total
                    self.stats.generated_tokens += delta
                    sh.stats.generated_tokens += delta
                if total > 0 and t.first_token_step is None:
                    t.first_token_step = self._fleet_step
                    t.first_token_wall = now

    # -- the fleet loop -----------------------------------------------------------

    def _outstanding(self) -> int:
        return len(self._tracks)

    def run(self, max_fleet_steps: Optional[int] = None
            ) -> Tuple[Dict[int, FleetStreamResult], FleetStats]:
        """Drive the fleet until every submitted request resolves (finished,
        rejected, or -- if the whole fleet dies with no scheduled restart --
        lost).  Returns per-request results keyed by rid plus fleet stats.
        Callable repeatedly; results accumulate across calls."""
        t0 = time.perf_counter()
        self._t_arrival_wall = t0  # wall anchor for ttft_s this run
        ran = 0
        while self._outstanding():
            if max_fleet_steps is not None and ran >= max_fleet_steps:
                break
            now = time.perf_counter()
            if self.injector is not None:
                progress = self._progress()
                self._restarts_due()
                for spec in self.injector.kills_due(
                        self._fleet_step, progress):
                    self._kill_shard(spec.shard, graceful=spec.graceful,
                                     restart_after=spec.restart_after)
            else:
                self._restarts_due()
            alive = self._alive()
            if not alive:
                if any(sh.restart_at is not None for sh in self.shards):
                    self._fleet_step += 1  # dead air until a restart lands
                    ran += 1
                    continue
                break  # whole fleet dead, no restart coming: bail out
            self._try_admissions(now)
            for i in list(alive):
                sh = self.shards[i]
                if not sh.alive:
                    continue  # killed earlier this same step
                eng = sh.engine
                if not (eng.live or eng.pending):
                    continue
                results, st = eng.run(max_steps=1, keep_live=True)
                s = sh.stats
                s.steps += st.steps
                s.active_slot_steps += st.active_slot_steps
                s.preemptions += st.preemptions
                s.resumes += st.resumes
                s.stragglers += st.stragglers
                s.hung += st.hung
                now = time.perf_counter()
                for rid, r in results.items():
                    if rid < 0:
                        continue  # warmup stragglers
                    self._finish(rid, r, i, now)
                if st.hung:
                    self.stats.hang_events += st.hung
                    if self.on_hang == "kill":
                        # the watchdog ruled the device wedged: graceful
                        # drain (host can still read state), streams migrate
                        self._kill_shard(
                            i, graceful=True,
                            restart_after=self.hang_restart_after)
            self._poll_first_tokens(time.perf_counter())
            self._fleet_step += 1
            ran += 1
        # a bounded run that hit max_fleet_steps hands live streams back to
        # the next run() call; any other early exit means the whole fleet
        # died with no restart coming -- drain those streams to truncated
        # results (prefixes preserved) so callers never lose one silently
        hit_bound = (max_fleet_steps is not None and ran >= max_fleet_steps)
        if self._outstanding() and not hit_bound:
            self._drain_outstanding_as_lost()
        self.stats.fleet_steps += ran
        self.stats.wall_s += time.perf_counter() - t0
        for sh in self.shards:
            sh.stats.alive = sh.alive
        self.stats.shards = [sh.stats for sh in self.shards]
        return dict(self._results), self.stats

    def _drain_outstanding_as_lost(self) -> None:
        """The fleet died with streams in flight and no restart scheduled:
        surface them as truncated results (prefix + whatever a live export
        can still recover as token lists -- no state survives)."""
        for i, sh in enumerate(self.shards):
            if not sh.alive:
                continue
            for ms in sh.engine.export_streams(device_alive=False):
                t = self._tracks.get(ms.request.rid)
                if t is not None:
                    t.prefix.extend(ms.generated)
        for rid, ms in self._orphans:
            t = self._tracks.get(rid)
            if t is not None and not ms.pending:
                t.prefix.extend(ms.generated)
        self._orphans.clear()
        self._queue.clear()
        for rid, t in list(self._tracks.items()):
            self._results[rid] = FleetStreamResult(
                rid=rid, tokens=list(t.prefix),
                prompt_len=int(t.request.prompt.size),
                arrival_step=t.arrival_step, admit_step=t.admit_step,
                first_token_step=t.first_token_step,
                finished_step=self._fleet_step, shard=t.shard,
                migrations=t.migrations, replays=t.replays,
                admit_attempts=max(t.attempts, 1), truncated=True)
            self.stats.lost += 1
            del self._tracks[rid]
