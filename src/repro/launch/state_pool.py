"""Paged host-side pool of quantized per-stream recurrent decode states.

The paper's deployment pitch makes preemption nearly free: an integer
recurrent layer's whole state is a handful of small integer vectors per
layer per stream (e.g. an LSTM's int8 hidden at its zero point + int16
cell, or a GRU's single int8 hidden) plus one int32 token counter -- a few
KB, not a transformer KV cache that grows with context.
Swapping a live stream out of its decode-batch slot is therefore one
row-slice + host copy, and swapping it back in is one row write; both are
**bit-exact** because the state is integer (no float re-rounding on the
round trip) and every decode-batch row is computed independently of its
neighbours.

:class:`StatePool` stores those per-stream states in fixed-size **pages**
(one page = ``page_size`` rows of every state leaf plus the ``len``
counters), allocated lazily and recycled through a free list, so a
long-lived serving process that oversubscribes its slots (more live streams
than decode-batch rows) neither fragments host memory nor grows it per
admission.  The pool is the mechanism behind the engine's scheduling
policies (``launch/scheduler.py``): a scheduler *preempts* a stream by
parking its state here and *resumes* it later into whatever slot is free,
and the stream's tokens stay bit-identical to decoding it alone no matter
how often it bounces.

The pool is cell-agnostic: it pages any ``{<leaf>: [rows...] | row, ...,
"len": counter}`` state dict whose arrays have a leading batch axis of 1
(the shape ``models.lstm_lm.slice_state`` produces) -- leaf names, leaf
count, dtypes, and whether a leaf is a per-layer list or a single array are
all taken from the first state parked.  LSTM (``h``/``c``), GRU (``h``
only), and any future ``QuantRecurrentCell`` page through it unchanged.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import numpy as np

__all__ = ["StatePool"]


def _as_row(x) -> np.ndarray:
    """Host copy of one state leaf, normalized to a leading batch-1 axis."""
    a = np.asarray(x)
    if a.ndim == 0:
        a = a[None]
    if a.shape[0] != 1:
        raise ValueError(
            f"pool rows must be batch-1 state slices, got leading dim "
            f"{a.shape[0]} (shape {a.shape})")
    return a


class _Page:
    """One page: ``page_size`` rows of every state leaf, preallocated.

    ``data[key]`` mirrors the state dict's shape: a list of per-layer
    arrays when the state holds a list, else a single array.
    """

    def __init__(self, template: Dict[str, Any], page_size: int):
        def alloc(r: np.ndarray) -> np.ndarray:
            return np.zeros((page_size,) + r.shape[1:], r.dtype)

        self.data: Dict[str, Any] = {
            k: [alloc(r) for r in v] if isinstance(v, list) else alloc(v)
            for k, v in template.items()
        }

    def write(self, row: int, state: Dict[str, Any]) -> None:
        for k, dst in self.data.items():
            if isinstance(dst, list):
                for d, src in zip(dst, state[k]):
                    d[row] = src[0]
            else:
                dst[row] = state[k][0]

    def read(self, row: int) -> Dict[str, Any]:
        return {
            k: ([a[row:row + 1].copy() for a in v] if isinstance(v, list)
                else v[row:row + 1].copy())
            for k, v in self.data.items()
        }


class StatePool:
    """Paged storage of per-stream decode states, keyed by stream id.

    ``put(key, state)`` parks a batch-1 state (host or device arrays; device
    arrays are copied to host) into a free page row, allocating a new page
    only when every existing row is taken.  ``take(key)`` returns the parked
    state (fresh host arrays, leading batch-1 axis -- ready for
    ``models.lstm_lm.write_quant_slot``) and recycles the row.  Misuse is a
    ``ValueError``, not silent corruption: parking a key twice (the stream
    is already swapped out), taking or freeing an absent key (double-resume
    / double-free), or a row whose leading axis is not 1.
    """

    def __init__(self, page_size: int = 8):
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.page_size = page_size
        self._pages: List[_Page] = []
        self._free: List[Tuple[int, int]] = []  # (page, row), LIFO reuse
        self._where: Dict[Any, Tuple[int, int]] = {}
        self._template: Optional[Dict[str, Any]] = None
        self.peak_live = 0  # high-water mark of parked streams

    # -- capacity introspection ---------------------------------------------

    def __len__(self) -> int:
        return len(self._where)

    def __contains__(self, key) -> bool:
        return key in self._where

    @property
    def n_pages(self) -> int:
        return len(self._pages)

    @property
    def capacity(self) -> int:
        return len(self._pages) * self.page_size

    @property
    def state_bytes_per_stream(self) -> int:
        """Host bytes one parked stream occupies (the paper's 'tiny state'
        claim, measurable: a few KB/stream vs a KV cache's MBs).  Summed
        generically over the state pytree, so it is correct for any cell
        (LSTM h+c, GRU h, ...)."""
        if self._template is None:
            return 0
        return int(sum(
            sum(a.nbytes for a in v) if isinstance(v, list) else v.nbytes
            for v in self._template.values()))

    def location(self, key) -> Tuple[int, int]:
        """(page, row) a key is parked at -- for tests pinning page reuse."""
        if key not in self._where:
            raise ValueError(f"stream {key!r} is not in the pool")
        return self._where[key]

    # -- park / resume ------------------------------------------------------

    def put(self, key, state: Dict[str, Any]) -> None:
        """Park a batch-1 state under ``key``.  O(state bytes) host copy."""
        if key in self._where:
            raise ValueError(
                f"stream {key!r} is already in the pool (double swap-out)")
        row_state = {
            k: ([_as_row(x) for x in v] if isinstance(v, list)
                else _as_row(v))
            for k, v in state.items()
        }
        if self._template is not None:
            if set(row_state) != set(self._template):
                raise ValueError(
                    f"state leaves {sorted(row_state)} do not match the "
                    f"pool's template {sorted(self._template)}")
        if self._template is None:
            self._template = row_state
        if not self._free:
            self._pages.append(_Page(self._template, self.page_size))
            pg = len(self._pages) - 1
            # push rows reversed so allocation order is row 0, 1, 2, ...
            self._free.extend((pg, r)
                              for r in reversed(range(self.page_size)))
        loc = self._free.pop()
        self._pages[loc[0]].write(loc[1], row_state)
        self._where[key] = loc
        self.peak_live = max(self.peak_live, len(self._where))

    def take(self, key) -> Dict[str, Any]:
        """Un-park ``key``'s state and recycle its row.

        Raises ``ValueError`` for an absent key -- a double resume (or a
        resume of a never-preempted stream) is a scheduler bug and must not
        fabricate a zero state.
        """
        if key not in self._where:
            raise ValueError(
                f"stream {key!r} is not in the pool (double resume?)")
        pg, row = self._where.pop(key)
        state = self._pages[pg].read(row)
        self._free.append((pg, row))
        return state

    def free(self, key) -> None:
        """Drop a parked state without reading it (stream cancelled)."""
        if key not in self._where:
            raise ValueError(
                f"stream {key!r} is not in the pool (double free?)")
        self._free.append(self._where.pop(key))
