"""Paged host-side pool of quantized per-stream LSTM decode states.

The paper's deployment pitch makes preemption nearly free: an integer
LSTM's whole recurrent state is two small integer vectors per layer per
stream (int8 hidden at its zero point, int16 cell) plus one int32 token
counter -- a few KB, not a transformer KV cache that grows with context.
Swapping a live stream out of its decode-batch slot is therefore one
row-slice + host copy, and swapping it back in is one row write; both are
**bit-exact** because the state is integer (no float re-rounding on the
round trip) and every decode-batch row is computed independently of its
neighbours.

:class:`StatePool` stores those per-stream states in fixed-size **pages**
(one page = ``page_size`` rows of every per-layer ``h``/``c`` array plus the
``len`` counters), allocated lazily and recycled through a free list, so a
long-lived serving process that oversubscribes its slots (more live streams
than decode-batch rows) neither fragments host memory nor grows it per
admission.  The pool is the mechanism behind the engine's scheduling
policies (``launch/scheduler.py``): a scheduler *preempts* a stream by
parking its state here and *resumes* it later into whatever slot is free,
and the stream's tokens stay bit-identical to decoding it alone no matter
how often it bounces.

The pool is deliberately model-agnostic at the dtype level: it pages any
``{"h": [rows...], "c": [rows...], "len": counter}`` state whose arrays
have a leading batch axis of 1 (the shape ``models.lstm_lm.slice_state``
produces), so a second recurrent family served through the engine reuses it
unchanged.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import numpy as np

__all__ = ["StatePool"]


def _as_row(x) -> np.ndarray:
    """Host copy of one state leaf, normalized to a leading batch-1 axis."""
    a = np.asarray(x)
    if a.ndim == 0:
        a = a[None]
    if a.shape[0] != 1:
        raise ValueError(
            f"pool rows must be batch-1 state slices, got leading dim "
            f"{a.shape[0]} (shape {a.shape})")
    return a


class _Page:
    """One page: ``page_size`` rows of every state leaf, preallocated."""

    def __init__(self, template: Dict[str, Any], page_size: int):
        self.h = [np.zeros((page_size,) + r.shape[1:], r.dtype)
                  for r in template["h"]]
        self.c = [np.zeros((page_size,) + r.shape[1:], r.dtype)
                  for r in template["c"]]
        self.len = np.zeros((page_size,), template["len"].dtype)

    def write(self, row: int, state: Dict[str, Any]) -> None:
        for dst, src in zip(self.h, state["h"]):
            dst[row] = src[0]
        for dst, src in zip(self.c, state["c"]):
            dst[row] = src[0]
        self.len[row] = state["len"][0]

    def read(self, row: int) -> Dict[str, Any]:
        return {
            "h": [a[row:row + 1].copy() for a in self.h],
            "c": [a[row:row + 1].copy() for a in self.c],
            "len": self.len[row:row + 1].copy(),
        }


class StatePool:
    """Paged storage of per-stream decode states, keyed by stream id.

    ``put(key, state)`` parks a batch-1 state (host or device arrays; device
    arrays are copied to host) into a free page row, allocating a new page
    only when every existing row is taken.  ``take(key)`` returns the parked
    state (fresh host arrays, leading batch-1 axis -- ready for
    ``models.lstm_lm.write_quant_slot``) and recycles the row.  Misuse is a
    ``ValueError``, not silent corruption: parking a key twice (the stream
    is already swapped out), taking or freeing an absent key (double-resume
    / double-free), or a row whose leading axis is not 1.
    """

    def __init__(self, page_size: int = 8):
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.page_size = page_size
        self._pages: List[_Page] = []
        self._free: List[Tuple[int, int]] = []  # (page, row), LIFO reuse
        self._where: Dict[Any, Tuple[int, int]] = {}
        self._template: Optional[Dict[str, Any]] = None
        self.peak_live = 0  # high-water mark of parked streams

    # -- capacity introspection ---------------------------------------------

    def __len__(self) -> int:
        return len(self._where)

    def __contains__(self, key) -> bool:
        return key in self._where

    @property
    def n_pages(self) -> int:
        return len(self._pages)

    @property
    def capacity(self) -> int:
        return len(self._pages) * self.page_size

    @property
    def state_bytes_per_stream(self) -> int:
        """Host bytes one parked stream occupies (the paper's 'tiny state'
        claim, measurable: a few KB/stream vs a KV cache's MBs)."""
        if self._template is None:
            return 0
        t = self._template
        return int(sum(a.nbytes for a in t["h"]) +
                   sum(a.nbytes for a in t["c"]) + t["len"].nbytes)

    def location(self, key) -> Tuple[int, int]:
        """(page, row) a key is parked at -- for tests pinning page reuse."""
        if key not in self._where:
            raise ValueError(f"stream {key!r} is not in the pool")
        return self._where[key]

    # -- park / resume ------------------------------------------------------

    def put(self, key, state: Dict[str, Any]) -> None:
        """Park a batch-1 state under ``key``.  O(state bytes) host copy."""
        if key in self._where:
            raise ValueError(
                f"stream {key!r} is already in the pool (double swap-out)")
        row_state = {
            "h": [_as_row(x) for x in state["h"]],
            "c": [_as_row(x) for x in state["c"]],
            "len": _as_row(state["len"]),
        }
        if self._template is None:
            self._template = row_state
        if not self._free:
            self._pages.append(_Page(self._template, self.page_size))
            pg = len(self._pages) - 1
            # push rows reversed so allocation order is row 0, 1, 2, ...
            self._free.extend((pg, r)
                              for r in reversed(range(self.page_size)))
        loc = self._free.pop()
        self._pages[loc[0]].write(loc[1], row_state)
        self._where[key] = loc
        self.peak_live = max(self.peak_live, len(self._where))

    def take(self, key) -> Dict[str, Any]:
        """Un-park ``key``'s state and recycle its row.

        Raises ``ValueError`` for an absent key -- a double resume (or a
        resume of a never-preempted stream) is a scheduler bug and must not
        fabricate a zero state.
        """
        if key not in self._where:
            raise ValueError(
                f"stream {key!r} is not in the pool (double resume?)")
        pg, row = self._where.pop(key)
        state = self._pages[pg].read(row)
        self._free.append((pg, row))
        return state

    def free(self, key) -> None:
        """Drop a parked state without reading it (stream cancelled)."""
        if key not in self._where:
            raise ValueError(
                f"stream {key!r} is not in the pool (double free?)")
        self._free.append(self._where.pop(key))
