"""Pluggable slot-scheduling policies for the continuous-batching engine.

The engine's executor (``launch/engine.py``) owns the jitted step programs
and the ``(S, ...)`` slot tensors; *which* streams occupy those S slots each
step is a :class:`Scheduler`'s decision.  Because a preempted integer-LSTM
stream's whole state is two small integer vectors per layer (parked
bit-exactly in ``launch/state_pool.StatePool``), policies may preempt and
resume streams freely -- every policy produces bit-identical per-stream
tokens; they differ only in *when* each stream's tokens come out (TTFT,
completion latency, fairness) and how much swap traffic they generate.

Contract: ``schedule`` sees three disjoint, deterministically-ordered lists
of :class:`StreamView`s and returns a :class:`Decision` naming at most
``n_slots`` streams to run this step.  Views in ``resident`` currently hold
a slot; ``pooled`` are live but parked; ``pending`` have arrived but never
started (starting one consumes ``start_budget`` -- the oversubscription
headroom ``max_live - live``).  The executor keeps re-elected residents in
their slots, parks residents left off the list, and fills freed slots with
the remaining elected streams in the order the policy listed them -- so a
policy's list order IS its slot-assignment preference.  Schedulers may keep
internal state (one instance serves one engine); they must be deterministic
for a given call sequence, which keeps every workload replayable.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

__all__ = [
    "StreamView", "Decision", "Scheduler", "FIFOScheduler",
    "FIFORejectScheduler", "PriorityScheduler",
    "ShortestRemainingFirstScheduler", "RoundRobinFairScheduler",
    "POLICIES", "get_scheduler",
]


@dataclasses.dataclass(frozen=True)
class StreamView:
    """What a policy may observe about one stream (host bookkeeping only --
    never tensors, so scheduling cannot perturb the integer math)."""

    rid: int
    priority: int  # larger = more urgent (Request.priority)
    arrival: float  # engine step the request became schedulable
    submit_idx: int  # submission order, the final deterministic tie-break
    prompt_len: int
    prompt_remaining: int  # prompt tokens not yet fed
    gen_remaining: int  # generation budget not yet produced
    resident: bool  # currently occupies a slot
    slot: Optional[int] = None  # its slot when resident
    resident_steps: int = 0  # consecutive steps of the current slot tenure

    @property
    def remaining(self) -> int:
        """Total tokens of work left (the SRF key)."""
        return self.prompt_remaining + self.gen_remaining

    def order_key(self):
        """The shared deterministic tie-break: earlier arrival, then
        submission order."""
        return (self.arrival, self.submit_idx)


@dataclasses.dataclass
class Decision:
    """``run``: rids to occupy slots this step (<= n_slots, policy-ordered).
    ``reject``: arrived-pending rids to refuse admission forever (admission
    control -- e.g. :class:`FIFORejectScheduler`'s bounded behavior)."""

    run: List[int]
    reject: List[int] = dataclasses.field(default_factory=list)


class Scheduler:
    """Interface: decide which streams hold slots for one engine step."""

    name: str = "base"

    def schedule(self, step_idx: int, resident: Sequence[StreamView],
                 pooled: Sequence[StreamView], pending: Sequence[StreamView],
                 n_slots: int, start_budget: int) -> Decision:
        raise NotImplementedError

    @staticmethod
    def _select(ranked: Sequence[StreamView], pending_rids, n_slots: int,
                start_budget: int) -> List[int]:
        """Shared greedy walk over ranked candidates: take the first
        ``n_slots`` runnable views, skipping pending ones beyond the
        oversubscription start budget (live streams -- resident or pooled
        -- already hold pool/slot capacity and always remain runnable)."""
        run: List[int] = []
        starts = 0
        for v in ranked:
            if len(run) == n_slots:
                break
            if v.rid in pending_rids:
                if starts >= start_budget:
                    continue
                starts += 1
            run.append(v.rid)
        return run


class FIFOScheduler(Scheduler):
    """The pre-refactor engine's exact behavior: residents are never
    preempted; free slots admit pooled streams (only present after a user
    ``evict(preserve=True)`` / ``resume``) then pending requests in arrival
    order.  With ``oversubscribe=1`` this reproduces the monolithic
    engine's step-by-step slot assignments bit- and step-exactly
    (``tests/test_scheduler.py`` locks that against a reference simulation
    of the old admission loop)."""

    name = "fifo"

    def schedule(self, step_idx, resident, pooled, pending, n_slots,
                 start_budget) -> Decision:
        run = [v.rid for v in resident]
        free = n_slots - len(run)
        for v in pooled[:max(free, 0)]:
            run.append(v.rid)
            free -= 1
        n_admit = max(min(free, start_budget), 0)
        run.extend(v.rid for v in pending[:n_admit])
        return Decision(run=run)


class FIFORejectScheduler(FIFOScheduler):
    """FIFO **without a waiting room**: an arrived request that cannot be
    placed into a free slot this very step is rejected outright.  The
    loss-of-goodput baseline ``benchmarks/preempt_resume.py`` measures
    oversubscribed scheduling against -- rejected work is gone forever,
    where a pooled engine would have parked it."""

    name = "fifo-reject"

    def schedule(self, step_idx, resident, pooled, pending, n_slots,
                 start_budget) -> Decision:
        d = super().schedule(step_idx, resident, pooled, pending, n_slots,
                             start_budget)
        placed = set(d.run)
        d.reject = [v.rid for v in pending if v.rid not in placed]
        return d


class PriorityScheduler(Scheduler):
    """Strict priority: the ``n_slots`` highest-priority live-or-arrived
    streams hold the slots; a newly-arrived high-priority request preempts
    the lowest-priority resident (its state parks in the pool, bit-exactly).
    Ties break by arrival then submission order, so equal-priority traffic
    degrades to FIFO."""

    name = "priority"

    def schedule(self, step_idx, resident, pooled, pending, n_slots,
                 start_budget) -> Decision:
        ranked = sorted(
            list(resident) + list(pooled) + list(pending),
            key=lambda v: (-v.priority,) + v.order_key())
        pending_rids = {v.rid for v in pending}
        return Decision(run=self._select(ranked, pending_rids, n_slots,
                                         start_budget))


class ShortestRemainingFirstScheduler(Scheduler):
    """Shortest-remaining-first: slots go to the streams with the least
    total work left (prompt remaining + generation budget remaining).
    Short jobs cut ahead of long residents, which park in the pool --
    minimizing mean completion time on mixed-length traffic at the price of
    swap traffic for the long tail.  A resident's remaining work only
    shrinks, so SRF never thrashes between equals (ties break by arrival /
    submission order, which is stable)."""

    name = "srf"

    def schedule(self, step_idx, resident, pooled, pending, n_slots,
                 start_budget) -> Decision:
        ranked = sorted(
            list(resident) + list(pooled) + list(pending),
            key=lambda v: (v.remaining,) + v.order_key())
        pending_rids = {v.rid for v in pending}
        return Decision(run=self._select(ranked, pending_rids, n_slots,
                                         start_budget))


class RoundRobinFairScheduler(Scheduler):
    """Time-sliced fairness: every live stream gets ``quantum`` consecutive
    slot-steps, then rotates to the back of the ring while waiters (pooled
    or pending) take its slot.  No stream starves regardless of length or
    priority -- the per-tenant-fairness building block.  The ring is
    internal scheduler state; order of first sight (resident slot order,
    then pool order, then arrival order) seeds it deterministically."""

    name = "rr"

    def __init__(self, quantum: int = 8):
        if quantum < 1:
            raise ValueError(f"quantum must be >= 1, got {quantum}")
        self.quantum = quantum
        self._ring: List[int] = []
        self._ran: Dict[int, int] = {}

    def schedule(self, step_idx, resident, pooled, pending, n_slots,
                 start_budget) -> Decision:
        views = {v.rid: v for v in
                 list(resident) + list(pooled) + list(pending)}
        # drop finished/evicted streams, enrol newly-seen ones at the tail
        self._ring = [r for r in self._ring if r in views]
        self._ran = {r: n for r, n in self._ran.items() if r in views}
        for v in list(resident) + list(pooled) + list(pending):
            if v.rid not in self._ran:
                self._ring.append(v.rid)
                self._ran[v.rid] = 0
        pending_rids = {p.rid for p in pending}
        run: List[int] = []
        starts = 0
        for rid in self._ring:
            if len(run) == n_slots:
                break
            if rid in pending_rids:
                if starts >= start_budget:
                    continue
                starts += 1
            run.append(rid)
        # account the slice; exhausted streams rotate to the tail when
        # someone is waiting (otherwise rotating is pointless churn)
        waiters = len(views) > len(run)
        for rid in run:
            self._ran[rid] += 1
        if waiters:
            expired = [r for r in run if self._ran[r] >= self.quantum]
            if expired:
                keep = [r for r in self._ring if r not in expired]
                self._ring = keep + expired
                for r in expired:
                    self._ran[r] = 0
        return Decision(run=run)


POLICIES = {
    "fifo": FIFOScheduler,
    "fifo-reject": FIFORejectScheduler,
    "priority": PriorityScheduler,
    "srf": ShortestRemainingFirstScheduler,
    "rr": RoundRobinFairScheduler,
}


def get_scheduler(policy, **kwargs) -> Scheduler:
    """Resolve a policy name (or pass through a Scheduler instance).

    Unknown names raise ``ValueError`` listing the registry -- scheduling is
    a correctness-adjacent knob and a typo must not silently serve FIFO.
    """
    if isinstance(policy, Scheduler):
        return policy
    if policy not in POLICIES:
        raise ValueError(
            f"unknown scheduling policy {policy!r}; "
            f"available: {sorted(POLICIES)}")
    return POLICIES[policy](**kwargs)
