"""Production mesh construction.

Single pod: (16, 16) = 256 chips, axes ("data", "model").
Multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model"); the pod
axis carries data parallelism across the DCN/ICI-pod boundary.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state); the dry-run entrypoint sets the host-device-count XLA flag
before any jax import.
"""
from __future__ import annotations

from typing import Optional

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)}; run "
            "under launch/dryrun.py which forces 512 host devices")
    # more devices than needed (e.g. 512 present, single-pod 256 wanted)
    from jax.sharding import Mesh

    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh over however many local devices exist (tests)."""
    import jax
    from jax.sharding import Mesh

    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        return None
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)
