import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import: jax locks the device
# count at first backend initialization (see MULTI-POD DRY-RUN contract).

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves the distribution config is coherent end-to-end --
sharding propagation, collective insertion, memory -- without TPU hardware,
and records the roofline terms for EXPERIMENTS.md.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b \
        --shape train_4k --mesh single [--quant int8] [--out out.json]
    PYTHONPATH=src python -m repro.launch.dryrun --all  # full sweep driver
"""
import argparse
import json
import sys
import time
import traceback


def _reduced_layer_cfgs(cfg):
    """Two reduced-depth configs for per-layer extrapolation, preserving the
    layer-type mix (dense prefix for kimi, rec/attn pattern unit for rg)."""
    import dataclasses

    if cfg.family == "hybrid":
        unit = len(cfg.block_pattern or ("rec", "rec", "attn"))
        return (dataclasses.replace(cfg, n_layers=unit),
                dataclasses.replace(cfg, n_layers=2 * unit))
    nd = min(cfg.n_dense_layers, 1)
    return (dataclasses.replace(cfg, n_layers=nd + 1, n_dense_layers=nd),
            dataclasses.replace(cfg, n_layers=nd + 3, n_dense_layers=nd))


def _linear_extrapolate(res_a: dict, res_b: dict, la: int, lb: int,
                        l_full: int) -> dict:
    """Per-layer linear extrapolation of additive cost fields (a=deeper)."""
    import copy

    out = copy.deepcopy(res_a)
    span = la - lb

    def extr(va, vb):
        per_layer = (va - vb) / span
        return va + per_layer * (l_full - la)

    pa, pb = res_a["per_device"], res_b["per_device"]
    for k in ("flops", "flops_corrected", "bytes_accessed", "bytes_corrected",
              "argument_bytes", "output_bytes", "temp_bytes"):
        out["per_device"][k] = extr(float(pa[k]), float(pb[k]))
    out["per_device"]["peak_hbm_gb"] = round(
        (out["per_device"]["argument_bytes"] + out["per_device"]["output_bytes"]
         + out["per_device"]["temp_bytes"]) / 1e9, 3)
    ca, cb = res_a["collectives"], res_b["collectives"]
    out["collectives"]["wire_bytes_per_dev"] = extr(
        ca["wire_bytes_per_dev"], cb["wire_bytes_per_dev"])
    out["collectives"]["pod_wire_bytes_per_dev"] = extr(
        ca["pod_wire_bytes_per_dev"], cb["pod_wire_bytes_per_dev"])
    out["collectives"]["by_kind_bytes"] = {
        k: extr(v, cb["by_kind_bytes"].get(k, 0.0))
        for k, v in ca["by_kind_bytes"].items()}
    out["collectives"]["by_kind_count"] = {
        k: int(round(extr(v, cb["by_kind_count"].get(k, 0))))
        for k, v in ca["by_kind_count"].items()}
    return out


def run_cell(arch: str, shape: str, mesh_kind: str, quant: str = "none",
             extra: dict | None = None, layers_mode: str = "auto",
             microbatches: int = 1) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.configs.base import SHAPES
    from repro.configs.registry import get_config
    from repro.launch import roofline as rl
    from repro.launch.mesh import make_production_mesh
    from repro.models import model_zoo
    from repro.optim.optimizers import OptConfig
    from repro.runtime import sharding as shlib
    from repro.runtime.train_loop import abstract_init, make_serve_fns, make_train_step

    import dataclasses

    cfg = get_config(arch)
    if layers_mode == "auto":
        # cost_analysis counts lax.scan bodies once -> unrolled HLO gives
        # layer-exact FLOPs/bytes/collectives.  Deep fwd+bwd graphs are too
        # slow to compile unrolled on this host, so trains/prefills of deep
        # nets use two shallow unrolled compiles + linear extrapolation, plus
        # a full-depth scan-mode compile as the "it compiles at scale" proof.
        cell0 = SHAPES[shape]
        deep = cfg.n_layers > 8 and cfg.family != "lstm"
        if cell0.kind in ("train", "prefill") and deep:
            cfg_b, cfg_a = _reduced_layer_cfgs(cfg)
            res_a = run_cell(arch, shape, mesh_kind, quant,
                             dict(extra or {}, n_layers=cfg_a.n_layers,
                                  n_dense_layers=cfg_a.n_dense_layers),
                             layers_mode="unroll", microbatches=microbatches)
            res_b = run_cell(arch, shape, mesh_kind, quant,
                             dict(extra or {}, n_layers=cfg_b.n_layers,
                                  n_dense_layers=cfg_b.n_dense_layers),
                             layers_mode="unroll", microbatches=microbatches)
            full = _linear_extrapolate(res_a, res_b, cfg_a.n_layers,
                                       cfg_b.n_layers, cfg.n_layers)
            # full-depth compile proof (scan mode, fast)
            check = run_cell(arch, shape, mesh_kind, quant, extra,
                             layers_mode="scan", microbatches=microbatches)
            from repro.launch import roofline as rl2
            coll = full["collectives"]

            class _C:
                wire_bytes = coll["wire_bytes_per_dev"]
                pod_wire_bytes = coll["pod_wire_bytes_per_dev"]

            full["roofline"] = rl2.roofline_terms(
                full["per_device"]["flops_corrected"],
                full["per_device"]["bytes_corrected"], _C,
                int8_compute=(quant == "int8"))
            full["n_params"] = check["n_params"]
            full["n_active_params"] = check["n_active_params"]
            full["model_flops_per_dev"] = check["model_flops_per_dev"]
            full["attn_flops_per_dev"] = check["attn_flops_per_dev"]
            full["useful_ratio"] = (
                (full["model_flops_per_dev"] + full["attn_flops_per_dev"])
                / full["per_device"]["flops_corrected"])
            full["method"] = (
                f"extrapolated({cfg_b.n_layers},{cfg_a.n_layers})"
                f"+scan_check(compile_s={check['compile_s']},"
                f"peak_scan_gb={check['per_device']['peak_hbm_gb']})")
            full["arch"] = arch
            full["compile_s"] = (res_a["compile_s"] + res_b["compile_s"]
                                 + check["compile_s"])
            return full
        layers_mode = "unroll"
    if layers_mode == "unroll":
        cfg = dataclasses.replace(cfg, scan_layers=False)
    elif layers_mode == "scan":
        cfg = dataclasses.replace(cfg, scan_layers=True)
    if extra:
        cfg = dataclasses.replace(cfg, **extra)
    cell = SHAPES[shape]
    multi_pod = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(jax.numpy.prod(jnp.array(list(mesh.shape.values()))))
    n_chips = 512 if multi_pod else 256

    bundle = model_zoo.build(cfg)
    if quant == "int8":
        from repro.models import quant_transformer
        bundle = quant_transformer.quantize_bundle(bundle)
    batch_specs = bundle.input_specs(cell)
    param_shapes, logical = abstract_init(bundle)
    n_params = sum(int(x.size) for x in jax.tree_util.tree_leaves(param_shapes))

    t0 = time.time()
    if cell.kind == "train":
        art = make_train_step(
            bundle, mesh, OptConfig(name=cfg.optimizer),
            microbatches=microbatches, batch_example=batch_specs)
        opt_shapes = jax.eval_shape(art.init_opt, param_shapes)
        with mesh:
            lowered = art.step_fn.lower(param_shapes, opt_shapes, batch_specs)
            compiled = lowered.compile()
        tokens = cell.global_batch * cell.seq_len
    elif cell.kind == "prefill":
        prefill_jit, _, _, param_sh = make_serve_fns(
            bundle, mesh, cell.global_batch, cell.seq_len,
            quantized_cache=(quant == "int8"))
        with mesh:
            lowered = prefill_jit.lower(param_shapes, batch_specs)
            compiled = lowered.compile()
        tokens = cell.global_batch * cell.seq_len
    else:  # decode
        _, decode_jit, state_sh, param_sh = make_serve_fns(
            bundle, mesh, cell.global_batch, cell.seq_len,
            quantized_cache=(quant == "int8"))
        state_shapes = jax.eval_shape(
            lambda: bundle.init_state(cell.global_batch, cell.seq_len,
                                      quantized=(quant == "int8")))
        tok = jax.ShapeDtypeStruct((cell.global_batch, 1), jnp.int32)
        with mesh:
            lowered = decode_jit.lower(param_shapes, tok, state_shapes)
            compiled = lowered.compile()
        tokens = cell.global_batch  # one new token per sequence
    compile_s = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    hlo = compiled.as_text()
    # inner scans (flash chunks / time recurrences) may carry collectives;
    # layer loop is unrolled so the hint only needs the largest inner trip
    inner_trip = max(cell.seq_len // 512, 1) if cell.kind != "decode" else 1
    coll = rl.parse_collectives(
        hlo, n_pods=2 if multi_pod else 1, devices_per_pod=256,
        region_trip_hint=inner_trip)

    hlo_flops = float(cost.get("flops", 0.0))
    hlo_bytes = float(cost.get("bytes accessed", 0.0))
    if microbatches > 1 and cell.kind == "train":
        # the microbatch loop is a scan (counted once); fwd/bwd dominates the
        # optimizer epilogue, so scale by the accumulation factor
        hlo_flops *= microbatches
        hlo_bytes *= microbatches
        coll = rl.parse_collectives(
            hlo, n_pods=2 if multi_pod else 1, devices_per_pod=256,
            region_trip_hint=inner_trip * microbatches)
    add_flops, add_bytes = rl.inner_scan_corrections(cfg, cell)
    corr_flops = hlo_flops + add_flops / n_chips
    corr_bytes = hlo_bytes + add_bytes / n_chips
    terms = rl.roofline_terms(
        corr_flops, corr_bytes, coll, int8_compute=(quant == "int8"))

    n_active = rl.active_params(cfg, n_params)
    mflops = rl.model_flops(n_params, n_active, tokens, cell.kind)
    attn_flops = rl.attention_flops(
        cfg, cell.seq_len, cell.global_batch, cell.kind, executed=False)
    mflops_per_dev = mflops / n_chips
    useful_per_dev = (mflops + attn_flops) / n_chips

    result = {
        "arch": arch,
        "shape": shape,
        "mesh": mesh_kind,
        "quant": quant,
        "kind": cell.kind,
        "method": layers_mode,
        "n_chips": n_chips,
        "n_params": n_params,
        "n_active_params": n_active,
        "compile_s": round(compile_s, 1),
        "per_device": {
            "flops": hlo_flops,
            "flops_corrected": corr_flops,
            "bytes_accessed": hlo_bytes,
            "bytes_corrected": corr_bytes,
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_hbm_gb": round(
                (mem.argument_size_in_bytes + mem.output_size_in_bytes
                 + mem.temp_size_in_bytes) / 1e9, 3),
        },
        "collectives": {
            "wire_bytes_per_dev": coll.wire_bytes,
            "pod_wire_bytes_per_dev": coll.pod_wire_bytes,
            "by_kind_bytes": coll.by_kind_bytes,
            "by_kind_count": coll.by_kind_count,
        },
        "roofline": terms,
        "model_flops_per_dev": mflops_per_dev,
        "attn_flops_per_dev": attn_flops / n_chips,
        "useful_ratio": (useful_per_dev / corr_flops) if corr_flops else 0.0,
    }
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--quant", default="none", choices=["none", "int8"])
    ap.add_argument("--out", default=None)
    ap.add_argument("--extra", default=None,
                    help="JSON dict of ArchConfig overrides (perf iterations)")
    ap.add_argument("--layers-mode", default="auto",
                    choices=["auto", "unroll", "scan"])
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    extra = json.loads(args.extra) if args.extra else None
    try:
        result = run_cell(args.arch, args.shape, args.mesh, args.quant, extra,
                          layers_mode=args.layers_mode,
                          microbatches=args.microbatches)
        status = 0
    except Exception as e:
        result = {
            "arch": args.arch, "shape": args.shape, "mesh": args.mesh,
            "quant": args.quant, "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-4000:],
        }
        status = 1
    out = json.dumps(result, indent=1)
    if args.out:
        with open(args.out, "w") as f:
            f.write(out)
    print(out[:2000] if status == 0 else out)
    sys.exit(status)


if __name__ == "__main__":
    main()
