"""Token drafters for in-engine speculative decoding.

Speculative decoding breaks the engine's 1-token-per-step barrier: a cheap
**drafter** proposes up to ``k`` candidate continuation tokens for a stream,
the engine feeds ``[last_token, d_1, .., d_k]`` through ONE masked chunked
verify step (``models.lstm_lm.quant_verify_step``), and the longest draft
prefix whose greedy argmax matches is accepted -- plus the model's own
next token after the accepted prefix, so every verify step emits between 1
and ``k + 1`` tokens while staying **bit-identical** to one-token greedy
decode (each emitted token IS the greedy argmax at its position; drafts only
decide how many positions one dispatch gets to confirm).

Draft quality therefore only affects *speed*, never output: a useless
drafter degrades to ~1 token/step, a perfect one reaches ``k + 1``.

The default :class:`NGramDrafter` is a per-stream suffix cache (prompt
lookup decoding): it matches the stream's most recent ``n``-gram against
earlier occurrences in that same stream's history and proposes the tokens
that followed last time.  Greedy integer LSTM decode frequently falls into
short cycles, and served text is self-repetitive, so this accepts well on
exactly the workloads where decode throughput matters -- with zero model
cost per draft.

:class:`Drafter` is the pluggable interface: anything with
``observe/draft/reset`` can slot in (e.g. a smaller integer LSTM stack
drafting with its own fused step -- see ROADMAP follow-ons).  One drafter
instance serves ONE stream; the engine creates a fresh instance per
stream start so no draft state ever leaks between co-tenant slots.  The
drafter belongs to the STREAM, not the slot: when the scheduler preempts a
stream to the state pool, its drafter travels with the stream's host
bookkeeping and resumes with its suffix history intact -- so speculation
quality (and the bit-exact output) survives any preemption schedule.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple


class Drafter:
    """Per-stream draft-token source (the pluggable speculation interface).

    Lifecycle inside the engine: ``reset()`` when the stream starts,
    ``observe`` for every token the stream's history grows by (the prompt
    at start, then each emitted token), ``draft(k)`` once per generation
    step.  Preemption does NOT reset a drafter -- the instance rides with
    its stream through the state pool and keeps drafting on resume.
    """

    def reset(self) -> None:
        """Forget all history (stream start)."""
        raise NotImplementedError

    def observe(self, tokens: Sequence[int]) -> None:
        """Append ``tokens`` to this stream's history."""
        raise NotImplementedError

    def draft(self, k: int) -> List[int]:
        """Propose up to ``k`` candidate next tokens (possibly none).

        Proposals are *guesses* -- the verify step keeps the output correct
        regardless -- but implementations should return an empty list rather
        than noise when they have no signal, so the engine can skip the
        wide verify dispatch entirely on that step.
        """
        raise NotImplementedError


class NGramDrafter(Drafter):
    """Suffix-match (prompt-lookup) drafter over one stream's own history.

    Keeps the stream's token history plus, for every n-gram of order
    ``1..max_n``, the positions right after its two most recent occurrences.
    ``draft(k)`` matches the longest current suffix (longest order first)
    against its previous occurrence and proposes the up-to-``k`` tokens that
    followed it.  Every proposed token is therefore a token this stream has
    already emitted/observed, and a fresh drafter (empty history) proposes
    nothing -- the two properties ``tests/test_spec_decode.py`` pins.

    O(max_n) per observed token, O(max_n + k) per draft.
    """

    def __init__(self, max_n: int = 3):
        if max_n < 1:
            raise ValueError(f"max_n must be >= 1, got {max_n}")
        self.max_n = max_n
        self.reset()

    def reset(self) -> None:
        self._history: List[int] = []
        # _after[n][gram] = (second-most-recent, most-recent) positions
        # IMMEDIATELY AFTER an occurrence of `gram`; the most recent entry
        # for the current suffix is the suffix itself, so draft() reads the
        # previous one.
        self._after: List[Dict[Tuple[int, ...], Tuple[int, int]]] = [
            {} for _ in range(self.max_n)
        ]

    @property
    def history(self) -> List[int]:
        return list(self._history)

    def observe(self, tokens: Sequence[int]) -> None:
        for t in tokens:
            self._history.append(int(t))
            end = len(self._history)
            for n in range(1, self.max_n + 1):
                if end < n:
                    break
                gram = tuple(self._history[end - n:end])
                idx = self._after[n - 1]
                prev = idx.get(gram)
                idx[gram] = (prev[1] if prev else -1, end)

    def draft(self, k: int) -> List[int]:
        h = self._history
        if k < 1 or not h:
            return []
        end = len(h)
        for n in range(min(self.max_n, end), 0, -1):
            gram = tuple(h[end - n:end])
            # the most-recent recorded position is always the current
            # suffix's own occurrence (observe indexes every suffix), so
            # the match to continue from is the one before it
            prev = self._after[n - 1].get(gram, (-1, -1))[0]
            if prev >= 0:
                return h[prev:prev + k]
        return []
