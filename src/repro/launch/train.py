"""Training launcher: data pipeline -> sharded train step -> checkpoints.

Runs on whatever devices exist (1 CPU device for local runs; the production
mesh when launched fleet-wide).  Demonstrates the full fault-tolerant loop:
periodic async checkpoints, watchdog-based straggler accounting, restart
recovery via ``--resume``.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --smoke \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--qat", action="store_true")
    ap.add_argument("--mesh", default="none", choices=["none", "test"])
    args = ap.parse_args()

    from repro.checkpoint.manager import CheckpointManager
    from repro.configs.registry import get_config
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.launch.mesh import make_test_mesh
    from repro.models import model_zoo
    from repro.optim.optimizers import OptConfig
    from repro.runtime.fault import StepWatchdog
    from repro.runtime.train_loop import make_train_step

    cfg = get_config(args.arch, smoke=args.smoke)
    bundle = model_zoo.build(cfg)
    mesh = make_test_mesh() if args.mesh == "test" else None

    dcfg = DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch,
        frontend_tokens=cfg.n_frontend_tokens if cfg.family in ("vlm", "encdec")
        else 0,
        d_model=cfg.d_model)
    data = SyntheticLM(dcfg)

    opt_cfg = OptConfig(name=cfg.optimizer, lr=args.lr,
                        warmup_steps=max(args.steps // 20, 5),
                        total_steps=args.steps)
    art = make_train_step(
        bundle, mesh, opt_cfg, microbatches=args.microbatches,
        grad_compress_int8=args.grad_compress, qat=args.qat,
        batch_example=None if mesh is None else jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
            data.batch_at(0)))

    params, _ = bundle.init(jax.random.PRNGKey(0))
    opt_state = art.init_opt(params)

    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start_step = 0
    if ckpt and args.resume and ckpt.latest_step() is not None:
        start_step = ckpt.latest_step()
        (params, opt_state), meta = ckpt.restore(
            start_step, (params, opt_state))
        print(f"resumed from step {start_step}")

    watchdog = StepWatchdog()
    losses = []
    for step, batch in data.iterate(start_step):
        if step >= args.steps:
            break
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        t0 = time.time()
        params, opt_state, metrics = art.step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        verdict = watchdog.observe(time.time() - t0)
        losses.append(loss)
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {loss:.4f} lr {float(metrics['lr']):.2e}"
                  f" gnorm {float(metrics['grad_norm']):.2f} [{verdict}]")
        if ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, (params, opt_state))
    if ckpt:
        ckpt.wait()
    print(f"final loss: {losses[-1]:.4f} (first: {losses[0]:.4f}); "
          f"stragglers: {watchdog.stragglers}/{watchdog.steps}")


if __name__ == "__main__":
    main()
