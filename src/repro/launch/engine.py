"""Slot-based continuous-batching engine for the integer-only LSTM LM.

The serving problem: requests with different prompt lengths and generation
budgets arrive as a queue, and naive serving decodes them one stream at a
time (one kernel dispatch per token per stream).  Because integer LSTM
decode state is just per-stream ``(h, c)`` vectors -- no paged KV cache, no
attention over a ragged history -- continuous batching is uniquely cheap
here: a fixed ``(B_slots, H)`` decode batch where

  * pending requests are **admitted** into free slots (the slot's int8
    hidden / int16 cell rows are reset to their initial values),
  * admitted streams are **prefilled by teacher-forcing** their prompt
    through the same fused decode step that drives generation (one token
    per step, so mixed prefill/decode shares a single jitted program with
    static shapes -- no per-prompt-length recompilation),
  * finished streams are **evicted mid-flight** and their slot is re-used
    by the next pending request on the following step,
  * ONE jitted fused decode step (PR 1's packed ``[i|f|z|o]`` executor, any
    ``backend=`` xla | pallas | interpret) advances all slots per iteration,
    with an **active-mask** freezing the state of empty slots.

Bit-exactness contract (what the test harness locks down): every row of the
fused integer step is computed independently of the other rows (the packed
matmuls are per-row, the cell fusion and integer LayerNorm reduce over the
hidden dim only), and integer arithmetic is deterministic.  Therefore the
token sequence a stream produces inside a busy engine batch is **bitwise
identical** to decoding that stream alone (``decode_single``), regardless of
slot index, co-tenants, or admission order.  ``tests/test_engine.py``
asserts this per stream, and the golden tests pin the absolute values.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lstm_lm


@dataclasses.dataclass
class Request:
    """One generation request: a prompt and a generation budget."""

    rid: int
    prompt: np.ndarray  # (P,) int32, P >= 1
    max_new_tokens: int  # >= 1

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        assert self.prompt.size >= 1, "empty prompt"
        assert self.max_new_tokens >= 1, "need a positive generation budget"


@dataclasses.dataclass
class StreamResult:
    """Finished stream: generated tokens + admission/finish bookkeeping.

    ``truncated`` marks a stream cut off by ``run(max_steps=...)`` before
    its generation budget was spent (tokens holds the partial output).
    """

    rid: int
    tokens: List[int]
    prompt_len: int
    admitted_step: int
    finished_step: int
    truncated: bool = False


@dataclasses.dataclass
class EngineStats:
    steps: int
    n_slots: int
    active_slot_steps: int  # sum over steps of #active slots
    max_active: int  # peak concurrent streams in one step
    generated_tokens: int
    prompt_tokens: int
    wall_s: float

    @property
    def occupancy(self) -> float:
        denom = self.steps * self.n_slots
        return self.active_slot_steps / denom if denom else 0.0

    @property
    def tokens_per_s(self) -> float:
        return self.generated_tokens / self.wall_s if self.wall_s else 0.0


@dataclasses.dataclass
class _Slot:
    """Host-side bookkeeping for one decode-batch row."""

    request: Optional[Request] = None
    fed: int = 0  # tokens consumed so far (prompt + fed-back generations)
    generated: List[int] = dataclasses.field(default_factory=list)
    admitted_step: int = 0

    @property
    def free(self) -> bool:
        return self.request is None

    def next_token(self) -> int:
        """The token this slot feeds on the upcoming step."""
        p = self.request.prompt
        if self.fed < p.size:
            return int(p[self.fed])  # teacher-forced prefill
        return self.generated[self.fed - p.size]  # fed-back generation


_ENGINE_FNS: Dict[Tuple[int, str], Tuple[Any, Any]] = {}
_FN_CACHE_MAX = 8  # each entry pins a model's arrays + compiled programs


def _cache_put(cache: Dict, key, value) -> None:
    """FIFO-bounded insert so long-lived processes that quantize many models
    don't pin every one of them (plus its executables) forever."""
    if len(cache) >= _FN_CACHE_MAX:
        cache.pop(next(iter(cache)))
    cache[key] = value


def _engine_step_fns(qlayers, cfg, backend: str, constrain=None):
    """Jitted (step, reset) pair for the engine loop.

    Cached per (qlayers identity, backend) when no sharding constrain is
    installed, so property tests and repeated engine instances over the
    same quantized model share compiled programs (the jit itself also
    specializes per slot count via input shapes).
    """
    key = (id(qlayers), backend)
    if constrain is None and key in _ENGINE_FNS:
        return _ENGINE_FNS[key]

    def step(params, tokens, state, active):
        """One engine iteration: all slots advance one token.

        tokens: (S,) int32; active: (S,) bool.  Returns the per-slot
        greedy next token (argmax over the last-position logits -- the
        row-wise computation is identical to a batch-1 decode, so the
        argmax is too) and the new state with inactive rows frozen.
        """
        logits, new_state = lstm_lm.quant_forward(
            params, qlayers, cfg, tokens[:, None], state, backend=backend)
        greedy = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        mask = active[:, None]
        out = {
            "h": [jnp.where(mask, n, o) for n, o in zip(new_state["h"],
                                                        state["h"])],
            "c": [jnp.where(mask, n, o) for n, o in zip(new_state["c"],
                                                        state["c"])],
            "len": state["len"] + active.astype(jnp.int32),
        }
        if constrain is not None:
            out["h"] = [constrain(h, ("batch", "mlp")) for h in out["h"]]
            out["c"] = [constrain(c, ("batch", "mlp")) for c in out["c"]]
        return greedy, out

    fns = (
        jax.jit(step),
        jax.jit(lambda state, slot: lstm_lm.reset_quant_slot(
            qlayers, state, slot)),
    )
    if constrain is None:
        _cache_put(_ENGINE_FNS, key, fns)
    return fns


class ContinuousBatchingEngine:
    """Drives a fixed-slot decode batch over a queue of requests.

    ``mesh``/``rules``: optional batch-axis sharding hook -- when given, the
    slot state is placed via ``runtime.sharding.engine_state_shardings`` so
    the slot dim spreads over the data-parallel mesh axes.
    """

    def __init__(self, params, qlayers, cfg, n_slots: int, *,
                 backend: str = "xla", mesh=None, rules=None):
        assert n_slots >= 1
        self.params = params
        self.qlayers = qlayers
        self.cfg = cfg
        self.n_slots = n_slots
        self.backend = backend
        self._slots = [_Slot() for _ in range(n_slots)]
        self._queue: List[Request] = []
        self._state = lstm_lm.init_quant_decode_state(
            qlayers, n_slots, per_slot_len=True)
        constrain = None
        if mesh is not None:
            from repro.runtime import sharding as shlib

            self._state = jax.device_put(
                self._state,
                shlib.engine_state_shardings(self._state, rules, mesh))
            constrain = shlib.make_constrain(rules, mesh)
        self._step, self._reset = _engine_step_fns(
            qlayers, cfg, backend, constrain)

    # -- queue management ---------------------------------------------------

    def submit(self, request: Request) -> None:
        # results are keyed by rid; a duplicate would silently shadow a
        # stream's output, so reject it at the door
        taken = {r.rid for r in self._queue}
        taken.update(s.request.rid for s in self._slots if not s.free)
        if request.rid in taken:
            raise ValueError(f"duplicate request id {request.rid}")
        self._queue.append(request)

    def submit_all(self, requests: Sequence[Request]) -> None:
        for r in requests:
            self.submit(r)

    @property
    def pending(self) -> int:
        return len(self._queue)

    @property
    def active(self) -> int:
        return sum(not s.free for s in self._slots)

    # -- the serving loop ---------------------------------------------------

    def _admit(self, step_idx: int) -> None:
        for i, slot in enumerate(self._slots):
            if not self._queue:
                break
            if not slot.free:
                continue
            req = self._queue.pop(0)
            self._slots[i] = _Slot(request=req, admitted_step=step_idx)
            self._state = self._reset(self._state, jnp.int32(i))

    def run(self, max_steps: Optional[int] = None
            ) -> Tuple[Dict[int, StreamResult], EngineStats]:
        """Serve until the queue and all slots drain.  Returns per-request
        results keyed by rid plus occupancy/throughput stats."""
        results: Dict[int, StreamResult] = {}
        step_idx = 0
        active_slot_steps = 0
        max_active = 0
        prompt_tokens = 0
        generated = 0
        t0 = time.perf_counter()
        while self._queue or any(not s.free for s in self._slots):
            if max_steps is not None and step_idx >= max_steps:
                break
            self._admit(step_idx)
            tokens = np.zeros((self.n_slots,), np.int32)
            active = np.zeros((self.n_slots,), bool)
            for i, slot in enumerate(self._slots):
                if slot.free:
                    continue
                active[i] = True
                tokens[i] = slot.next_token()
            active_slot_steps += int(active.sum())
            max_active = max(max_active, int(active.sum()))
            greedy, self._state = self._step(
                self.params, jnp.asarray(tokens), self._state,
                jnp.asarray(active))
            greedy = np.asarray(greedy)
            for i, slot in enumerate(self._slots):
                if slot.free:
                    continue
                req = slot.request
                in_prefill = slot.fed < req.prompt.size
                prompt_tokens += int(in_prefill)
                slot.fed += 1
                if slot.fed >= req.prompt.size:
                    # last prompt token consumed, or a fed-back generation:
                    # this step's logits carry the next generated token
                    slot.generated.append(int(greedy[i]))
                if len(slot.generated) >= req.max_new_tokens:
                    results[req.rid] = StreamResult(
                        rid=req.rid,
                        tokens=list(slot.generated),
                        prompt_len=int(req.prompt.size),
                        admitted_step=slot.admitted_step,
                        finished_step=step_idx,
                    )
                    generated += len(slot.generated)
                    self._slots[i] = _Slot()  # evict mid-flight
            step_idx += 1
        # hitting max_steps leaves streams in flight: return their partial
        # generations (marked truncated) instead of silently dropping them
        for i, slot in enumerate(self._slots):
            if slot.free:
                continue
            req = slot.request
            results[req.rid] = StreamResult(
                rid=req.rid,
                tokens=list(slot.generated),
                prompt_len=int(req.prompt.size),
                admitted_step=slot.admitted_step,
                finished_step=step_idx,
                truncated=True,
            )
            generated += len(slot.generated)
            self._slots[i] = _Slot()
        wall = time.perf_counter() - t0
        stats = EngineStats(
            steps=step_idx,
            n_slots=self.n_slots,
            active_slot_steps=active_slot_steps,
            max_active=max_active,
            generated_tokens=generated,
            prompt_tokens=prompt_tokens,
            wall_s=wall,
        )
        return results, stats


# ---------------------------------------------------------------------------
# Single-stream reference + request traces
# ---------------------------------------------------------------------------


_SINGLE_FNS: Dict[Tuple[int, str], Tuple[Any, Any]] = {}


def single_stream_fns(qlayers, cfg, backend: str = "xla"):
    """Jitted (prefill, decode) pair for batch-1 serving, cached per
    (qlayers identity, backend) so repeated ``decode_single`` calls reuse
    the compiled programs instead of re-tracing fresh closures."""
    key = (id(qlayers), backend)
    if key not in _SINGLE_FNS:
        prefill_fn = jax.jit(lambda p, t, s: lstm_lm.quant_prefill(
            p, qlayers, cfg, t, s, backend=backend))
        decode_fn = jax.jit(lambda p, t, s: lstm_lm.quant_decode_step(
            p, qlayers, cfg, t, s, backend=backend))
        _cache_put(_SINGLE_FNS, key, (prefill_fn, decode_fn))
    return _SINGLE_FNS[key]


def decode_single(params, qlayers, cfg, prompt, max_new_tokens: int, *,
                  backend: str = "xla",
                  prefill_fn=None, decode_fn=None) -> List[int]:
    """Decode ONE stream alone: scanned prefill + greedy loop.

    The bit-exactness oracle for the engine (and the naive serving baseline
    of ``benchmarks/engine_throughput.py``).  Compiled programs are shared
    across calls via ``single_stream_fns`` (prefill still specializes per
    distinct prompt length).
    """
    prompt = jnp.asarray(np.asarray(prompt, np.int32).reshape(1, -1))
    if prefill_fn is None or decode_fn is None:
        pf, df = single_stream_fns(qlayers, cfg, backend)
        prefill_fn = prefill_fn or pf
        decode_fn = decode_fn or df
    state = lstm_lm.init_quant_decode_state(qlayers, 1)
    logits, state = prefill_fn(params, prompt, state)
    out = [int(jnp.argmax(logits, -1)[0])]
    for _ in range(max_new_tokens - 1):
        tok = jnp.asarray([[out[-1]]], jnp.int32)
        logits, state = decode_fn(params, tok, state)
        out.append(int(jnp.argmax(logits, -1)[0]))
    return out


def synthetic_trace(n_requests: int, vocab_size: int, *, seed: int = 0,
                    prompt_lens: Sequence[int] = (4, 6, 8, 12),
                    gen_lens: Sequence[int] = (4, 8, 12)) -> List[Request]:
    """A mixed-length request workload with deterministic token content."""
    rng = np.random.default_rng(seed)
    out = []
    for rid in range(n_requests):
        p = int(rng.choice(list(prompt_lens)))
        g = int(rng.choice(list(gen_lens)))
        toks = rng.integers(0, vocab_size, size=(p,), dtype=np.int64)
        out.append(Request(rid=rid, prompt=toks.astype(np.int32),
                           max_new_tokens=g))
    return out


def load_trace(path: str, vocab_size: int, *, seed: int = 0) -> List[Request]:
    """Load a request trace: a JSON list of objects with either an explicit
    ``prompt`` token list or a ``prompt_len`` (tokens drawn from ``seed``),
    plus ``gen`` (generation budget) and optional ``id``.

        [{"prompt_len": 12, "gen": 8}, {"prompt": [3, 1, 4], "gen": 4}]
    """
    with open(path) as f:
        entries = json.load(f)
    rng = np.random.default_rng(seed)
    out = []
    for i, e in enumerate(entries):
        if "prompt" in e:
            toks = np.asarray(e["prompt"], np.int32)
        else:
            toks = rng.integers(
                0, vocab_size, size=(int(e["prompt_len"]),)).astype(np.int32)
        out.append(Request(rid=int(e.get("id", i)), prompt=toks,
                           max_new_tokens=int(e["gen"])))
    return out
