"""Continuous-batching executor for the integer-only LSTM LM.

Since PR 6 the serving stack is a three-layer split, cashing in the paper's
core deployment advantage -- an integer LSTM's whole recurrent state is two
small integer vectors per layer per stream, so parking and resuming a
stream is nearly free and bit-exact:

  * **scheduler** (``launch/scheduler.py``) -- a pluggable policy decides
    each step which streams occupy the S decode-batch slots: FIFO (the
    default, reproducing the pre-split engine's exact step-by-step slot
    assignments), strict priority, shortest-remaining-first, and
    round-robin-fair time slicing, plus a FIFO-with-rejection baseline for
    admission-control benchmarks.  Policies may **oversubscribe**: admit
    more live streams than slots and multiplex them by preemption.
  * **state pool** (``launch/state_pool.py``) -- preempted streams park
    their quantized per-cell state (plus ``len``) in host-side pages and resume
    later bit-exactly (integer state: the swap round trip re-rounds
    nothing).  The stream's drafter travels with its host bookkeeping, so
    speculation state survives preemption too.
  * **executor** (this module) -- owns ONLY the jitted step programs
    (one-token / chunked-prefill / chunk-advance / verify) and the
    ``(S, ...)`` slot tensors, and applies the scheduler's decision each
    iteration: park evicted residents, restore elected pool streams into
    freed slots, reset slots for fresh admissions, then dispatch one fused
    integer step over all S rows.

The executor's step programs are unchanged from PRs 2-5: pending requests
prefill by teacher-forcing through the same fused decode step that
generates (``chunk=K > 1`` feeds up to K prompt tokens per slot per step
through the masked ragged executor), finished streams are evicted
mid-flight, an active-mask freezes empty rows, and ``speculate=k > 0``
verifies per-slot drafter proposals in one masked ``(S, k+1)`` block with
in-graph longest-confirmed-prefix acceptance.

Bit-exactness contract (what the test harness locks down): every row of the
fused integer step is computed independently of the other rows, integer
arithmetic is deterministic, and the pool round trip copies integers
verbatim.  Therefore the token sequence a stream produces inside a busy
engine batch is **bitwise identical** to decoding that stream alone
(``decode_single``) -- regardless of slot index, co-tenants, admission
order, scheduling policy, preemption schedule, or oversubscription ratio.
``tests/test_engine.py`` and ``tests/test_scheduler.py`` assert this per
stream, and the golden tests pin the absolute values.
"""
from __future__ import annotations

import dataclasses
import json
import math
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.scheduler import (Decision, Scheduler, StreamView,
                                    get_scheduler)
from repro.launch.spec_decode import Drafter, NGramDrafter
from repro.launch.state_pool import StatePool
from repro.models import lstm_lm
from repro.runtime.fault import StepWatchdog


@dataclasses.dataclass
class Request:
    """One generation request: a prompt, a generation budget, and optional
    scheduling attributes.

    ``priority`` (larger = more urgent) only matters to priority-aware
    policies; ``arrival`` is the engine step at which the request becomes
    schedulable (0 = immediately), letting one trace schema express the
    open-loop bursty workloads the scheduling benchmarks replay.
    """

    rid: int
    prompt: np.ndarray  # (P,) int32, P >= 1
    max_new_tokens: int  # >= 1
    priority: int = 0
    arrival: float = 0.0

    def __post_init__(self):
        # plain raises, not assert: engine invariants must survive python -O
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size < 1:
            raise ValueError(f"request {self.rid}: empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError(
                f"request {self.rid}: max_new_tokens must be >= 1, "
                f"got {self.max_new_tokens}")
        self.priority = int(self.priority)
        self.arrival = float(self.arrival)
        if not math.isfinite(self.arrival) or self.arrival < 0:
            raise ValueError(
                f"request {self.rid}: arrival must be a finite step "
                f">= 0, got {self.arrival}")


@dataclasses.dataclass
class StreamResult:
    """Finished stream: generated tokens + admission/finish bookkeeping.

    ``truncated`` marks a stream cut off before its generation budget was
    spent -- by ``run(max_steps=...)``, by a user ``evict``, or (with the
    rejection policy) refused admission outright (``rejected=True``, no
    tokens).  ``state_preserved`` records whether the stream's decode state
    (and drafter) survived in the pool: a preserved stream can be
    ``resume``-d and continued bit-exactly; an unpreserved one is gone.
    ``preemptions`` counts how often the scheduler parked the stream
    mid-flight (0 under FIFO).

    Latency metrics (``None`` when the stream never emitted a token, i.e. it
    was truncated mid-prefill):

    * ``ttft_steps`` -- engine steps from first slot admission through the
      step that produced the first generated token, inclusive (so a
      1-prompt-token request has TTFT of 1 step).  Deterministic for a given
      workload/chunk/policy.
    * ``ttft_s``     -- wall-clock from admission to the first token.
    * ``tokens_per_s`` -- generated tokens over the stream's residency
      (admission wall-clock to finish wall-clock).

    Speculation metrics (both 0 when the engine ran with ``speculate=0`` or
    the stream never drafted): ``drafted_tokens`` counts draft candidates
    this stream's drafter proposed, ``accepted_draft_tokens`` how many of
    them verification confirmed (the stream additionally emits one
    model-corrected token per verify step, so its generated total can
    exceed its accepted drafts).
    """

    rid: int
    tokens: List[int]
    prompt_len: int
    admitted_step: int
    finished_step: int
    truncated: bool = False
    ttft_steps: Optional[int] = None
    ttft_s: Optional[float] = None
    tokens_per_s: Optional[float] = None
    drafted_tokens: int = 0
    accepted_draft_tokens: int = 0
    state_preserved: bool = False
    preemptions: int = 0
    rejected: bool = False

    @property
    def accept_rate(self) -> Optional[float]:
        """Fraction of this stream's drafts that verified (None if it
        never drafted)."""
        if not self.drafted_tokens:
            return None
        return self.accepted_draft_tokens / self.drafted_tokens


@dataclasses.dataclass
class EngineStats:
    steps: int
    n_slots: int
    active_slot_steps: int  # sum over steps of #active slots
    max_active: int  # peak concurrent streams in one step
    generated_tokens: int
    prompt_tokens: int
    wall_s: float
    chunk: int = 1  # prefill chunk size the engine ran with
    # request-level latency aggregates over streams that emitted >= 1 token
    mean_ttft_steps: float = 0.0
    mean_ttft_s: float = 0.0
    mean_stream_tokens_per_s: float = 0.0
    # speculative-decode accounting (all 0 when speculate=0)
    speculate: int = 0  # draft budget k the engine ran with
    spec_steps: int = 0  # engine steps that ran the verify program
    spec_slot_steps: int = 0  # (slot, step) pairs that speculated
    drafted_tokens: int = 0  # draft candidates proposed across all streams
    accepted_draft_tokens: int = 0  # drafts confirmed by verification
    # scheduling accounting (the scheduler/pool split, PR 6)
    policy: str = "fifo"  # scheduling policy the engine ran with
    oversubscribe: float = 1.0  # max_live / n_slots admission headroom
    preemptions: int = 0  # resident streams parked to the pool this run
    resumes: int = 0  # pool streams restored into slots this run
    rejected: int = 0  # requests refused admission (rejection policies)
    peak_live: int = 0  # peak live streams (resident + pooled) in one step
    pool_state_bytes: int = 0  # host bytes one parked stream occupies
    # watchdog verdicts for THIS run call (both 0 when no watchdog is wired):
    # dispatched steps whose wall time exceeded straggler_factor x EMA /
    # timeout_factor x EMA (runtime.fault.StepWatchdog)
    stragglers: int = 0
    hung: int = 0

    @property
    def occupancy(self) -> float:
        denom = self.steps * self.n_slots
        return self.active_slot_steps / denom if denom else 0.0

    @property
    def tokens_per_s(self) -> float:
        return self.generated_tokens / self.wall_s if self.wall_s else 0.0

    @property
    def accept_rate(self) -> float:
        """Fraction of proposed draft tokens that verification confirmed."""
        if not self.drafted_tokens:
            return 0.0
        return self.accepted_draft_tokens / self.drafted_tokens

    @property
    def accepted_tokens_per_spec_step(self) -> float:
        """Mean tokens a SPECULATING slot emits on a verify step: its
        accepted drafts plus the model-corrected token, i.e.
        ``1 + accepted_draft_tokens / spec_slot_steps``.  The multi-token
        decode win per speculation opportunity -- 1.0 means no draft was
        ever accepted (greedy pace), ``speculate + 1`` is the ceiling.
        Deliberately per slot-step, NOT per engine step: co-tenant slots
        emitting in the same step must not inflate it."""
        if not self.spec_slot_steps:
            return 0.0
        return 1.0 + self.accepted_draft_tokens / self.spec_slot_steps


@dataclasses.dataclass
class _Stream:
    """Host-side bookkeeping for one live stream.

    Unlike the pre-split engine's per-SLOT record, this travels with the
    STREAM: preemption moves the tensors to the pool but leaves this object
    (fed counter, generated tokens, drafter, latency stamps) intact, so a
    resumed stream continues exactly where it stopped -- including its
    drafter's history, which must never die with the slot.
    """

    request: Request
    fed: int = 0  # tokens consumed so far (prompt + fed-back generations)
    generated: List[int] = dataclasses.field(default_factory=list)
    admitted_step: int = 0  # first step the stream held a slot
    admit_wall: float = 0.0
    first_token_step: Optional[int] = None
    first_token_wall: Optional[float] = None
    # speculation: this stream's drafter (fresh per stream start -- draft
    # history must never leak across streams, but DOES survive preemption)
    drafter: Optional[Drafter] = None
    drafted: int = 0  # draft tokens proposed for this stream
    accepted_drafts: int = 0  # drafts confirmed by verification
    # scheduling: residency + preemption accounting
    slot: Optional[int] = None  # decode-batch row, None while pooled
    resident_steps: int = 0  # consecutive steps of the current slot tenure
    preemptions: int = 0

    def next_token(self) -> int:
        """The token this stream feeds on the upcoming step."""
        p = self.request.prompt
        if self.fed < p.size:
            return int(p[self.fed])  # teacher-forced prefill
        return self.generated[self.fed - p.size]  # fed-back generation


@dataclasses.dataclass
class MigratedStream:
    """One stream drained out of an engine for re-admission elsewhere
    (``launch/fleet.py`` shard-kill recovery).

    ``state_row`` is the host-side batch-1 state pytree when it survived --
    the stream was parked in the host pool, or the drain ran with the device
    still alive -- and the receiving engine adopts it through the same
    ``pool.take -> jitted slot write`` resume path user preemption uses, so
    continuation is bit-exact (integer state, nothing re-rounds).  ``None``
    means the device state died with the shard: the stream must be REPLAYED
    by teacher-forcing its prompt + already-generated prefix (bit-exact by
    determinism, at the cost of re-ingesting the prefix).  ``pending`` marks
    a request that never started (no state, no replay cost -- re-route it).
    """

    request: Request
    fed: int
    generated: List[int]
    state_row: Optional[Dict[str, Any]]
    drafter: Optional[Drafter]
    preemptions: int
    pending: bool = False


_ENGINE_FNS: Dict[Tuple[int, str], Tuple[Any, ...]] = {}
_FN_CACHE_MAX = 8  # each entry pins a model's arrays + compiled programs


def _cache_put(cache: Dict, key, value) -> None:
    """FIFO-bounded insert so long-lived processes that quantize many models
    don't pin every one of them (plus its executables) forever."""
    if len(cache) >= _FN_CACHE_MAX:
        cache.pop(next(iter(cache)))
    cache[key] = value


def _engine_step_fns(qlayers, cfg, backend: str, constrain=None):
    """Jitted (step, chunk_step, chunk_advance, verify, reset, write)
    programs for the engine loop.

    Cached per (qlayers identity, backend) when no sharding constrain is
    installed, so property tests and repeated engine instances over the
    same quantized model share compiled programs (the jit itself also
    specializes per slot count / chunk size via input shapes).
    """
    key = (id(qlayers), backend)
    if constrain is None and key in _ENGINE_FNS:
        return _ENGINE_FNS[key]

    def constrain_state(out):
        """Re-apply the batch-axis sharding constraint to a new state."""
        if constrain is None:
            return out
        out = dict(out)
        for k in out:
            if k != "len":
                out[k] = [constrain(leaf, ("batch", "mlp"))
                          for leaf in out[k]]
        return out

    def step(params, tokens, state, active):
        """One engine iteration: all slots advance one token.

        tokens: (S,) int32; active: (S,) bool.  Returns the per-slot
        greedy next token (argmax over the last-position logits -- the
        row-wise computation is identical to a batch-1 decode, so the
        argmax is too) and the new state with inactive rows frozen.
        """
        logits, new_state = lstm_lm.quant_forward(
            params, qlayers, cfg, tokens[:, None], state, backend=backend)
        greedy = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        mask = active[:, None]
        out = {
            k: [jnp.where(mask, n, o)
                for n, o in zip(new_state[k], state[k])]
            for k in state if k != "len"
        }
        out["len"] = state["len"] + active.astype(jnp.int32)
        return greedy, constrain_state(out)

    def chunk_step(params, tokens, state, valid):
        """One chunked-prefill iteration: slot i advances valid[i] tokens.

        tokens: (S, K) int32; valid: (S,) int32 in [0, K].  The ragged
        masked executor freezes each row's per-layer (h, c) and its ``len``
        counter beyond its valid length (valid == 0 rows are frozen
        entirely, subsuming the one-token step's active mask), so every
        row's state after the block is bitwise identical to feeding its
        valid prefix one token at a time.  Returns the greedy argmax over
        each row's LAST VALID position -- the only logits computed from
        live state.
        """
        logits, out = lstm_lm.quant_chunk_step(
            params, qlayers, cfg, tokens, state, valid, backend=backend)
        greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return greedy, constrain_state(out)

    def verify(params, tokens, state, valid, draft_len):
        """One speculative verify iteration over a ``(S, W)`` block.

        Row i's first ``valid[i] - draft_len[i]`` positions are committed
        tokens (prompt chunk, or the fed-back last generation), the next
        ``draft_len[i]`` are draft candidates.  Returns the per-position
        greedy argmax ``(S, W)``, the per-row accepted input count
        (committed tokens always consume; a draft consumes iff the argmax
        one position earlier equals it), and the state advanced to exactly
        each row's accepted length -- rejected positions are rolled back by
        construction (the advance is a masked chunk advance from the
        pre-step state, the same executor chunked prefill trusts).  Idle
        rows (``valid == 0``) stay frozen, subsuming the active mask.
        """
        pred, accepted, out = lstm_lm.quant_verify_step(
            params, qlayers, cfg, tokens, state, valid, draft_len,
            backend=backend)
        return pred, accepted, constrain_state(out)

    def chunk_advance(params, tokens, state, valid):
        """Chunked iteration where NO slot emits a token this step (every
        active row is mid-prompt with > K tokens still to feed): advance
        state only, no LM head, no greedy output -- so the engine loop can
        dispatch consecutive prefill chunks without a host sync."""
        out = lstm_lm.quant_chunk_advance(
            params, qlayers, cfg, tokens, state, valid, backend=backend)
        return constrain_state(out)

    def write(state, slot, row_state):
        """Resume: restore a pool row into decode-batch row ``slot``."""
        return constrain_state(
            lstm_lm.write_quant_slot(state, slot, row_state))

    fns = (
        jax.jit(step),
        jax.jit(chunk_step),
        jax.jit(chunk_advance),
        jax.jit(verify),
        jax.jit(lambda state, slot: lstm_lm.reset_quant_slot(
            qlayers, state, slot)),
        jax.jit(write),
    )
    if constrain is None:
        _cache_put(_ENGINE_FNS, key, fns)
    return fns


class ContinuousBatchingEngine:
    """Drives a fixed-slot decode batch over a queue of requests.

    ``policy``: scheduling policy name (``launch.scheduler.POLICIES``:
    ``fifo`` | ``priority`` | ``srf`` | ``rr`` | ``fifo-reject``) or a
    ``Scheduler`` instance.  The policy decides each step which streams
    occupy slots; everything else (state swaps, dispatch, bookkeeping) is
    the executor's job.  The default FIFO reproduces the pre-split engine's
    exact step-by-step slot assignments.

    ``oversubscribe``: admission headroom as a multiple of ``n_slots`` --
    up to ``ceil(oversubscribe * n_slots)`` streams may be live (holding a
    slot or parked in the state pool) at once.  With ``1.0`` (default) a
    stream only starts when a slot is free, like the pre-split engine;
    ratios > 1 let preempting policies time-multiplex more streams than
    slots, with every stream still bit-exact vs ``decode_single``.

    ``chunk``: prefill chunk size K.  With ``chunk > 1`` a second jitted
    program teacher-forces up to K prompt tokens per slot per engine step as
    an ``(S, K)`` block with per-slot valid lengths (slots mid-generation
    feed 1 token in the same step), cutting time-to-first-token for long
    prompts by ~K dispatches while staying bit-exact with ``chunk=1`` and
    with ``decode_single``.  Steps where no slot has >= 2 prompt tokens left
    fall back to the one-token program, so pure generation never pays the
    K-wide block.

    ``speculate``: draft budget k for speculative decoding.  With ``k > 0``
    each generating stream's drafter (``drafter_factory``, default
    ``NGramDrafter``: a suffix cache over that stream's own tokens) proposes
    up to k continuation tokens per step, and steps where at least one slot
    drafts run the jitted masked-chunk **verify** program over a
    ``(S, k+1)`` block: per-position argmax, longest-confirmed-prefix
    acceptance, and per-row state rollback to the accepted length, emitting
    1..k+1 tokens per slot per step.  Output tokens are bit-identical to
    ``speculate=0`` (and to ``decode_single``) by construction; the drafter
    belongs to the STREAM, so it survives preemption and resumes with its
    history intact.

    ``mesh``/``rules``: optional batch-axis sharding hook -- when given, the
    slot state is placed via ``runtime.sharding.engine_state_shardings``,
    per-step token/valid blocks via ``engine_block_sharding``, and pool
    swap-in rows via ``pool_row_shardings``, so the slot dim spreads
    consistently over the data-parallel mesh axes with no resharding on the
    hot loop.

    ``watchdog``: optional ``runtime.fault.StepWatchdog`` -- every dispatched
    engine step's wall time is ``observe``-d and the resulting straggler /
    hung verdict counts surface in ``EngineStats`` (per ``run`` call).  The
    fleet router (``launch/fleet.py``) treats a hung verdict as a fault-plane
    event.  ``step_hook``: optional callable invoked with the engine step
    index at the top of every dispatched step, INSIDE the watchdog's timed
    window -- the fault-injection seam (a hook that sleeps simulates a hung
    device; the watchdog must flag it).
    """

    def __init__(self, params, qlayers, cfg, n_slots: int, *,
                 backend: str = "xla", chunk: int = 1, speculate: int = 0,
                 drafter_factory=None, policy: Union[str, Scheduler] = "fifo",
                 oversubscribe: float = 1.0, pool_page_size: int = 8,
                 mesh=None, rules=None,
                 watchdog: Optional[StepWatchdog] = None, step_hook=None):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        if chunk < 1:
            raise ValueError(f"chunk must be >= 1, got {chunk}")
        if speculate < 0:
            raise ValueError(f"speculate must be >= 0, got {speculate}")
        if not (isinstance(oversubscribe, (int, float))
                and math.isfinite(oversubscribe)) or oversubscribe < 1.0:
            raise ValueError(
                f"oversubscribe must be a finite ratio >= 1, "
                f"got {oversubscribe}")
        self.params = params
        self.qlayers = qlayers
        self.cfg = cfg
        self.n_slots = n_slots
        self.backend = backend
        self.chunk = chunk
        self.speculate = speculate
        self.oversubscribe = float(oversubscribe)
        self.max_live = max(n_slots, int(math.ceil(n_slots * oversubscribe)))
        self.scheduler = get_scheduler(policy)
        self.pool = StatePool(page_size=pool_page_size)
        self._drafter_factory = (
            drafter_factory if drafter_factory is not None
            else NGramDrafter)
        self.watchdog = watchdog
        self._step_hook = step_hook
        # stream bookkeeping: pending queue (submission order), live streams
        # keyed by rid, slot -> rid map, pool parking order, parked (user-
        # evicted, resumable) streams
        self._queue: List[Request] = []
        self._submit_idx: Dict[int, int] = {}
        self._n_submitted = 0
        self._streams: Dict[int, _Stream] = {}
        self._slot_rid: List[Optional[int]] = [None] * n_slots
        self._pool_order: List[int] = []
        self._parked: Dict[int, _Stream] = {}
        self._step = 0  # global engine step, persistent across run() calls
        # (step, event, rid, slot) trail: admissions, preemptions, resumes,
        # rejections -- what the FIFO-equivalence regression test replays
        self.schedule_log: List[Tuple[int, str, int, int]] = []
        self._state = lstm_lm.init_quant_decode_state(
            qlayers, n_slots, per_slot_len=True)
        constrain = None
        self._put = lambda x: x
        self._put_row = lambda tree: tree
        if mesh is not None:
            from repro.runtime import sharding as shlib

            self._state = jax.device_put(
                self._state,
                shlib.engine_state_shardings(self._state, rules, mesh))
            constrain = shlib.make_constrain(rules, mesh)
            # only two input shapes ever occur ((S,) and (S, K)): resolve
            # each sharding once, not twice per step on the serving hot loop
            shard_cache: Dict[Tuple[int, ...], Any] = {}

            def _put(x):
                s = shard_cache.get(x.shape)
                if s is None:
                    s = shard_cache[x.shape] = shlib.engine_block_sharding(
                        x.shape, rules, mesh)
                return jax.device_put(x, s)

            self._put = _put
            row_sharding_cache: List[Any] = []

            def _put_row(tree):
                if not row_sharding_cache:
                    row_sharding_cache.append(
                        shlib.pool_row_shardings(tree, rules, mesh))
                return jax.device_put(tree, row_sharding_cache[0])

            self._put_row = _put_row
        (self._step_fn, self._chunk_step, self._chunk_advance, self._verify,
         self._reset, self._write) = _engine_step_fns(
             qlayers, cfg, backend, constrain)

    # -- queue management ---------------------------------------------------

    def submit(self, request: Request) -> None:
        # results are keyed by rid; a duplicate would silently shadow a
        # stream's output, so reject it at the door
        taken = {r.rid for r in self._queue}
        taken.update(self._streams)
        taken.update(self._parked)
        if request.rid in taken:
            raise ValueError(f"duplicate request id {request.rid}")
        self._queue.append(request)
        self._submit_idx[request.rid] = self._n_submitted
        self._n_submitted += 1

    def submit_all(self, requests: Sequence[Request]) -> None:
        for r in requests:
            self.submit(r)

    @property
    def pending(self) -> int:
        return len(self._queue)

    @property
    def active(self) -> int:
        """Streams currently holding a decode-batch slot."""
        return sum(rid is not None for rid in self._slot_rid)

    @property
    def live(self) -> int:
        """Streams holding a slot OR parked in the pool (excludes
        user-evicted parked streams, which left the live set)."""
        return len(self._streams)

    # -- scheduling: views, decision application ----------------------------

    def _view(self, stream: _Stream) -> StreamView:
        req = stream.request
        return StreamView(
            rid=req.rid,
            priority=req.priority,
            arrival=req.arrival,
            submit_idx=self._submit_idx[req.rid],
            prompt_len=int(req.prompt.size),
            prompt_remaining=max(int(req.prompt.size) - stream.fed, 0),
            gen_remaining=req.max_new_tokens - len(stream.generated),
            resident=stream.slot is not None,
            slot=stream.slot,
            resident_steps=stream.resident_steps,
        )

    def _pending_view(self, req: Request) -> StreamView:
        return StreamView(
            rid=req.rid,
            priority=req.priority,
            arrival=req.arrival,
            submit_idx=self._submit_idx[req.rid],
            prompt_len=int(req.prompt.size),
            prompt_remaining=int(req.prompt.size),
            gen_remaining=req.max_new_tokens,
            resident=False,
        )

    def _preempt(self, rid: int) -> None:
        """Park a resident stream's state in the pool, freeing its slot."""
        s = self._streams[rid]
        row = lstm_lm.slice_state(self._state, s.slot)
        self.pool.put(rid, jax.device_get(row))
        self._slot_rid[s.slot] = None
        s.slot = None
        s.resident_steps = 0
        s.preemptions += 1
        self._pool_order.append(rid)
        self._n_preempts += 1
        self.schedule_log.append((self._step, "preempt", rid, -1))

    def _resume(self, rid: int, slot: int) -> None:
        """Restore a pooled stream's state into a free slot, bit-exactly."""
        s = self._streams[rid]
        row = self._put_row(self.pool.take(rid))
        self._state = self._write(self._state, jnp.int32(slot), row)
        self._pool_order.remove(rid)
        self._slot_rid[slot] = rid
        s.slot = slot
        s.resident_steps = 0
        self._n_resumes += 1
        self.schedule_log.append((self._step, "resume", rid, slot))

    def _start(self, req: Request, slot: int, now: float) -> None:
        """First admission of a pending request: reset the slot, create the
        stream record (and its drafter, which lives with the STREAM)."""
        self._queue.remove(req)
        drafter = None
        if self.speculate:
            # a FRESH drafter per stream, reset() besides (the documented
            # lifecycle -- so pooled/shared factory instances also start
            # blank): another stream's history must never leak in
            drafter = self._drafter_factory()
            drafter.reset()
            drafter.observe(req.prompt.tolist())
        self._streams[req.rid] = _Stream(
            request=req, admitted_step=self._step, admit_wall=now,
            drafter=drafter, slot=slot)
        self._slot_rid[slot] = req.rid
        self._state = self._reset(self._state, jnp.int32(slot))
        self.schedule_log.append((self._step, "admit", req.rid, slot))

    def _reject(self, req: Request, now: float,
                results: Dict[int, StreamResult]) -> None:
        self._queue.remove(req)
        results[req.rid] = StreamResult(
            rid=req.rid, tokens=[], prompt_len=int(req.prompt.size),
            admitted_step=-1, finished_step=self._step, truncated=True,
            rejected=True)
        self._n_rejects += 1
        self.schedule_log.append((self._step, "reject", req.rid, -1))

    def _apply_schedule(self, now: float,
                        results: Dict[int, StreamResult]) -> None:
        """Ask the policy for this step's slot occupancy and apply it:
        preempt, resume, admit, reject.  Malformed decisions raise -- a
        scheduler bug must never silently corrupt slot bookkeeping."""
        resident = [self._view(self._streams[rid])
                    for rid in self._slot_rid if rid is not None]
        pooled = [self._view(self._streams[rid])
                  for rid in self._pool_order]
        arrived = [r for r in self._queue if r.arrival <= self._step]
        pending = [self._pending_view(r) for r in arrived]
        start_budget = max(self.max_live - len(self._streams), 0)
        decision = self.scheduler.schedule(
            self._step, resident, pooled, pending, self.n_slots,
            start_budget)
        run = list(decision.run)
        pending_rids = {v.rid for v in pending}
        known = ({v.rid for v in resident} | {v.rid for v in pooled}
                 | pending_rids)
        name = self.scheduler.name
        if len(run) > self.n_slots or len(set(run)) != len(run):
            raise RuntimeError(
                f"scheduler {name!r} returned an invalid run list "
                f"(> n_slots or duplicates): {run}")
        if not set(run) <= known:
            raise RuntimeError(
                f"scheduler {name!r} scheduled unknown streams: "
                f"{sorted(set(run) - known)}")
        if sum(rid in pending_rids for rid in run) > start_budget:
            raise RuntimeError(
                f"scheduler {name!r} started more streams than the "
                f"oversubscription budget {start_budget} allows: {run}")
        bad_reject = [rid for rid in decision.reject
                      if rid not in pending_rids or rid in set(run)]
        if bad_reject:
            raise RuntimeError(
                f"scheduler {name!r} rejected non-pending or scheduled "
                f"streams: {bad_reject}")
        by_rid = {r.rid: r for r in arrived}
        for rid in decision.reject:
            self._reject(by_rid[rid], now, results)
        run_set = set(run)
        # 1) park residents the policy un-elected
        for rid in list(self._slot_rid):
            if rid is not None and rid not in run_set:
                self._preempt(rid)
        # 2) fill free slots (increasing index) with the remaining elected
        #    streams, in the order the policy listed them
        newcomers = [rid for rid in run
                     if rid in pending_rids
                     or self._streams[rid].slot is None]
        free_slots = [i for i, rid in enumerate(self._slot_rid)
                      if rid is None]
        for slot, rid in zip(free_slots, newcomers):
            if rid in self._streams:
                self._resume(rid, slot)
            else:
                self._start(by_rid[rid], slot, now)
        for rid in run_set:
            self._streams[rid].resident_steps += 1

    # -- user-initiated eviction / resume -----------------------------------

    def evict(self, rid: int, *, preserve: bool = True) -> StreamResult:
        """Evict a stream mid-flight (between ``run`` calls).

        With ``preserve=True`` (default) the stream's decode state is
        parked in the pool and its host bookkeeping -- including its
        drafter -- is retained, so ``resume(rid)`` can continue it later
        **bit-exactly**; the returned result records
        ``state_preserved=True``.  With ``preserve=False`` the state is
        discarded (the pre-split engine's only behavior), recorded as
        ``state_preserved=False``.  A still-pending request is simply
        removed from the queue (it never had state).
        """
        now = time.perf_counter()
        for r in self._queue:
            if r.rid == rid:
                self._queue.remove(r)
                return StreamResult(
                    rid=rid, tokens=[], prompt_len=int(r.prompt.size),
                    admitted_step=-1, finished_step=max(self._step - 1, 0),
                    truncated=True, state_preserved=False)
        s = self._streams.get(rid)
        if s is None:
            raise ValueError(
                f"stream {rid} is not live (finished, parked, or unknown)")
        if preserve:
            if s.slot is not None:
                row = lstm_lm.slice_state(self._state, s.slot)
                self.pool.put(rid, jax.device_get(row))
                s.preemptions += 1
        elif s.slot is None:
            self.pool.free(rid)  # pooled state dies with the eviction
        if s.slot is not None:
            self._slot_rid[s.slot] = None
            s.slot = None
        if rid in self._pool_order:
            self._pool_order.remove(rid)
        del self._streams[rid]
        res = self._result(s, max(self._step - 1, 0), now, truncated=True)
        res.state_preserved = preserve
        if preserve:
            self._parked[rid] = s
        return res

    def resume(self, rid: int) -> None:
        """Return a ``evict(preserve=True)``-parked stream to the live set;
        the scheduler will slot it back in on the next ``run`` step and it
        continues bit-exactly (state from the pool, drafter intact)."""
        s = self._parked.pop(rid, None)
        if s is None:
            raise ValueError(
                f"stream {rid} is not parked (evict(preserve=True) it "
                f"first); double resume?")
        self._streams[rid] = s
        self._pool_order.append(rid)

    # -- fleet migration: drain this engine / adopt another's streams -------

    def export_streams(self, *, device_alive: bool = True
                       ) -> List[MigratedStream]:
        """Drain every queued and live stream for re-admission elsewhere,
        leaving this engine empty (the fleet router calls this when a shard
        dies or is being retired).

        ``device_alive=True`` models a graceful drain (watchdog-flagged
        shard, planned retirement): resident streams' slot rows are sliced
        to host first, so EVERY stream migrates with its state.  With
        ``device_alive=False`` (hard kill: the accelerator died) resident
        streams lose their device state (``state_row=None`` -> replay);
        pooled streams still migrate -- their pages are host memory and
        survive the device.  User-parked streams (``evict(preserve=True)``)
        are NOT exported: the caller holds their handle and decides.
        """
        out: List[MigratedStream] = []
        for req in self._queue:
            out.append(MigratedStream(
                request=req, fed=0, generated=[], state_row=None,
                drafter=None, preemptions=0, pending=True))
        self._queue.clear()
        for rid, s in list(self._streams.items()):
            if s.slot is not None:
                row = (jax.device_get(lstm_lm.slice_state(self._state,
                                                          s.slot))
                       if device_alive else None)
                self._slot_rid[s.slot] = None
                s.slot = None
            else:
                row = self.pool.take(rid)
            out.append(MigratedStream(
                request=s.request, fed=s.fed, generated=list(s.generated),
                state_row=row, drafter=s.drafter,
                preemptions=s.preemptions))
        self._streams.clear()
        self._pool_order.clear()
        return out

    def adopt_stream(self, request: Request, *, state_row, fed: int,
                     generated: Sequence[int] = (), drafter=None,
                     preemptions: int = 0) -> None:
        """Admit a mid-flight stream WITH its integer state (fleet migration
        after a shard death or drain).

        The state row enters the pool and the scheduler restores it into a
        free slot through the same ``pool.take -> jitted slot write`` path
        preemption uses, so the stream continues bit-exactly as if it had
        never moved -- the recovery primitive only a
        constant-few-hundred-bytes integer state makes affordable.  Streams
        whose state died with their device are NOT adopted: replay them by
        folding the generated prefix into a fresh request's prompt
        (teacher-forcing reproduces the state bit-exactly).
        """
        taken = {r.rid for r in self._queue}
        taken.update(self._streams)
        taken.update(self._parked)
        if request.rid in taken:
            raise ValueError(f"duplicate request id {request.rid}")
        if state_row is None:
            raise ValueError(
                f"stream {request.rid}: adopt_stream needs a state row; "
                f"replay state-less streams via submit() with the generated "
                f"prefix folded into the prompt")
        gen = list(generated)
        if len(gen) >= request.max_new_tokens:
            raise ValueError(
                f"stream {request.rid}: already generated {len(gen)} of "
                f"{request.max_new_tokens} tokens -- nothing to adopt")
        if not 0 <= fed <= int(request.prompt.size) + max(len(gen) - 1, 0):
            raise ValueError(
                f"stream {request.rid}: fed={fed} inconsistent with "
                f"prompt_len={int(request.prompt.size)} + "
                f"{len(gen)} generated")
        if self.speculate and drafter is None:
            # a migrating stream entering a speculating engine without its
            # drafter rebuilds one from its full observed history
            drafter = self._drafter_factory()
            drafter.reset()
            drafter.observe(request.prompt.tolist() + gen)
        s = _Stream(
            request=request, fed=fed, generated=gen,
            admitted_step=self._step, admit_wall=time.perf_counter(),
            drafter=drafter, preemptions=preemptions)
        self._streams[request.rid] = s
        self._submit_idx[request.rid] = self._n_submitted
        self._n_submitted += 1
        self.pool.put(request.rid, state_row)
        self._pool_order.append(request.rid)
        self.schedule_log.append((self._step, "adopt", request.rid, -1))

    def live_progress(self) -> Dict[int, int]:
        """{rid: generated-token count} for every live stream -- the fleet
        router's cheap per-step poll for first-token (TTFT) stamping."""
        return {rid: len(s.generated) for rid, s in self._streams.items()}

    # -- the serving loop ---------------------------------------------------

    def _result(self, stream: _Stream, finished_step: int, now: float,
                truncated: bool) -> StreamResult:
        req = stream.request
        ttft_steps = ttft_s = tps = None
        if stream.generated and stream.first_token_step is not None:
            ttft_steps = stream.first_token_step - stream.admitted_step + 1
            ttft_s = stream.first_token_wall - stream.admit_wall
            span = now - stream.admit_wall
            tps = len(stream.generated) / span if span > 0 else float("inf")
        return StreamResult(
            rid=req.rid,
            tokens=list(stream.generated),
            prompt_len=int(req.prompt.size),
            admitted_step=stream.admitted_step,
            finished_step=finished_step,
            truncated=truncated,
            ttft_steps=ttft_steps,
            ttft_s=ttft_s,
            tokens_per_s=tps,
            drafted_tokens=stream.drafted,
            accepted_draft_tokens=stream.accepted_drafts,
            preemptions=stream.preemptions,
        )

    def run(self, max_steps: Optional[int] = None, *,
            keep_live: bool = False
            ) -> Tuple[Dict[int, StreamResult], EngineStats]:
        """Serve until the queue and all live streams drain.  Returns
        per-request results keyed by rid plus occupancy/throughput/latency/
        scheduling stats.

        ``max_steps`` bounds THIS call's engine steps.  By default streams
        still in flight at the bound are returned as truncated results and
        their state is discarded (``state_preserved=False``), like the
        pre-split engine; with ``keep_live=True`` they stay live instead
        (slots, pool entries, drafters intact) so a later ``run`` call
        continues them bit-exactly -- the stepwise-driving mode the
        scheduling benchmarks use.
        """
        results: Dict[int, StreamResult] = {}
        ran = 0
        active_slot_steps = 0
        max_active = 0
        prompt_tokens = 0
        generated = 0
        spec_steps = 0
        spec_slot_steps = 0
        peak_live = len(self._streams)
        self._n_preempts = 0
        self._n_resumes = 0
        self._n_rejects = 0
        wd = self.watchdog
        wd_before = (wd.stragglers, wd.hung) if wd is not None else (0, 0)
        t0 = time.perf_counter()
        while self._queue or self._streams:
            if max_steps is not None and ran >= max_steps:
                break
            self._apply_schedule(time.perf_counter(), results)
            peak_live = max(peak_live, len(self._streams))
            if not any(rid is not None for rid in self._slot_rid):
                # nothing runnable (all arrivals in the future): the step
                # passes idle -- no dispatch, no active accounting (and no
                # watchdog observation -- an idle step's wall time says
                # nothing about device health)
                self._step += 1
                ran += 1
                continue
            step_t0 = time.perf_counter()
            if self._step_hook is not None:
                # fault-injection seam: runs INSIDE the watchdog's timed
                # window, so an injected sleep reads as a hung device
                self._step_hook(self._step)
            # speculative drafts: ask each generating stream's drafter for
            # up to k candidates, capped so even a fully-accepted block
            # lands exactly on the stream's remaining budget (a stream one
            # token from done never drafts -- its drafts could never be
            # emitted)
            drafts: Dict[int, List[int]] = {}
            if self.speculate:
                for i, rid in enumerate(self._slot_rid):
                    if rid is None:
                        continue
                    s = self._streams[rid]
                    if s.fed < s.request.prompt.size:
                        continue
                    room = s.request.max_new_tokens - len(s.generated)
                    if room >= 2:
                        k = min(self.speculate, room - 1)
                        # clamp: a custom Drafter returning more than asked
                        # must not overflow the block or the stream budget
                        d = list(s.drafter.draft(k))[:k]
                        if d:
                            drafts[i] = d
            # pick this step's program: the (S, k+1) verify block when any
            # slot drafted; else chunked prefill when some slot still has
            # >= 2 prompt tokens to teacher-force; else the one-token step
            # -- so speculate=0 engines run exactly the pre-speculation
            # program sequence, and undraftable workloads never pay the
            # wide block
            slot_streams: List[Optional[_Stream]] = [
                self._streams[rid] if rid is not None else None
                for rid in self._slot_rid]
            chunk_pending = self.chunk > 1 and any(
                s is not None and s.request.prompt.size - s.fed >= 2
                for s in slot_streams)
            if drafts:
                # a mixed step (drafting slots + mid-prefill co-tenants)
                # widens to whichever program is larger: the verify step
                # handles arbitrary per-row valid/draft_len, so chunked
                # prefill must not be capped at k+1 when chunk > k+1
                width = max(self.speculate + 1,
                            self.chunk if chunk_pending else 1)
            elif chunk_pending:
                width = self.chunk
            else:
                width = 1
            tokens = np.zeros((self.n_slots, width), np.int32)
            valid = np.zeros((self.n_slots,), np.int32)
            draft_len = np.zeros((self.n_slots,), np.int32)
            fed_before = [s.fed if s is not None else 0
                          for s in slot_streams]
            for i, s in enumerate(slot_streams):
                if s is None:
                    continue
                rem = s.request.prompt.size - s.fed
                if rem >= 1:  # teacher-forced prefill: up to `width` tokens
                    n = min(width, rem)
                    tokens[i, :n] = s.request.prompt[s.fed:s.fed + n]
                else:  # mid-generation: feed back latest token (+ drafts)
                    d = drafts.get(i, ())
                    n = 1 + len(d)
                    tokens[i, 0] = s.next_token()
                    tokens[i, 1:n] = d
                    draft_len[i] = len(d)
                valid[i] = n
            n_active = int((valid > 0).sum())
            active_slot_steps += n_active
            max_active = max(max_active, n_active)
            # dispatch ONE jitted program; afterwards ``consumed[i]`` is the
            # inputs row i advanced by and ``preds[i, p]`` the greedy token
            # following input position p (for every consumed position on
            # verify steps; only at a row's single emitting position on the
            # one-token / chunked paths, which emit at most one token)
            if drafts:
                pred, accepted, self._state = self._verify(
                    self.params, self._put(jnp.asarray(tokens)),
                    self._state, self._put(jnp.asarray(valid)),
                    self._put(jnp.asarray(draft_len)))
                preds = np.asarray(pred)
                consumed = np.asarray(accepted)
                spec_steps += 1
            elif width == 1:
                greedy, self._state = self._step_fn(
                    self.params, self._put(jnp.asarray(tokens[:, 0])),
                    self._state, self._put(jnp.asarray(valid > 0)))
                preds = np.asarray(greedy)[:, None]
                consumed = valid
            else:
                # a slot emits a token this step iff it consumes its last
                # prompt token (0 < remaining <= chunk) or is generating
                # (remaining == 0).  When nothing emits, the logits would
                # never be read: run the head-free advance program and skip
                # the host sync so consecutive prefill chunks pipeline.
                emits = any(
                    s is not None and
                    s.request.prompt.size - s.fed <= width
                    for s in slot_streams)
                consumed = valid
                if emits:
                    greedy, self._state = self._chunk_step(
                        self.params, self._put(jnp.asarray(tokens)),
                        self._state, self._put(jnp.asarray(valid)))
                    # the chunked head reads each row's LAST VALID position,
                    # the only one the emission rule below can select
                    greedy = np.asarray(greedy)
                    preds = np.zeros((self.n_slots, width), np.int32)
                    for i in range(self.n_slots):
                        if valid[i]:
                            preds[i, valid[i] - 1] = greedy[i]
                else:
                    preds = None  # never read: no row emits this step
                    self._state = self._chunk_advance(
                        self.params, self._put(jnp.asarray(tokens)),
                        self._state, self._put(jnp.asarray(valid)))
            now = time.perf_counter()
            for i, s in enumerate(slot_streams):
                if s is None:
                    continue
                req = s.request
                n = int(consumed[i])
                fb = fed_before[i]
                # prompt tokens consumed this step (0 when mid-generation)
                prompt_tokens += min(n, max(int(req.prompt.size) - fb, 0))
                s.fed += n
                if draft_len[i]:
                    # accepted drafts = consumed inputs minus the committed
                    # fed-back token (draft capping keeps emissions within
                    # budget, so no accepted token is ever discarded); the
                    # engine-wide totals are summed from StreamResults at
                    # stats build -- every slot ends up in results
                    s.drafted += int(draft_len[i])
                    s.accepted_drafts += n - 1
                    spec_slot_steps += 1
                for p in range(n):
                    # consuming input position p yields a generated token
                    # iff p is the row's last prompt token or later
                    if fb + p + 1 < req.prompt.size:
                        continue
                    s.generated.append(int(preds[i, p]))
                    if s.drafter is not None:
                        s.drafter.observe([s.generated[-1]])
                    if len(s.generated) == 1:
                        s.first_token_step = self._step
                        s.first_token_wall = now
                if len(s.generated) >= req.max_new_tokens:
                    results[req.rid] = self._result(
                        s, self._step, now, truncated=False)
                    generated += len(s.generated)
                    self._slot_rid[i] = None  # evict mid-flight
                    del self._streams[req.rid]
            if wd is not None:
                wd.observe(time.perf_counter() - step_t0)
            self._step += 1
            ran += 1
        # hitting max_steps leaves streams in flight: by default return
        # their partial generations (marked truncated, state discarded)
        # instead of silently dropping them -- the step that actually ran
        # last is self._step - 1 (already advanced past it), matching
        # mid-flight eviction's stamps.  keep_live=True keeps them live
        # (slots + pool + drafters intact) for a later run() call.
        if not keep_live:
            now = time.perf_counter()
            for rid, s in list(self._streams.items()):
                results[rid] = self._result(
                    s, max(self._step - 1, 0), now, truncated=True)
                generated += len(s.generated)
                if s.slot is not None:
                    self._slot_rid[s.slot] = None
                else:
                    self.pool.free(rid)
                del self._streams[rid]
            self._pool_order.clear()
        wall = time.perf_counter() - t0
        ttfts = [r for r in results.values() if r.ttft_steps is not None]
        stats = EngineStats(
            steps=ran,
            n_slots=self.n_slots,
            active_slot_steps=active_slot_steps,
            max_active=max_active,
            generated_tokens=generated,
            prompt_tokens=prompt_tokens,
            wall_s=wall,
            chunk=self.chunk,
            speculate=self.speculate,
            spec_steps=spec_steps,
            spec_slot_steps=spec_slot_steps,
            drafted_tokens=sum(
                r.drafted_tokens for r in results.values()),
            accepted_draft_tokens=sum(
                r.accepted_draft_tokens for r in results.values()),
            mean_ttft_steps=(sum(r.ttft_steps for r in ttfts) / len(ttfts)
                             if ttfts else 0.0),
            mean_ttft_s=(sum(r.ttft_s for r in ttfts) / len(ttfts)
                         if ttfts else 0.0),
            mean_stream_tokens_per_s=(
                sum(r.tokens_per_s for r in ttfts) / len(ttfts)
                if ttfts else 0.0),
            policy=self.scheduler.name,
            oversubscribe=self.oversubscribe,
            preemptions=self._n_preempts,
            resumes=self._n_resumes,
            rejected=self._n_rejects,
            peak_live=peak_live,
            pool_state_bytes=self.pool.state_bytes_per_stream,
            stragglers=(wd.stragglers - wd_before[0]
                        if wd is not None else 0),
            hung=wd.hung - wd_before[1] if wd is not None else 0,
        )
        return results, stats


# ---------------------------------------------------------------------------
# Single-stream reference + request traces
# ---------------------------------------------------------------------------


_SINGLE_FNS: Dict[Tuple[int, str], Tuple[Any, Any]] = {}


def single_stream_fns(qlayers, cfg, backend: str = "xla"):
    """Jitted (prefill, decode) pair for batch-1 serving, cached per
    (qlayers identity, backend) so repeated ``decode_single`` calls reuse
    the compiled programs instead of re-tracing fresh closures."""
    key = (id(qlayers), backend)
    if key not in _SINGLE_FNS:
        prefill_fn = jax.jit(lambda p, t, s: lstm_lm.quant_prefill(
            p, qlayers, cfg, t, s, backend=backend))
        decode_fn = jax.jit(lambda p, t, s: lstm_lm.quant_decode_step(
            p, qlayers, cfg, t, s, backend=backend))
        _cache_put(_SINGLE_FNS, key, (prefill_fn, decode_fn))
    return _SINGLE_FNS[key]


def decode_single(params, qlayers, cfg, prompt, max_new_tokens: int, *,
                  backend: str = "xla",
                  prefill_fn=None, decode_fn=None) -> List[int]:
    """Decode ONE stream alone: scanned prefill + greedy loop.

    The bit-exactness oracle for the engine (and the naive serving baseline
    of ``benchmarks/engine_throughput.py``).  Compiled programs are shared
    across calls via ``single_stream_fns`` (prefill still specializes per
    distinct prompt length).
    """
    prompt = jnp.asarray(np.asarray(prompt, np.int32).reshape(1, -1))
    if prefill_fn is None or decode_fn is None:
        pf, df = single_stream_fns(qlayers, cfg, backend)
        prefill_fn = prefill_fn or pf
        decode_fn = decode_fn or df
    state = lstm_lm.init_quant_decode_state(qlayers, 1)
    logits, state = prefill_fn(params, prompt, state)
    out = [int(jnp.argmax(logits, -1)[0])]
    for _ in range(max_new_tokens - 1):
        tok = jnp.asarray([[out[-1]]], jnp.int32)
        logits, state = decode_fn(params, tok, state)
        out.append(int(jnp.argmax(logits, -1)[0]))
    return out


def synthetic_trace(n_requests: int, vocab_size: int, *, seed: int = 0,
                    prompt_lens: Sequence[int] = (4, 6, 8, 12),
                    gen_lens: Sequence[int] = (4, 8, 12),
                    priority_levels: Sequence[int] = (0,),
                    arrival_span: int = 0) -> List[Request]:
    """A mixed-length request workload with deterministic token content.

    ``priority_levels`` draws each request's scheduling priority uniformly
    from the given set; ``arrival_span > 0`` scatters arrivals uniformly
    over engine steps ``[0, arrival_span]`` (0 keeps the closed-loop
    everything-arrives-at-once trace).  Both default to the pre-scheduling
    schema so existing workloads replay unchanged.
    """
    if arrival_span < 0:
        raise ValueError(f"arrival_span must be >= 0, got {arrival_span}")
    if not priority_levels:
        raise ValueError("priority_levels must be non-empty")
    rng = np.random.default_rng(seed)
    out = []
    for rid in range(n_requests):
        p = int(rng.choice(list(prompt_lens)))
        g = int(rng.choice(list(gen_lens)))
        toks = rng.integers(0, vocab_size, size=(p,), dtype=np.int64)
        prio = int(rng.choice(list(priority_levels)))
        arrival = float(rng.integers(0, arrival_span + 1)) \
            if arrival_span else 0.0
        out.append(Request(rid=rid, prompt=toks.astype(np.int32),
                           max_new_tokens=g, priority=prio,
                           arrival=arrival))
    return out


def load_trace(path: str, vocab_size: int, *, seed: int = 0) -> List[Request]:
    """Load a request trace: a JSON list of objects with either an explicit
    ``prompt`` token list or a ``prompt_len`` (tokens drawn from ``seed``),
    plus ``gen`` (generation budget), optional ``id``, and the optional
    scheduling fields ``priority`` (int, larger = more urgent) and
    ``arrival`` (engine step >= 0 the request becomes schedulable).

        [{"prompt_len": 12, "gen": 8, "priority": 1, "arrival": 16},
         {"prompt": [3, 1, 4], "gen": 4}]

    One schema serves the engine CLI, the policy benchmarks, and the future
    open-loop load generator.  Malformed entries (missing keys, empty
    prompts, non-positive lengths or budgets, non-numeric priority,
    negative arrival) raise ``ValueError`` naming the offending entry
    instead of failing deep inside the engine.
    """
    with open(path) as f:
        entries = json.load(f)
    if not isinstance(entries, list):
        raise ValueError(
            f"trace {path}: expected a JSON list of request objects, "
            f"got {type(entries).__name__}")
    rng = np.random.default_rng(seed)
    out = []
    for i, e in enumerate(entries):
        if not isinstance(e, dict):
            raise ValueError(
                f"trace {path} entry {i}: expected an object, "
                f"got {type(e).__name__}")
        if "gen" not in e:
            raise ValueError(f"trace {path} entry {i}: missing 'gen'")
        gen = int(e["gen"])
        if gen < 1:
            raise ValueError(
                f"trace {path} entry {i}: 'gen' must be >= 1, got {gen}")
        if "prompt" in e:
            toks = np.asarray(e["prompt"], np.int32).reshape(-1)
            if toks.size < 1:
                raise ValueError(
                    f"trace {path} entry {i}: 'prompt' is empty")
        elif "prompt_len" in e:
            plen = int(e["prompt_len"])
            if plen < 1:
                raise ValueError(
                    f"trace {path} entry {i}: 'prompt_len' must be >= 1, "
                    f"got {plen}")
            toks = rng.integers(0, vocab_size, size=(plen,)).astype(np.int32)
        else:
            raise ValueError(
                f"trace {path} entry {i}: needs 'prompt' or 'prompt_len'")
        priority = e.get("priority", 0)
        if isinstance(priority, bool) or not isinstance(priority, int):
            raise ValueError(
                f"trace {path} entry {i}: 'priority' must be an int, "
                f"got {priority!r}")
        arrival = e.get("arrival", 0)
        if isinstance(arrival, bool) or \
                not isinstance(arrival, (int, float)) or arrival < 0:
            raise ValueError(
                f"trace {path} entry {i}: 'arrival' must be a number >= 0, "
                f"got {arrival!r}")
        out.append(Request(rid=int(e.get("id", i)), prompt=toks,
                           max_new_tokens=gen, priority=priority,
                           arrival=float(arrival)))
    return out
