"""Slot-based continuous-batching engine for the integer-only LSTM LM.

The serving problem: requests with different prompt lengths and generation
budgets arrive as a queue, and naive serving decodes them one stream at a
time (one kernel dispatch per token per stream).  Because integer LSTM
decode state is just per-stream ``(h, c)`` vectors -- no paged KV cache, no
attention over a ragged history -- continuous batching is uniquely cheap
here: a fixed ``(B_slots, H)`` decode batch where

  * pending requests are **admitted** into free slots (the slot's int8
    hidden / int16 cell rows are reset to their initial values),
  * admitted streams are **prefilled by teacher-forcing** their prompt
    through the same fused decode step that drives generation (one token
    per step, so mixed prefill/decode shares a single jitted program with
    static shapes -- no per-prompt-length recompilation); with
    ``chunk=K > 1`` a second jitted **chunked-prefill** program feeds each
    slot up to K prompt tokens per step as an ``(S, K)`` block with per-slot
    valid lengths (the masked ragged executor freezes each row's state past
    its valid prefix), cutting time-to-first-token for long prompts ~K-fold
    while staying bit-exact; since PR 4 the block's input GEMM is hoisted
    out of the recurrent scan (one time-batched ``(S*K, d_in)`` packed
    matmul per layer), so wider chunks also raise arithmetic intensity
    instead of just amortizing dispatches,
  * finished streams are **evicted mid-flight** and their slot is re-used
    by the next pending request on the following step,
  * ONE jitted fused decode step (PR 1's packed ``[i|f|z|o]`` executor, any
    ``backend=`` xla | pallas | interpret) advances all slots per iteration,
    with an **active-mask** freezing the state of empty slots,
  * with ``speculate=k > 0``, generation itself goes multi-token: a cheap
    per-slot drafter (``launch/spec_decode.py``, default: an n-gram suffix
    cache over the stream's own tokens) proposes up to k continuation
    tokens, and a third jitted program -- the **masked-chunk verify step**
    (``lstm_lm.quant_verify_step``) -- feeds each speculating slot
    ``[last_token, d_1..d_k]`` as one ``(S, k+1)`` block, computes every
    position's greedy argmax, accepts the longest draft prefix the argmax
    confirms, and rolls each row's ``(h, c)`` state back to exactly its
    accepted length (a masked chunk advance from the pre-step state).  A
    verify step emits 1..k+1 tokens per slot, every one bit-identical to
    1-token greedy decode by construction: drafts only decide how many
    greedy tokens one dispatch gets to confirm, never their values.

Bit-exactness contract (what the test harness locks down): every row of the
fused integer step is computed independently of the other rows (the packed
matmuls are per-row, the cell fusion and integer LayerNorm reduce over the
hidden dim only), and integer arithmetic is deterministic.  Therefore the
token sequence a stream produces inside a busy engine batch is **bitwise
identical** to decoding that stream alone (``decode_single``), regardless of
slot index, co-tenants, or admission order.  ``tests/test_engine.py``
asserts this per stream, and the golden tests pin the absolute values.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.spec_decode import Drafter, NGramDrafter
from repro.models import lstm_lm


@dataclasses.dataclass
class Request:
    """One generation request: a prompt and a generation budget."""

    rid: int
    prompt: np.ndarray  # (P,) int32, P >= 1
    max_new_tokens: int  # >= 1

    def __post_init__(self):
        # plain raises, not assert: engine invariants must survive python -O
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size < 1:
            raise ValueError(f"request {self.rid}: empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError(
                f"request {self.rid}: max_new_tokens must be >= 1, "
                f"got {self.max_new_tokens}")


@dataclasses.dataclass
class StreamResult:
    """Finished stream: generated tokens + admission/finish bookkeeping.

    ``truncated`` marks a stream cut off by ``run(max_steps=...)`` before
    its generation budget was spent (tokens holds the partial output).

    Latency metrics (``None`` when the stream never emitted a token, i.e. it
    was truncated mid-prefill):

    * ``ttft_steps`` -- engine steps from admission through the step that
      produced the first generated token, inclusive (so a 1-prompt-token
      request has TTFT of 1 step).  Deterministic for a given workload/chunk.
    * ``ttft_s``     -- wall-clock from admission to the first token.
    * ``tokens_per_s`` -- generated tokens over the stream's residency
      (admission wall-clock to finish wall-clock).

    Speculation metrics (both 0 when the engine ran with ``speculate=0`` or
    the stream never drafted): ``drafted_tokens`` counts draft candidates
    this stream's drafter proposed, ``accepted_draft_tokens`` how many of
    them verification confirmed (the stream additionally emits one
    model-corrected token per verify step, so its generated total can
    exceed its accepted drafts).
    """

    rid: int
    tokens: List[int]
    prompt_len: int
    admitted_step: int
    finished_step: int
    truncated: bool = False
    ttft_steps: Optional[int] = None
    ttft_s: Optional[float] = None
    tokens_per_s: Optional[float] = None
    drafted_tokens: int = 0
    accepted_draft_tokens: int = 0

    @property
    def accept_rate(self) -> Optional[float]:
        """Fraction of this stream's drafts that verified (None if it
        never drafted)."""
        if not self.drafted_tokens:
            return None
        return self.accepted_draft_tokens / self.drafted_tokens


@dataclasses.dataclass
class EngineStats:
    steps: int
    n_slots: int
    active_slot_steps: int  # sum over steps of #active slots
    max_active: int  # peak concurrent streams in one step
    generated_tokens: int
    prompt_tokens: int
    wall_s: float
    chunk: int = 1  # prefill chunk size the engine ran with
    # request-level latency aggregates over streams that emitted >= 1 token
    mean_ttft_steps: float = 0.0
    mean_ttft_s: float = 0.0
    mean_stream_tokens_per_s: float = 0.0
    # speculative-decode accounting (all 0 when speculate=0)
    speculate: int = 0  # draft budget k the engine ran with
    spec_steps: int = 0  # engine steps that ran the verify program
    spec_slot_steps: int = 0  # (slot, step) pairs that speculated
    drafted_tokens: int = 0  # draft candidates proposed across all streams
    accepted_draft_tokens: int = 0  # drafts confirmed by verification

    @property
    def occupancy(self) -> float:
        denom = self.steps * self.n_slots
        return self.active_slot_steps / denom if denom else 0.0

    @property
    def tokens_per_s(self) -> float:
        return self.generated_tokens / self.wall_s if self.wall_s else 0.0

    @property
    def accept_rate(self) -> float:
        """Fraction of proposed draft tokens that verification confirmed."""
        if not self.drafted_tokens:
            return 0.0
        return self.accepted_draft_tokens / self.drafted_tokens

    @property
    def accepted_tokens_per_spec_step(self) -> float:
        """Mean tokens a SPECULATING slot emits on a verify step: its
        accepted drafts plus the model-corrected token, i.e.
        ``1 + accepted_draft_tokens / spec_slot_steps``.  The multi-token
        decode win per speculation opportunity -- 1.0 means no draft was
        ever accepted (greedy pace), ``speculate + 1`` is the ceiling.
        Deliberately per slot-step, NOT per engine step: co-tenant slots
        emitting in the same step must not inflate it."""
        if not self.spec_slot_steps:
            return 0.0
        return 1.0 + self.accepted_draft_tokens / self.spec_slot_steps


@dataclasses.dataclass
class _Slot:
    """Host-side bookkeeping for one decode-batch row."""

    request: Optional[Request] = None
    fed: int = 0  # tokens consumed so far (prompt + fed-back generations)
    generated: List[int] = dataclasses.field(default_factory=list)
    admitted_step: int = 0
    admit_wall: float = 0.0
    first_token_step: Optional[int] = None
    first_token_wall: Optional[float] = None
    # speculation: this stream's drafter (fresh per admission -- draft
    # history must never leak across the slot's successive tenants)
    drafter: Optional[Drafter] = None
    drafted: int = 0  # draft tokens proposed for this stream
    accepted_drafts: int = 0  # drafts confirmed by verification

    @property
    def free(self) -> bool:
        return self.request is None

    def next_token(self) -> int:
        """The token this slot feeds on the upcoming step."""
        p = self.request.prompt
        if self.fed < p.size:
            return int(p[self.fed])  # teacher-forced prefill
        return self.generated[self.fed - p.size]  # fed-back generation


_ENGINE_FNS: Dict[Tuple[int, str], Tuple[Any, Any, Any, Any, Any]] = {}
_FN_CACHE_MAX = 8  # each entry pins a model's arrays + compiled programs


def _cache_put(cache: Dict, key, value) -> None:
    """FIFO-bounded insert so long-lived processes that quantize many models
    don't pin every one of them (plus its executables) forever."""
    if len(cache) >= _FN_CACHE_MAX:
        cache.pop(next(iter(cache)))
    cache[key] = value


def _engine_step_fns(qlayers, cfg, backend: str, constrain=None):
    """Jitted (step, chunk_step, chunk_advance, verify, reset) programs for
    the engine loop.

    Cached per (qlayers identity, backend) when no sharding constrain is
    installed, so property tests and repeated engine instances over the
    same quantized model share compiled programs (the jit itself also
    specializes per slot count / chunk size via input shapes).
    """
    key = (id(qlayers), backend)
    if constrain is None and key in _ENGINE_FNS:
        return _ENGINE_FNS[key]

    def constrain_state(out):
        """Re-apply the batch-axis sharding constraint to a new state."""
        if constrain is None:
            return out
        out = dict(out)
        out["h"] = [constrain(h, ("batch", "mlp")) for h in out["h"]]
        out["c"] = [constrain(c, ("batch", "mlp")) for c in out["c"]]
        return out

    def step(params, tokens, state, active):
        """One engine iteration: all slots advance one token.

        tokens: (S,) int32; active: (S,) bool.  Returns the per-slot
        greedy next token (argmax over the last-position logits -- the
        row-wise computation is identical to a batch-1 decode, so the
        argmax is too) and the new state with inactive rows frozen.
        """
        logits, new_state = lstm_lm.quant_forward(
            params, qlayers, cfg, tokens[:, None], state, backend=backend)
        greedy = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        mask = active[:, None]
        out = {
            "h": [jnp.where(mask, n, o) for n, o in zip(new_state["h"],
                                                        state["h"])],
            "c": [jnp.where(mask, n, o) for n, o in zip(new_state["c"],
                                                        state["c"])],
            "len": state["len"] + active.astype(jnp.int32),
        }
        return greedy, constrain_state(out)

    def chunk_step(params, tokens, state, valid):
        """One chunked-prefill iteration: slot i advances valid[i] tokens.

        tokens: (S, K) int32; valid: (S,) int32 in [0, K].  The ragged
        masked executor freezes each row's per-layer (h, c) and its ``len``
        counter beyond its valid length (valid == 0 rows are frozen
        entirely, subsuming the one-token step's active mask), so every
        row's state after the block is bitwise identical to feeding its
        valid prefix one token at a time.  Returns the greedy argmax over
        each row's LAST VALID position -- the only logits computed from
        live state.
        """
        logits, out = lstm_lm.quant_chunk_step(
            params, qlayers, cfg, tokens, state, valid, backend=backend)
        greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return greedy, constrain_state(out)

    def verify(params, tokens, state, valid, draft_len):
        """One speculative verify iteration over a ``(S, W)`` block.

        Row i's first ``valid[i] - draft_len[i]`` positions are committed
        tokens (prompt chunk, or the fed-back last generation), the next
        ``draft_len[i]`` are draft candidates.  Returns the per-position
        greedy argmax ``(S, W)``, the per-row accepted input count
        (committed tokens always consume; a draft consumes iff the argmax
        one position earlier equals it), and the state advanced to exactly
        each row's accepted length -- rejected positions are rolled back by
        construction (the advance is a masked chunk advance from the
        pre-step state, the same executor chunked prefill trusts).  Idle
        rows (``valid == 0``) stay frozen, subsuming the active mask.
        """
        pred, accepted, out = lstm_lm.quant_verify_step(
            params, qlayers, cfg, tokens, state, valid, draft_len,
            backend=backend)
        return pred, accepted, constrain_state(out)

    def chunk_advance(params, tokens, state, valid):
        """Chunked iteration where NO slot emits a token this step (every
        active row is mid-prompt with > K tokens still to feed): advance
        state only, no LM head, no greedy output -- so the engine loop can
        dispatch consecutive prefill chunks without a host sync."""
        out = lstm_lm.quant_chunk_advance(
            params, qlayers, cfg, tokens, state, valid, backend=backend)
        return constrain_state(out)

    fns = (
        jax.jit(step),
        jax.jit(chunk_step),
        jax.jit(chunk_advance),
        jax.jit(verify),
        jax.jit(lambda state, slot: lstm_lm.reset_quant_slot(
            qlayers, state, slot)),
    )
    if constrain is None:
        _cache_put(_ENGINE_FNS, key, fns)
    return fns


class ContinuousBatchingEngine:
    """Drives a fixed-slot decode batch over a queue of requests.

    ``chunk``: prefill chunk size K.  With ``chunk > 1`` a second jitted
    program teacher-forces up to K prompt tokens per slot per engine step as
    an ``(S, K)`` block with per-slot valid lengths (slots mid-generation
    feed 1 token in the same step), cutting time-to-first-token for long
    prompts by ~K dispatches while staying bit-exact with ``chunk=1`` and
    with ``decode_single``.  Steps where no slot has >= 2 prompt tokens left
    fall back to the one-token program, so pure generation never pays the
    K-wide block.

    ``speculate``: draft budget k for speculative decoding.  With ``k > 0``
    each generating slot's drafter (``drafter_factory``, default
    ``NGramDrafter``: a suffix cache over that stream's own tokens) proposes
    up to k continuation tokens per step, and steps where at least one slot
    drafts run the jitted masked-chunk **verify** program over a
    ``(S, k+1)`` block: per-position argmax, longest-confirmed-prefix
    acceptance, and per-row state rollback to the accepted length, emitting
    1..k+1 tokens per slot per step.  Output tokens are bit-identical to
    ``speculate=0`` (and to ``decode_single``) by construction; steps where
    no slot drafts fall back to the one-token / chunked-prefill programs,
    so workloads the drafter can't predict never pay the wide block.

    ``mesh``/``rules``: optional batch-axis sharding hook -- when given, the
    slot state is placed via ``runtime.sharding.engine_state_shardings`` and
    per-step token/valid blocks via ``engine_block_sharding``, so the slot
    dim spreads consistently over the data-parallel mesh axes.
    """

    def __init__(self, params, qlayers, cfg, n_slots: int, *,
                 backend: str = "xla", chunk: int = 1, speculate: int = 0,
                 drafter_factory=None, mesh=None, rules=None):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        if chunk < 1:
            raise ValueError(f"chunk must be >= 1, got {chunk}")
        if speculate < 0:
            raise ValueError(f"speculate must be >= 0, got {speculate}")
        self.params = params
        self.qlayers = qlayers
        self.cfg = cfg
        self.n_slots = n_slots
        self.backend = backend
        self.chunk = chunk
        self.speculate = speculate
        self._drafter_factory = (
            drafter_factory if drafter_factory is not None
            else NGramDrafter)
        self._slots = [_Slot() for _ in range(n_slots)]
        self._queue: List[Request] = []
        self._state = lstm_lm.init_quant_decode_state(
            qlayers, n_slots, per_slot_len=True)
        constrain = None
        self._put = lambda x: x
        if mesh is not None:
            from repro.runtime import sharding as shlib

            self._state = jax.device_put(
                self._state,
                shlib.engine_state_shardings(self._state, rules, mesh))
            constrain = shlib.make_constrain(rules, mesh)
            # only two input shapes ever occur ((S,) and (S, K)): resolve
            # each sharding once, not twice per step on the serving hot loop
            shard_cache: Dict[Tuple[int, ...], Any] = {}

            def _put(x):
                s = shard_cache.get(x.shape)
                if s is None:
                    s = shard_cache[x.shape] = shlib.engine_block_sharding(
                        x.shape, rules, mesh)
                return jax.device_put(x, s)

            self._put = _put
        (self._step, self._chunk_step, self._chunk_advance, self._verify,
         self._reset) = _engine_step_fns(qlayers, cfg, backend, constrain)

    # -- queue management ---------------------------------------------------

    def submit(self, request: Request) -> None:
        # results are keyed by rid; a duplicate would silently shadow a
        # stream's output, so reject it at the door
        taken = {r.rid for r in self._queue}
        taken.update(s.request.rid for s in self._slots if not s.free)
        if request.rid in taken:
            raise ValueError(f"duplicate request id {request.rid}")
        self._queue.append(request)

    def submit_all(self, requests: Sequence[Request]) -> None:
        for r in requests:
            self.submit(r)

    @property
    def pending(self) -> int:
        return len(self._queue)

    @property
    def active(self) -> int:
        return sum(not s.free for s in self._slots)

    # -- the serving loop ---------------------------------------------------

    def _admit(self, step_idx: int, now: float) -> None:
        for i, slot in enumerate(self._slots):
            if not self._queue:
                break
            if not slot.free:
                continue
            req = self._queue.pop(0)
            drafter = None
            if self.speculate:
                # a FRESH drafter per admission, reset() besides (the
                # documented lifecycle -- so pooled/shared factory
                # instances also start blank): the slot's previous tenant
                # must never leak draft history into this stream
                drafter = self._drafter_factory()
                drafter.reset()
                drafter.observe(req.prompt.tolist())
            self._slots[i] = _Slot(request=req, admitted_step=step_idx,
                                   admit_wall=now, drafter=drafter)
            self._state = self._reset(self._state, jnp.int32(i))

    def _result(self, slot: _Slot, finished_step: int, now: float,
                truncated: bool) -> StreamResult:
        req = slot.request
        ttft_steps = ttft_s = tps = None
        if slot.generated and slot.first_token_step is not None:
            ttft_steps = slot.first_token_step - slot.admitted_step + 1
            ttft_s = slot.first_token_wall - slot.admit_wall
            span = now - slot.admit_wall
            tps = len(slot.generated) / span if span > 0 else float("inf")
        return StreamResult(
            rid=req.rid,
            tokens=list(slot.generated),
            prompt_len=int(req.prompt.size),
            admitted_step=slot.admitted_step,
            finished_step=finished_step,
            truncated=truncated,
            ttft_steps=ttft_steps,
            ttft_s=ttft_s,
            tokens_per_s=tps,
            drafted_tokens=slot.drafted,
            accepted_draft_tokens=slot.accepted_drafts,
        )

    def run(self, max_steps: Optional[int] = None
            ) -> Tuple[Dict[int, StreamResult], EngineStats]:
        """Serve until the queue and all slots drain.  Returns per-request
        results keyed by rid plus occupancy/throughput/latency stats."""
        results: Dict[int, StreamResult] = {}
        step_idx = 0
        active_slot_steps = 0
        max_active = 0
        prompt_tokens = 0
        generated = 0
        spec_steps = 0
        spec_slot_steps = 0
        t0 = time.perf_counter()
        while self._queue or any(not s.free for s in self._slots):
            if max_steps is not None and step_idx >= max_steps:
                break
            self._admit(step_idx, time.perf_counter())
            # speculative drafts: ask each generating slot's drafter for up
            # to k candidates, capped so even a fully-accepted block lands
            # exactly on the stream's remaining budget (a slot one token
            # from done never drafts -- its drafts could never be emitted)
            drafts: Dict[int, List[int]] = {}
            if self.speculate:
                for i, slot in enumerate(self._slots):
                    if slot.free or slot.fed < slot.request.prompt.size:
                        continue
                    room = slot.request.max_new_tokens - len(slot.generated)
                    if room >= 2:
                        k = min(self.speculate, room - 1)
                        # clamp: a custom Drafter returning more than asked
                        # must not overflow the block or the stream budget
                        d = list(slot.drafter.draft(k))[:k]
                        if d:
                            drafts[i] = d
            # pick this step's program: the (S, k+1) verify block when any
            # slot drafted; else chunked prefill when some slot still has
            # >= 2 prompt tokens to teacher-force; else the one-token step
            # -- so speculate=0 engines run exactly the pre-speculation
            # program sequence, and undraftable workloads never pay the
            # wide block
            chunk_pending = self.chunk > 1 and any(
                not s.free and s.request.prompt.size - s.fed >= 2
                for s in self._slots)
            if drafts:
                # a mixed step (drafting slots + mid-prefill co-tenants)
                # widens to whichever program is larger: the verify step
                # handles arbitrary per-row valid/draft_len, so chunked
                # prefill must not be capped at k+1 when chunk > k+1
                width = max(self.speculate + 1,
                            self.chunk if chunk_pending else 1)
            elif chunk_pending:
                width = self.chunk
            else:
                width = 1
            tokens = np.zeros((self.n_slots, width), np.int32)
            valid = np.zeros((self.n_slots,), np.int32)
            draft_len = np.zeros((self.n_slots,), np.int32)
            fed_before = [s.fed for s in self._slots]
            for i, slot in enumerate(self._slots):
                if slot.free:
                    continue
                rem = slot.request.prompt.size - slot.fed
                if rem >= 1:  # teacher-forced prefill: up to `width` tokens
                    n = min(width, rem)
                    tokens[i, :n] = slot.request.prompt[
                        slot.fed:slot.fed + n]
                else:  # mid-generation: feed back latest token (+ drafts)
                    d = drafts.get(i, ())
                    n = 1 + len(d)
                    tokens[i, 0] = slot.next_token()
                    tokens[i, 1:n] = d
                    draft_len[i] = len(d)
                valid[i] = n
            n_active = int((valid > 0).sum())
            active_slot_steps += n_active
            max_active = max(max_active, n_active)
            # dispatch ONE jitted program; afterwards ``consumed[i]`` is the
            # inputs row i advanced by and ``preds[i, p]`` the greedy token
            # following input position p (for every consumed position on
            # verify steps; only at a row's single emitting position on the
            # one-token / chunked paths, which emit at most one token)
            if drafts:
                pred, accepted, self._state = self._verify(
                    self.params, self._put(jnp.asarray(tokens)),
                    self._state, self._put(jnp.asarray(valid)),
                    self._put(jnp.asarray(draft_len)))
                preds = np.asarray(pred)
                consumed = np.asarray(accepted)
                spec_steps += 1
            elif width == 1:
                greedy, self._state = self._step(
                    self.params, self._put(jnp.asarray(tokens[:, 0])),
                    self._state, self._put(jnp.asarray(valid > 0)))
                preds = np.asarray(greedy)[:, None]
                consumed = valid
            else:
                # a slot emits a token this step iff it consumes its last
                # prompt token (0 < remaining <= chunk) or is generating
                # (remaining == 0).  When nothing emits, the logits would
                # never be read: run the head-free advance program and skip
                # the host sync so consecutive prefill chunks pipeline.
                emits = any(
                    not s.free and
                    s.request.prompt.size - s.fed <= width
                    for s in self._slots)
                consumed = valid
                if emits:
                    greedy, self._state = self._chunk_step(
                        self.params, self._put(jnp.asarray(tokens)),
                        self._state, self._put(jnp.asarray(valid)))
                    # the chunked head reads each row's LAST VALID position,
                    # the only one the emission rule below can select
                    greedy = np.asarray(greedy)
                    preds = np.zeros((self.n_slots, width), np.int32)
                    for i in range(self.n_slots):
                        if valid[i]:
                            preds[i, valid[i] - 1] = greedy[i]
                else:
                    preds = None  # never read: no row emits this step
                    self._state = self._chunk_advance(
                        self.params, self._put(jnp.asarray(tokens)),
                        self._state, self._put(jnp.asarray(valid)))
            now = time.perf_counter()
            for i, slot in enumerate(self._slots):
                if slot.free:
                    continue
                req = slot.request
                n = int(consumed[i])
                fb = fed_before[i]
                # prompt tokens consumed this step (0 when mid-generation)
                prompt_tokens += min(n, max(int(req.prompt.size) - fb, 0))
                slot.fed += n
                if draft_len[i]:
                    # accepted drafts = consumed inputs minus the committed
                    # fed-back token (draft capping keeps emissions within
                    # budget, so no accepted token is ever discarded); the
                    # engine-wide totals are summed from StreamResults at
                    # stats build -- every slot ends up in results
                    slot.drafted += int(draft_len[i])
                    slot.accepted_drafts += n - 1
                    spec_slot_steps += 1
                for p in range(n):
                    # consuming input position p yields a generated token
                    # iff p is the row's last prompt token or later
                    if fb + p + 1 < req.prompt.size:
                        continue
                    slot.generated.append(int(preds[i, p]))
                    if slot.drafter is not None:
                        slot.drafter.observe([slot.generated[-1]])
                    if len(slot.generated) == 1:
                        slot.first_token_step = step_idx
                        slot.first_token_wall = now
                if len(slot.generated) >= req.max_new_tokens:
                    results[req.rid] = self._result(
                        slot, step_idx, now, truncated=False)
                    generated += len(slot.generated)
                    self._slots[i] = _Slot()  # evict mid-flight
            step_idx += 1
        # hitting max_steps leaves streams in flight: return their partial
        # generations (marked truncated) instead of silently dropping them.
        # The step that actually ran last is step_idx - 1 (step_idx was
        # already advanced past it), matching mid-flight eviction's stamps.
        now = time.perf_counter()
        for i, slot in enumerate(self._slots):
            if slot.free:
                continue
            results[slot.request.rid] = self._result(
                slot, max(step_idx - 1, 0), now, truncated=True)
            generated += len(slot.generated)
            self._slots[i] = _Slot()
        wall = time.perf_counter() - t0
        ttfts = [r for r in results.values() if r.ttft_steps is not None]
        stats = EngineStats(
            steps=step_idx,
            n_slots=self.n_slots,
            active_slot_steps=active_slot_steps,
            max_active=max_active,
            generated_tokens=generated,
            prompt_tokens=prompt_tokens,
            wall_s=wall,
            chunk=self.chunk,
            speculate=self.speculate,
            spec_steps=spec_steps,
            spec_slot_steps=spec_slot_steps,
            drafted_tokens=sum(
                r.drafted_tokens for r in results.values()),
            accepted_draft_tokens=sum(
                r.accepted_draft_tokens for r in results.values()),
            mean_ttft_steps=(sum(r.ttft_steps for r in ttfts) / len(ttfts)
                             if ttfts else 0.0),
            mean_ttft_s=(sum(r.ttft_s for r in ttfts) / len(ttfts)
                         if ttfts else 0.0),
            mean_stream_tokens_per_s=(
                sum(r.tokens_per_s for r in ttfts) / len(ttfts)
                if ttfts else 0.0),
        )
        return results, stats


# ---------------------------------------------------------------------------
# Single-stream reference + request traces
# ---------------------------------------------------------------------------


_SINGLE_FNS: Dict[Tuple[int, str], Tuple[Any, Any]] = {}


def single_stream_fns(qlayers, cfg, backend: str = "xla"):
    """Jitted (prefill, decode) pair for batch-1 serving, cached per
    (qlayers identity, backend) so repeated ``decode_single`` calls reuse
    the compiled programs instead of re-tracing fresh closures."""
    key = (id(qlayers), backend)
    if key not in _SINGLE_FNS:
        prefill_fn = jax.jit(lambda p, t, s: lstm_lm.quant_prefill(
            p, qlayers, cfg, t, s, backend=backend))
        decode_fn = jax.jit(lambda p, t, s: lstm_lm.quant_decode_step(
            p, qlayers, cfg, t, s, backend=backend))
        _cache_put(_SINGLE_FNS, key, (prefill_fn, decode_fn))
    return _SINGLE_FNS[key]


def decode_single(params, qlayers, cfg, prompt, max_new_tokens: int, *,
                  backend: str = "xla",
                  prefill_fn=None, decode_fn=None) -> List[int]:
    """Decode ONE stream alone: scanned prefill + greedy loop.

    The bit-exactness oracle for the engine (and the naive serving baseline
    of ``benchmarks/engine_throughput.py``).  Compiled programs are shared
    across calls via ``single_stream_fns`` (prefill still specializes per
    distinct prompt length).
    """
    prompt = jnp.asarray(np.asarray(prompt, np.int32).reshape(1, -1))
    if prefill_fn is None or decode_fn is None:
        pf, df = single_stream_fns(qlayers, cfg, backend)
        prefill_fn = prefill_fn or pf
        decode_fn = decode_fn or df
    state = lstm_lm.init_quant_decode_state(qlayers, 1)
    logits, state = prefill_fn(params, prompt, state)
    out = [int(jnp.argmax(logits, -1)[0])]
    for _ in range(max_new_tokens - 1):
        tok = jnp.asarray([[out[-1]]], jnp.int32)
        logits, state = decode_fn(params, tok, state)
        out.append(int(jnp.argmax(logits, -1)[0]))
    return out


def synthetic_trace(n_requests: int, vocab_size: int, *, seed: int = 0,
                    prompt_lens: Sequence[int] = (4, 6, 8, 12),
                    gen_lens: Sequence[int] = (4, 8, 12)) -> List[Request]:
    """A mixed-length request workload with deterministic token content."""
    rng = np.random.default_rng(seed)
    out = []
    for rid in range(n_requests):
        p = int(rng.choice(list(prompt_lens)))
        g = int(rng.choice(list(gen_lens)))
        toks = rng.integers(0, vocab_size, size=(p,), dtype=np.int64)
        out.append(Request(rid=rid, prompt=toks.astype(np.int32),
                           max_new_tokens=g))
    return out


def load_trace(path: str, vocab_size: int, *, seed: int = 0) -> List[Request]:
    """Load a request trace: a JSON list of objects with either an explicit
    ``prompt`` token list or a ``prompt_len`` (tokens drawn from ``seed``),
    plus ``gen`` (generation budget) and optional ``id``.

        [{"prompt_len": 12, "gen": 8}, {"prompt": [3, 1, 4], "gen": 4}]

    Malformed entries (missing keys, empty prompt, non-positive lengths or
    budgets) raise ``ValueError`` naming the offending entry instead of
    failing deep inside the engine.
    """
    with open(path) as f:
        entries = json.load(f)
    if not isinstance(entries, list):
        raise ValueError(
            f"trace {path}: expected a JSON list of request objects, "
            f"got {type(entries).__name__}")
    rng = np.random.default_rng(seed)
    out = []
    for i, e in enumerate(entries):
        if not isinstance(e, dict):
            raise ValueError(
                f"trace {path} entry {i}: expected an object, "
                f"got {type(e).__name__}")
        if "gen" not in e:
            raise ValueError(f"trace {path} entry {i}: missing 'gen'")
        gen = int(e["gen"])
        if gen < 1:
            raise ValueError(
                f"trace {path} entry {i}: 'gen' must be >= 1, got {gen}")
        if "prompt" in e:
            toks = np.asarray(e["prompt"], np.int32).reshape(-1)
            if toks.size < 1:
                raise ValueError(
                    f"trace {path} entry {i}: 'prompt' is empty")
        elif "prompt_len" in e:
            plen = int(e["prompt_len"])
            if plen < 1:
                raise ValueError(
                    f"trace {path} entry {i}: 'prompt_len' must be >= 1, "
                    f"got {plen}")
            toks = rng.integers(0, vocab_size, size=(plen,)).astype(np.int32)
        else:
            raise ValueError(
                f"trace {path} entry {i}: needs 'prompt' or 'prompt_len'")
        out.append(Request(rid=int(e.get("id", i)), prompt=toks,
                           max_new_tokens=gen))
    return out
