"""Serving launcher: batched prefill + decode with optional quantization.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
        --batch 4 --prompt-len 32 --gen 16 [--quant int8]

    # the paper's integer-only LSTM path (fused [i|f|z|o] executor):
    PYTHONPATH=src python -m repro.launch.serve --arch lstm-rnnt --smoke \
        --quant int8-lstm --backend interpret

    # same engine, integer GRU cell (packed [r|u|n], single h carry):
    PYTHONPATH=src python -m repro.launch.serve --arch gru-rnnt --smoke \
        --quant int8-gru --backend interpret

Continuous-batching engine mode (``--engine``, int8-lstm / int8-gru):
instead of
one fixed static batch, a queue of requests with mixed prompt lengths and
generation budgets is served through ``launch/engine.py`` -- admitted into
``--slots`` decode-batch rows, prefilled by teacher-forcing through the same
jitted fused step that decodes, and evicted mid-flight when their budget is
spent.  ``--chunk K`` enables chunked prefill: up to K prompt tokens per
slot per engine step (one masked ``(S, K)`` dispatch instead of K), cutting
time-to-first-token ~K-fold on prompt-heavy workloads while every stream
stays bit-identical to ``--chunk 1`` and to decoding it alone.
``--speculate k`` enables speculative decoding: each generating slot's
n-gram drafter proposes up to k continuation tokens per step and one masked
``(S, k+1)`` verify dispatch accepts the longest greedy-confirmed prefix
(1..k+1 tokens emitted per slot per step), again bit-identical to
``--speculate 0``.  ``--policy`` picks the slot-scheduling policy (fifo |
priority | srf | rr | fifo-reject) and ``--oversubscribe R`` lets up to
``ceil(R * slots)`` streams be live at once, time-multiplexed through the
host-side integer-state pool -- every stream still bit-identical to
decoding it alone.  The workload is either synthetic (``--requests N``) or
a JSON trace (``--trace requests.json``, entries ``{"prompt_len"|"prompt",
"gen", "id"?}``).  Reported metrics include mean TTFT (steps + wall-clock),
per-stream tokens/sec, and -- under speculation -- the draft accept rate
and mean accepted tokens per verify step.

    PYTHONPATH=src python -m repro.launch.serve --arch lstm-rnnt --smoke \
        --quant int8-lstm --engine --slots 8 --requests 16 --chunk 4 \
        --speculate 4

Fleet mode (``--shards N``, requires ``--engine``): the same workload served
through ``launch/fleet.py``'s admission router over N per-shard engines --
least-loaded routing, capped retry/backoff on transient admission failures,
fifo-reject degradation when saturated, and shard-kill recovery that
migrates or replays every in-flight stream bit-exactly.  ``--fault-spec``
takes a JSON object (inline, or ``@path/to/spec.json``) in the
``FaultInjector.from_spec`` schema:

    PYTHONPATH=src python -m repro.launch.serve --arch lstm-rnnt --smoke \
        --quant int8-lstm --engine --shards 2 --slots 4 --requests 16 \
        --fault-spec '{"kills": [{"shard": 0, "at_frac": 0.5}]}'

Each shard gets its own disjoint device mesh when the host exposes enough
devices (``runtime.sharding.fleet_meshes``; on CPU set
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` BEFORE jax starts),
and shares the default device otherwise.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp


def _scan_prefill(decode, params, prompt, state):
    """Teacher-force the whole prompt through decode in ONE scanned pass.

    Replaces the former per-token python loop (one dispatch per prompt
    position) with a single jitted ``lax.scan``; returns the last-position
    logits and the warmed decode state.
    """

    # first token primes the (B, V) logits carry; the scan then keeps only
    # the latest logits live instead of stacking a (T, B, V) array
    logits, state = decode(params, prompt[:, :1], state)

    def body(carry, tok):
        state, _ = carry
        logits, state = decode(params, tok[:, None], state)
        return (state, logits), None

    (state, logits), _ = jax.lax.scan(
        body, (state, logits), jnp.swapaxes(prompt[:, 1:], 0, 1))
    return logits, state


def _greedy_loop(decode, params, logits, state, n_gen):
    out_tokens = []
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for _ in range(n_gen):
        logits, state = decode(params, tok, state)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    return jnp.concatenate(out_tokens, axis=1)


def _quantized_recurrent_lm(args, cfg):
    """Init + calibrate + quantize the stacked recurrent LM once (shared by
    the static path and the engine path)."""
    from repro.models import lstm_lm, model_zoo

    want_cell = args.quant.split("-", 1)[1]  # int8-lstm -> lstm
    if cfg.family != "lstm":
        raise SystemExit(
            f"--quant {args.quant} requires an lstm-family arch (e.g. "
            f"lstm-rnnt, gru-rnnt), got {cfg.name} ({cfg.family})")
    have_cell = lstm_lm.rnn_cell(cfg)
    if have_cell != want_cell:
        raise SystemExit(
            f"--quant {args.quant} expects rnn_cell={want_cell!r} but "
            f"{cfg.name} uses {have_cell!r} (try --quant int8-{have_cell})")
    bundle = model_zoo.build(cfg)
    params, _ = bundle.init(jax.random.PRNGKey(0))
    calib = jax.random.randint(
        jax.random.PRNGKey(2), (args.batch, max(args.prompt_len, 8)), 0,
        cfg.vocab_size)
    t0 = time.time()
    qlayers = lstm_lm.quantize_stack(params, cfg, calib)
    print(f"calibrated+quantized {len(qlayers)} {have_cell.upper()} layers "
          f"in {time.time() - t0:.1f}s (backend={args.backend})")
    return params, qlayers


def _serve_engine(args, cfg) -> None:
    """Continuous-batching serving of the integer recurrent LM."""
    from repro.launch import engine as E

    params, qlayers = _quantized_recurrent_lm(args, cfg)
    if args.trace:
        requests = E.load_trace(args.trace, cfg.vocab_size, seed=1)
    else:
        requests = E.synthetic_trace(
            args.requests, cfg.vocab_size, seed=1,
            prompt_lens=(args.prompt_len // 2 or 1, args.prompt_len),
            gen_lens=(args.gen // 2 or 1, args.gen))
    if not requests:
        raise SystemExit("engine: empty workload (use --requests N >= 1 or "
                         "a non-empty --trace)")
    eng = E.ContinuousBatchingEngine(
        params, qlayers, cfg, n_slots=args.slots, backend=args.backend,
        chunk=args.chunk, speculate=args.speculate, policy=args.policy,
        oversubscribe=args.oversubscribe)
    eng.submit_all(requests)
    results, stats = eng.run()
    print(f"arch={cfg.name} quant={args.quant} engine slots={args.slots} "
          f"chunk={args.chunk} speculate={args.speculate} "
          f"policy={stats.policy} oversubscribe={stats.oversubscribe} "
          f"backend={args.backend}")
    print(f"served {len(results)}/{len(requests)} requests in "
          f"{stats.wall_s:.2f}s ({stats.steps} steps)")
    print(f"decode tokens/s: {stats.tokens_per_s:.1f} "
          f"(+{stats.prompt_tokens} prompt tokens)")
    print(f"slot occupancy: {stats.occupancy:.2f}")
    print(f"mean TTFT: {stats.mean_ttft_steps:.1f} steps / "
          f"{stats.mean_ttft_s * 1e3:.1f} ms; "
          f"mean stream tokens/s: {stats.mean_stream_tokens_per_s:.1f}")
    if stats.preemptions or stats.resumes or stats.rejected \
            or stats.oversubscribe > 1:
        print(f"scheduling: peak live {stats.peak_live} "
              f"(slots={stats.n_slots}), {stats.preemptions} preemptions, "
              f"{stats.resumes} resumes, {stats.rejected} rejected, "
              f"{stats.pool_state_bytes} B/stream parked state")
    if args.speculate:
        print(f"speculation: accept rate {stats.accept_rate:.2f} "
              f"({stats.accepted_draft_tokens}/{stats.drafted_tokens} "
              f"drafts), {stats.accepted_tokens_per_spec_step:.2f} "
              f"tokens/slot-step over {stats.spec_slot_steps} speculating "
              f"slot-steps ({stats.spec_steps} verify steps)")
    first = results[requests[0].rid]
    print("sample:", first.tokens)


def _load_fault_spec(raw):
    """``--fault-spec`` value -> FaultInjector (inline JSON or @file)."""
    import json

    from repro.launch import fleet as F

    if raw is None:
        return None
    if raw.startswith("@"):
        with open(raw[1:]) as f:
            spec = json.load(f)
    else:
        spec = json.loads(raw)
    if not isinstance(spec, dict):
        raise SystemExit(f"--fault-spec: expected a JSON object, "
                         f"got {type(spec).__name__}")
    return F.FaultInjector.from_spec(spec)


def _serve_fleet(args, cfg) -> None:
    """Sharded serving of the integer recurrent LM through the fleet
    router (admission routing + fault-plane recovery)."""
    from repro.launch import engine as E
    from repro.launch import fleet as F
    from repro.runtime import sharding as shlib

    params, qlayers = _quantized_recurrent_lm(args, cfg)
    if args.trace:
        requests = E.load_trace(args.trace, cfg.vocab_size, seed=1)
    else:
        requests = E.synthetic_trace(
            args.requests, cfg.vocab_size, seed=1,
            prompt_lens=(args.prompt_len // 2 or 1, args.prompt_len),
            gen_lens=(args.gen // 2 or 1, args.gen),
            arrival_span=max(args.requests // 2, 1))
    if not requests:
        raise SystemExit("fleet: empty workload (use --requests N >= 1 or "
                         "a non-empty --trace)")
    meshes = shlib.fleet_meshes(args.shards)
    placed = sum(m is not None for m in meshes)
    router = F.FleetRouter(
        params, qlayers, cfg, n_shards=args.shards,
        slots_per_shard=args.slots, backend=args.backend, chunk=args.chunk,
        speculate=args.speculate, policy=args.policy,
        oversubscribe=args.oversubscribe, injector=_load_fault_spec(
            args.fault_spec), meshes=meshes)
    router.warmup()
    router.submit_all(requests)
    results, stats = router.run()
    print(f"arch={cfg.name} quant={args.quant} fleet shards={args.shards} "
          f"slots/shard={args.slots} chunk={args.chunk} "
          f"policy={args.policy} oversubscribe={args.oversubscribe} "
          f"backend={args.backend} meshes={placed}/{args.shards}")
    print(f"served {stats.completed}/{stats.submitted} requests in "
          f"{stats.wall_s:.2f}s ({stats.fleet_steps} fleet steps); "
          f"{stats.rejected} rejected, {stats.lost} lost")
    print(f"goodput: {stats.goodput_tokens_per_step:.2f} tokens/step "
          f"({stats.tokens_per_s:.1f} tokens/s)")
    print(f"fault plane: {stats.kills} kills, {stats.restarts} restarts, "
          f"{stats.hang_events} hung steps, {stats.migrated_streams} "
          f"migrated, {stats.replayed_streams} replayed, "
          f"{stats.rerouted_pending} rerouted, {stats.admit_retries} "
          f"admission retries")
    for i, s in enumerate(stats.shards):
        print(f"  shard {i}: {'alive' if s.alive else 'dead '} "
              f"steps={s.steps} occupancy={s.occupancy(args.slots):.2f} "
              f"tokens={s.generated_tokens} adopted={s.adopted} "
              f"stragglers={s.stragglers} hung={s.hung} "
              f"kills={s.kills} restarts={s.restarts}")
    done = [r for r in results.values() if r.tokens and not r.truncated]
    if done:
        ttfts = sorted(r.ttft_steps for r in done
                       if r.ttft_steps is not None)
        if ttfts:
            print(f"TTFT p50/p99: {ttfts[len(ttfts) // 2]} / "
                  f"{ttfts[min(len(ttfts) - 1, int(len(ttfts) * 0.99))]} "
                  f"fleet steps")
        print("sample:", done[0].tokens)


def _serve_int8_recurrent(args, cfg) -> None:
    """Integer-only serving of the stacked recurrent LM (paper sec 3.2).

    The scanned prefill runs the hoisted two-stage executor: per layer, the
    whole prompt's packed input GEMM is one time-batched int8 matmul and
    only the recurrent stage scans over time (as the persistent Pallas
    sequence kernel under ``--backend pallas|interpret``), so prompt
    tokens/s no longer pays a per-token input matmul dispatch.
    """
    from repro.models import lstm_lm

    params, qlayers = _quantized_recurrent_lm(args, cfg)
    prompt = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0,
        cfg.vocab_size)
    prefill = jax.jit(lambda p, toks, s: lstm_lm.quant_prefill(
        p, qlayers, cfg, toks, s, backend=args.backend))
    decode = jax.jit(lambda p, t, s: lstm_lm.quant_decode_step(
        p, qlayers, cfg, t, s, backend=args.backend))

    state = lstm_lm.init_quant_decode_state(qlayers, args.batch)
    t0 = time.time()
    logits, state = prefill(params, prompt, state)
    jax.block_until_ready(logits)
    prefill_s = time.time() - t0
    t0 = time.time()
    gen = _greedy_loop(decode, params, logits, state, args.gen)
    gen_s = time.time() - t0
    print(f"arch={cfg.name} quant={args.quant} backend={args.backend}")
    print(f"prompt tokens/s: {args.batch * args.prompt_len / prefill_s:.1f}")
    print(f"decode tokens/s: {args.batch * args.gen / gen_s:.1f}")
    print("sample:", gen[0].tolist())


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--quant", default="none",
                    choices=["none", "int8", "int8-lstm", "int8-gru"])
    ap.add_argument("--backend", default="xla",
                    choices=["xla", "pallas", "interpret"],
                    help="integer recurrent kernel backend "
                         "(int8-lstm / int8-gru only)")
    ap.add_argument("--engine", action="store_true",
                    help="continuous-batching engine (int8-lstm / "
                         "int8-gru only)")
    ap.add_argument("--slots", type=int, default=8,
                    help="decode-batch rows of the engine")
    ap.add_argument("--chunk", type=int, default=1,
                    help="prefill chunk size K for --engine: feed up to K "
                         "prompt tokens per slot per step (one masked "
                         "(S, K) dispatch instead of K one-token steps). "
                         "Cuts TTFT ~K-fold on prompt-heavy workloads; "
                         "bit-exact vs --chunk 1. Pure generation is "
                         "unaffected, so K>1 only helps when prompts are "
                         "long relative to generation budgets")
    ap.add_argument("--speculate", type=int, default=0,
                    help="draft budget k for --engine speculative decoding: "
                         "an n-gram drafter proposes up to k continuation "
                         "tokens per generating slot per step, verified in "
                         "one masked (S, k+1) dispatch that emits every "
                         "greedy-confirmed token (1..k+1 per slot per "
                         "step). Bit-exact vs --speculate 0; pays off on "
                         "self-repetitive streams (the drafter only knows "
                         "each stream's own history)")
    ap.add_argument("--policy", default="fifo",
                    help="slot-scheduling policy for --engine (fifo | "
                         "priority | srf | rr | fifo-reject; see "
                         "launch/scheduler.py). fifo reproduces the "
                         "pre-scheduler engine exactly; the others may "
                         "preempt streams to the host-side state pool and "
                         "resume them later, bit-exactly")
    ap.add_argument("--oversubscribe", type=float, default=1.0,
                    help="admission headroom for --engine as a multiple of "
                         "--slots: up to ceil(ratio * slots) streams may be "
                         "live at once, time-multiplexed through the state "
                         "pool by preempting policies. 1.0 (default) never "
                         "holds more streams than slots")
    ap.add_argument("--shards", type=int, default=None,
                    help="serve through the fleet router over N per-shard "
                         "engines (requires --engine; launch/fleet.py). "
                         "Each shard gets --slots decode rows and its own "
                         "device mesh when enough devices exist")
    ap.add_argument("--fault-spec", default=None,
                    help="fault-injection spec for --shards: inline JSON or "
                         "@file, schema per fleet.FaultInjector.from_spec "
                         "(kills / hangs / admission failures, all seeded "
                         "and deterministic)")
    ap.add_argument("--requests", type=int, default=16,
                    help="synthetic workload size for --engine")
    ap.add_argument("--trace", default=None,
                    help="JSON request trace for --engine "
                         "(see launch/engine.py:load_trace)")
    args = ap.parse_args()
    if args.prompt_len < 1:
        # decode needs at least one teacher-forced token to produce logits
        ap.error("--prompt-len must be >= 1")
    if args.chunk < 1:
        ap.error("--chunk must be >= 1")
    if args.speculate < 0:
        ap.error("--speculate must be >= 0")
    if args.oversubscribe < 1.0:
        ap.error("--oversubscribe must be >= 1.0")
    if (args.policy != "fifo" or args.oversubscribe > 1.0) \
            and not args.engine:
        ap.error("--policy/--oversubscribe require --engine (scheduling "
                 "is a continuous-batching concern)")
    if args.speculate and not args.engine:
        ap.error("--speculate requires --engine (speculative decoding is a "
                 "continuous-batching program)")
    if args.engine and args.quant not in ("int8-lstm", "int8-gru"):
        ap.error("--engine requires --quant int8-lstm or int8-gru (the "
                 "integer recurrent LMs are the only models with per-slot "
                 "integer decode state)")
    if args.shards is not None and not args.engine:
        ap.error("--shards requires --engine (the fleet router drives "
                 "continuous-batching engines)")
    if args.shards is not None and args.shards < 1:
        ap.error("--shards must be >= 1")
    if args.fault_spec is not None and args.shards is None:
        ap.error("--fault-spec requires --shards (faults are injected at "
                 "the fleet router)")

    from repro.configs.registry import get_config
    from repro.models import model_zoo, quant_transformer

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.engine:
        if args.shards is not None:
            _serve_fleet(args, cfg)
        else:
            _serve_engine(args, cfg)
        return
    if args.quant in ("int8-lstm", "int8-gru"):
        _serve_int8_recurrent(args, cfg)
        return

    bundle = model_zoo.build(cfg)
    params, _ = bundle.init(jax.random.PRNGKey(0))
    if args.quant == "int8":
        params = quant_transformer.quantize_param_tree(params)
        bundle = quant_transformer.quantize_bundle(bundle)  # for init_state

    constrain = lambda x, logical=None: x
    prompt = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0,
        cfg.vocab_size)

    decode = jax.jit(lambda p, t, s: bundle.decode(p, t, s, constrain))
    state = bundle.init_state(args.batch, args.max_len)
    # prefill by teacher-forcing the prompt through decode (cache warmup)
    t0 = time.time()
    logits, state = _scan_prefill(decode, params, prompt, state)
    jax.block_until_ready(logits)
    prefill_s = time.time() - t0
    t0 = time.time()
    gen = _greedy_loop(decode, params, logits, state, args.gen)
    gen_s = time.time() - t0
    print(f"arch={cfg.name} quant={args.quant}")
    print(f"prompt tokens/s: {args.batch * args.prompt_len / prefill_s:.1f}")
    print(f"decode tokens/s: {args.batch * args.gen / gen_s:.1f}")
    print("sample:", gen[0].tolist())


if __name__ == "__main__":
    main()
