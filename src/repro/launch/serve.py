"""Serving launcher: batched prefill + decode with optional int8 quantization.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
        --batch 4 --prompt-len 32 --gen 16 [--quant int8]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--quant", default="none", choices=["none", "int8"])
    args = ap.parse_args()

    from repro.configs.registry import get_config
    from repro.models import model_zoo, quant_transformer

    cfg = get_config(args.arch, smoke=args.smoke)
    bundle = model_zoo.build(cfg)
    params, _ = bundle.init(jax.random.PRNGKey(0))
    if args.quant == "int8":
        params = quant_transformer.quantize_param_tree(params)
        bundle = quant_transformer.quantize_bundle(bundle)  # for init_state

    constrain = lambda x, logical=None: x
    prompt = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab_size)

    decode = jax.jit(lambda p, t, s: bundle.decode(p, t, s, constrain))
    state = bundle.init_state(args.batch, args.max_len)
    # prefill by teacher-forcing the prompt through decode (cache warmup)
    tok = prompt[:, :1]
    t0 = time.time()
    for i in range(args.prompt_len):
        logits, state = decode(params, prompt[:, i:i + 1], state)
    jax.block_until_ready(logits)
    prefill_s = time.time() - t0
    out_tokens = []
    t0 = time.time()
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for _ in range(args.gen):
        logits, state = decode(params, tok, state)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    gen_s = time.time() - t0
    gen = jnp.concatenate(out_tokens, axis=1)
    print(f"arch={cfg.name} quant={args.quant}")
    print(f"prompt tokens/s: {args.batch * args.prompt_len / prefill_s:.1f}")
    print(f"decode tokens/s: {args.batch * args.gen / gen_s:.1f}")
    print("sample:", gen[0].tolist())


if __name__ == "__main__":
    main()
