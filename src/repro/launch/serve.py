"""Serving launcher: batched prefill + decode with optional quantization.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
        --batch 4 --prompt-len 32 --gen 16 [--quant int8]

    # the paper's integer-only LSTM path (fused [i|f|z|o] executor):
    PYTHONPATH=src python -m repro.launch.serve --arch lstm-rnnt --smoke \
        --quant int8-lstm --backend interpret
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp


def _scan_prefill(decode, params, prompt, state):
    """Teacher-force the whole prompt through decode in ONE scanned pass.

    Replaces the former per-token python loop (one dispatch per prompt
    position) with a single jitted ``lax.scan``; returns the last-position
    logits and the warmed decode state.
    """

    # first token primes the (B, V) logits carry; the scan then keeps only
    # the latest logits live instead of stacking a (T, B, V) array
    logits, state = decode(params, prompt[:, :1], state)

    def body(carry, tok):
        state, _ = carry
        logits, state = decode(params, tok[:, None], state)
        return (state, logits), None

    (state, logits), _ = jax.lax.scan(
        body, (state, logits), jnp.swapaxes(prompt[:, 1:], 0, 1))
    return logits, state


def _greedy_loop(decode, params, logits, state, n_gen):
    out_tokens = []
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for _ in range(n_gen):
        logits, state = decode(params, tok, state)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    return jnp.concatenate(out_tokens, axis=1)


def _serve_int8_lstm(args, cfg) -> None:
    """Integer-only serving of the stacked LSTM LM (paper sec 3.2 path)."""
    from repro.models import lstm_lm, model_zoo

    if cfg.family != "lstm":
        raise SystemExit(
            f"--quant int8-lstm requires an lstm arch (e.g. lstm-rnnt), "
            f"got {cfg.name} ({cfg.family})")
    bundle = model_zoo.build(cfg)
    params, _ = bundle.init(jax.random.PRNGKey(0))
    calib = jax.random.randint(
        jax.random.PRNGKey(2), (args.batch, max(args.prompt_len, 8)), 0,
        cfg.vocab_size)
    t0 = time.time()
    qlayers = lstm_lm.quantize_stack(params, cfg, calib)
    print(f"calibrated+quantized {len(qlayers)} LSTM layers "
          f"in {time.time() - t0:.1f}s (backend={args.backend})")

    prompt = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0,
        cfg.vocab_size)
    prefill = jax.jit(lambda p, toks, s: lstm_lm.quant_prefill(
        p, qlayers, cfg, toks, s, backend=args.backend))
    decode = jax.jit(lambda p, t, s: lstm_lm.quant_decode_step(
        p, qlayers, cfg, t, s, backend=args.backend))

    state = lstm_lm.init_quant_decode_state(qlayers, args.batch)
    t0 = time.time()
    logits, state = prefill(params, prompt, state)
    jax.block_until_ready(logits)
    prefill_s = time.time() - t0
    t0 = time.time()
    gen = _greedy_loop(decode, params, logits, state, args.gen)
    gen_s = time.time() - t0
    print(f"arch={cfg.name} quant=int8-lstm backend={args.backend}")
    print(f"prompt tokens/s: {args.batch * args.prompt_len / prefill_s:.1f}")
    print(f"decode tokens/s: {args.batch * args.gen / gen_s:.1f}")
    print("sample:", gen[0].tolist())


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--quant", default="none",
                    choices=["none", "int8", "int8-lstm"])
    ap.add_argument("--backend", default="xla",
                    choices=["xla", "pallas", "interpret"],
                    help="integer LSTM kernel backend (int8-lstm only)")
    args = ap.parse_args()
    if args.prompt_len < 1:
        # decode needs at least one teacher-forced token to produce logits
        ap.error("--prompt-len must be >= 1")

    from repro.configs.registry import get_config
    from repro.models import model_zoo, quant_transformer

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.quant == "int8-lstm":
        _serve_int8_lstm(args, cfg)
        return

    bundle = model_zoo.build(cfg)
    params, _ = bundle.init(jax.random.PRNGKey(0))
    if args.quant == "int8":
        params = quant_transformer.quantize_param_tree(params)
        bundle = quant_transformer.quantize_bundle(bundle)  # for init_state

    constrain = lambda x, logical=None: x
    prompt = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0,
        cfg.vocab_size)

    decode = jax.jit(lambda p, t, s: bundle.decode(p, t, s, constrain))
    state = bundle.init_state(args.batch, args.max_len)
    # prefill by teacher-forcing the prompt through decode (cache warmup)
    t0 = time.time()
    logits, state = _scan_prefill(decode, params, prompt, state)
    jax.block_until_ready(logits)
    prefill_s = time.time() - t0
    t0 = time.time()
    gen = _greedy_loop(decode, params, logits, state, args.gen)
    gen_s = time.time() - t0
    print(f"arch={cfg.name} quant={args.quant}")
    print(f"prompt tokens/s: {args.batch * args.prompt_len / prefill_s:.1f}")
    print(f"decode tokens/s: {args.batch * args.gen / gen_s:.1f}")
    print("sample:", gen[0].tolist())


if __name__ == "__main__":
    main()
