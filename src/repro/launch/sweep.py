"""Dry-run sweep driver: every (arch x applicable shape x mesh) cell.

Runs each cell as a subprocess (fresh jax, fresh 512-device flag), resumable
(skips cells whose JSON already exists).  Ordering: multi-pod scan-mode pass
first (the deliverable gate), then single-pod roofline baselines.

    PYTHONPATH=src python -m repro.launch.sweep [--only single|multi]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

OUT_DIR = "experiments/dryrun"


def cells():
    from repro.configs.base import applicable_shapes
    from repro.configs.registry import ASSIGNED, CONFIGS

    # ASSIGNED excludes the recurrent paper-repro LMs; sweep them too
    recurrent = [k for k, c in CONFIGS.items() if c.family == "lstm"]
    for arch in list(ASSIGNED) + recurrent:
        for cell in applicable_shapes(CONFIGS[arch]):
            yield arch, cell.name


def run_one(arch: str, shape: str, mesh: str, layers_mode: str,
            quant: str = "none", timeout: int = 3000, force: bool = False):
    tag = f"{arch}__{shape}__{mesh}" + (f"__{quant}" if quant != "none" else "")
    out = os.path.join(OUT_DIR, tag + ".json")
    if os.path.exists(out) and not force:
        try:
            with open(out) as f:
                if "error" not in json.load(f):
                    return "cached", out
        except Exception:
            pass
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--mesh", mesh, "--layers-mode", layers_mode,
           "--quant", quant, "--out", out]
    env = dict(os.environ, PYTHONPATH="src")
    t0 = time.time()
    try:
        proc = subprocess.run(cmd, capture_output=True, text=True,
                              timeout=timeout, env=env)
        status = "ok" if proc.returncode == 0 else "FAIL"
        if status == "FAIL" and not os.path.exists(out):
            with open(out, "w") as f:
                json.dump({"arch": arch, "shape": shape, "mesh": mesh,
                           "error": proc.stderr[-2000:]}, f)
    except subprocess.TimeoutExpired:
        status = "TIMEOUT"
        with open(out, "w") as f:
            json.dump({"arch": arch, "shape": shape, "mesh": mesh,
                       "error": f"compile timeout {timeout}s"}, f)
    return f"{status}({time.time() - t0:.0f}s)", out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="all", choices=["all", "single", "multi"])
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    os.makedirs(OUT_DIR, exist_ok=True)
    jobs = []
    if args.only in ("all", "multi"):
        # multi-pod coherence pass: scan mode (fast; proves the pod axis)
        for arch, shape in cells():
            jobs.append((arch, shape, "multi", "scan"))
    if args.only in ("all", "single"):
        # single-pod roofline baselines: auto (unroll / extrapolate)
        for arch, shape in cells():
            jobs.append((arch, shape, "single", "auto"))
    print(f"{len(jobs)} cells")
    for i, (arch, shape, mesh, mode) in enumerate(jobs):
        status, out = run_one(arch, shape, mesh, mode, force=args.force)
        print(f"[{i + 1}/{len(jobs)}] {arch} {shape} {mesh} [{mode}]: {status}",
              flush=True)


if __name__ == "__main__":
    main()
