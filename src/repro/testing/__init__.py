"""Deterministic golden-data utilities shared by tests and regen scripts."""
