"""Deterministic golden-case builders for the integer bit-exactness
regression harness (cell-agnostic since PR 8: LSTM and GRU).

Integer decode is fully deterministic, so small golden outputs (int8/int16
tensors and greedy tokens) can be checked into the repo and asserted with
exact equality: any refactor of the fused executor, the recipe, or the
serving engine that silently changes even one low bit fails loudly.

Three golden families:

* **Per-variant layer cases** -- all 16 LSTM topology variants of the paper
  (LN x Proj x PH x CIFG), and both GRU variants (LN x), run through the
  cell-agnostic ``quant_recurrent_layer`` on a fixed seeded input; the
  golden records the full int8 output sequence and every final state leaf.
* **LM decode case** -- a smoke stack (``lstm-rnnt`` or ``gru-rnnt``)
  end-to-end: scanned prefill + greedy decode; the golden records the
  generated token ids and the final per-layer state leaves.
* **Engine decode cases** (GRU goldens) -- a fixed mixed-length workload
  through the continuous-batching engine under a scheduling policy +
  oversubscription ratio; the golden records every stream's emitted tokens
  (which are also asserted against ``decode_single`` in the tests).

Scale derivation happens in float64 numpy offline and calibration runs a
float32 jax forward; both are deterministic for a fixed platform/jax build
(the goldens are generated on the CPU CI platform).  Everything after the
recipe is integer-only and platform-independent.

Regenerate with ``python tests/golden/regen_goldens.py`` after an
*intentional* numerics change, and say so in the commit message.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Tuple

import jax
import numpy as np

from repro.core import cell as C
from repro.core import recipe as R
from repro.core.calibrate import Stats, TapCollector
from repro.models import gru as GR
from repro.models import lstm as L
from repro.models import quant_lstm as QL

# layer-case dims: small enough for a readable JSON diff, big enough to
# exercise packed-matmul tiling and the integer LayerNorm limb math
B, T, D_IN, D_H, D_P = 2, 5, 8, 12, 6

LM_PROMPT_LEN = 6
LM_GEN = 8


def variant_key(variant: L.LSTMVariant) -> str:
    return variant.name


def build_variant_case(variant: L.LSTMVariant, seed: int = 0):
    """Deterministic quantized layer + input for one topology variant."""
    cfg = L.LSTMConfig(D_IN, D_H, D_P if variant.use_projection else 0,
                       variant)
    params = L.init_lstm_params(jax.random.PRNGKey(seed), cfg)
    xs = 0.8 * jax.random.normal(jax.random.PRNGKey(seed + 1), (B, T, D_IN))
    col = TapCollector()
    L.lstm_layer(params, cfg, xs, collector=col)
    stats = Stats()
    stats.merge(jax.device_get(col.snapshot()))
    arrays, spec = R.quantize_lstm_layer(params, cfg, stats)
    xs_q = QL.quantize_input(xs, spec.s_x, spec.zp_x)
    return xs_q, arrays, spec


def execute_case(case, backend: str) -> Dict[str, Any]:
    """Run a built layer case; returns JSON-ready int lists: the output
    sequence under ``"ys"`` plus one entry per final state leaf (LSTM
    ``{"h", "c"}``, GRU ``{"h"}``) -- the pre-PR-8 LSTM schema unchanged."""
    xs_q, arrays, spec = case
    run = jax.jit(lambda a, x: QL.quant_recurrent_layer(
        a, spec, x, backend=backend))
    ys_q, state = run(arrays, xs_q)
    out = {"ys": np.asarray(ys_q).astype(int).tolist()}
    for key, leaf in zip(C.get_cell(spec).state_keys(spec), state):
        out[key] = np.asarray(leaf).astype(int).tolist()
    return out


def run_variant_case(variant: L.LSTMVariant, backend: str = "xla"
                     ) -> Dict[str, Any]:
    """Build + execute one layer case (regen entry point)."""
    return execute_case(build_variant_case(variant), backend)


def gru_variant_key(variant: GR.GRUVariant) -> str:
    return variant.name


def build_gru_variant_case(variant: GR.GRUVariant, seed: int = 0):
    """Deterministic quantized GRU layer + input for one variant."""
    cfg = GR.GRUConfig(D_IN, D_H, variant)
    params = GR.init_gru_params(jax.random.PRNGKey(seed), cfg)
    xs = 0.8 * jax.random.normal(jax.random.PRNGKey(seed + 1), (B, T, D_IN))
    col = TapCollector()
    GR.gru_layer(params, cfg, xs, collector=col)
    stats = Stats()
    stats.merge(jax.device_get(col.snapshot()))
    arrays, spec = R.quantize_gru_layer(params, cfg, stats)
    xs_q = QL.quantize_input(xs, spec.s_x, spec.zp_x)
    return xs_q, arrays, spec


def run_gru_variant_case(variant: GR.GRUVariant, backend: str = "xla"
                         ) -> Dict[str, Any]:
    """Build + execute one GRU layer case (regen entry point)."""
    return execute_case(build_gru_variant_case(variant), backend)


def build_lm_case(arch: str = "lstm-rnnt"
                  ) -> Tuple[Any, Any, Any, np.ndarray]:
    """Deterministic quantized smoke recurrent LM + prompt (params,
    qlayers, cfg, prompt)."""
    from repro.configs.registry import SMOKE_CONFIGS
    from repro.models import lstm_lm, model_zoo

    cfg = SMOKE_CONFIGS[arch]
    bundle = model_zoo.build(cfg)
    params, _ = bundle.init(jax.random.PRNGKey(0))
    calib = jax.random.randint(jax.random.PRNGKey(2), (4, 8), 0,
                               cfg.vocab_size)
    qlayers = lstm_lm.quantize_stack(params, cfg, calib)
    prompt = np.random.default_rng(0).integers(
        0, cfg.vocab_size, size=(LM_PROMPT_LEN,)).astype(np.int32)
    return params, qlayers, cfg, prompt


def run_lm_case(backend: str = "xla", arch: str = "lstm-rnnt"
                ) -> Dict[str, Any]:
    """Greedy-decode the LM case; returns {tokens, <state leaves...>}
    int lists (LSTM: {tokens, h, c}; GRU: {tokens, h})."""
    import jax.numpy as jnp

    from repro.models import lstm_lm

    params, qlayers, cfg, prompt = build_lm_case(arch)
    prefill = jax.jit(lambda p, t, s: lstm_lm.quant_prefill(
        p, qlayers, cfg, t, s, backend=backend))
    decode = jax.jit(lambda p, t, s: lstm_lm.quant_decode_step(
        p, qlayers, cfg, t, s, backend=backend))
    state = lstm_lm.init_quant_decode_state(qlayers, 1)
    logits, state = prefill(params, jnp.asarray(prompt[None]), state)
    tokens = [int(jnp.argmax(logits, -1)[0])]
    for _ in range(LM_GEN - 1):
        tok = jnp.asarray([[tokens[-1]]], jnp.int32)
        logits, state = decode(params, tok, state)
        tokens.append(int(jnp.argmax(logits, -1)[0]))
    out: Dict[str, Any] = {"tokens": tokens}
    for key in (k for k in state if k != "len"):
        out[key] = [np.asarray(leaf).astype(int).tolist()
                    for leaf in state[key]]
    return out


# fixed engine-golden workload: mixed prompt/gen lengths, enough streams to
# force preemption at oversubscribe=2.0 with 4 slots
ENGINE_SLOTS = 4
ENGINE_REQUESTS = 8


def engine_trace(cfg):
    from repro.launch import engine as E

    return E.synthetic_trace(
        ENGINE_REQUESTS, cfg.vocab_size, seed=11,
        prompt_lens=(3, 5, 8), gen_lens=(4, 6, 9))


def run_engine_case(arch: str, policy: str, oversubscribe: float,
                    backend: str = "xla", built=None) -> Dict[str, Any]:
    """Serve the fixed workload through the engine; returns each stream's
    emitted tokens keyed by request id (JSON keys are strings)."""
    from repro.launch import engine as E

    params, qlayers, cfg, _ = built or build_lm_case(arch)
    requests = engine_trace(cfg)
    eng = E.ContinuousBatchingEngine(
        params, qlayers, cfg, n_slots=ENGINE_SLOTS, backend=backend,
        policy=policy, oversubscribe=oversubscribe)
    eng.submit_all(requests)
    results, _ = eng.run()
    return {str(rid): list(res.tokens) for rid, res in sorted(
        results.items())}


def generate_goldens() -> Dict[str, Any]:
    """All LSTM golden cases, generated on the xla backend."""
    out: Dict[str, Any] = {"variants": {}, "lm": run_lm_case(backend="xla")}
    for variant in L.ALL_VARIANTS:
        out["variants"][variant_key(variant)] = run_variant_case(
            variant, backend="xla")
    return out


# engine goldens cover both a plain policy and a preempting one under
# oversubscription -- the pool/preemption path must stay bit-stable too
ENGINE_GOLDEN_CASES = (("fifo", 1.0), ("srf", 2.0))


def generate_gru_goldens() -> Dict[str, Any]:
    """All GRU golden cases (layer variants + LM decode + engine decode),
    generated on the xla backend."""
    out: Dict[str, Any] = {
        "variants": {},
        "lm": run_lm_case(backend="xla", arch="gru-rnnt"),
    }
    for variant in GR.ALL_VARIANTS:
        out["variants"][gru_variant_key(variant)] = run_gru_variant_case(
            variant, backend="xla")
    built = build_lm_case("gru-rnnt")
    out["engine"] = {
        f"{policy}-{ratio}": run_engine_case(
            "gru-rnnt", policy, ratio, backend="xla", built=built)
        for policy, ratio in ENGINE_GOLDEN_CASES
    }
    return out


def write_goldens(path: str, generate=generate_goldens) -> None:
    with open(path, "w") as f:
        json.dump(generate(), f, separators=(",", ":"))
        f.write("\n")


def load_goldens(path: str) -> Dict[str, Any]:
    with open(path) as f:
        return json.load(f)
