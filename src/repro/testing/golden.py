"""Deterministic golden-case builders for the integer LSTM bit-exactness
regression harness.

Integer decode is fully deterministic, so small golden outputs (int8/int16
tensors and greedy tokens) can be checked into the repo and asserted with
exact equality: any refactor of the fused executor, the recipe, or the
serving engine that silently changes even one low bit fails loudly.

Two golden families:

* **Per-variant layer cases** -- all 16 topology variants of the paper
  (LN x Proj x PH x CIFG) run through ``quant_lstm_layer`` on a fixed seeded
  input; the golden records the full int8 output sequence and the final
  ``(h, c)`` carry.
* **LM decode case** -- the smoke ``lstm-rnnt`` stack end-to-end: scanned
  prefill + greedy decode; the golden records the generated token ids and
  the final per-layer ``(h, c)``.

Scale derivation happens in float64 numpy offline and calibration runs a
float32 jax forward; both are deterministic for a fixed platform/jax build
(the goldens are generated on the CPU CI platform).  Everything after the
recipe is integer-only and platform-independent.

Regenerate with ``python tests/golden/regen_goldens.py`` after an
*intentional* numerics change, and say so in the commit message.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Tuple

import jax
import numpy as np

from repro.core import recipe as R
from repro.core.calibrate import Stats, TapCollector
from repro.models import lstm as L
from repro.models import quant_lstm as QL

# layer-case dims: small enough for a readable JSON diff, big enough to
# exercise packed-matmul tiling and the integer LayerNorm limb math
B, T, D_IN, D_H, D_P = 2, 5, 8, 12, 6

LM_PROMPT_LEN = 6
LM_GEN = 8


def variant_key(variant: L.LSTMVariant) -> str:
    return variant.name


def build_variant_case(variant: L.LSTMVariant, seed: int = 0):
    """Deterministic quantized layer + input for one topology variant."""
    cfg = L.LSTMConfig(D_IN, D_H, D_P if variant.use_projection else 0,
                       variant)
    params = L.init_lstm_params(jax.random.PRNGKey(seed), cfg)
    xs = 0.8 * jax.random.normal(jax.random.PRNGKey(seed + 1), (B, T, D_IN))
    col = TapCollector()
    L.lstm_layer(params, cfg, xs, collector=col)
    stats = Stats()
    stats.merge(jax.device_get(col.snapshot()))
    arrays, spec = R.quantize_lstm_layer(params, cfg, stats)
    xs_q = QL.quantize_input(xs, spec.s_x, spec.zp_x)
    return xs_q, arrays, spec


def execute_case(case, backend: str) -> Dict[str, Any]:
    """Run a built layer case; returns JSON-ready {ys, h, c} int lists."""
    xs_q, arrays, spec = case
    run = jax.jit(lambda a, x: QL.quant_lstm_layer(
        a, spec, x, backend=backend))
    ys_q, (h, c) = run(arrays, xs_q)
    return {
        "ys": np.asarray(ys_q).astype(int).tolist(),
        "h": np.asarray(h).astype(int).tolist(),
        "c": np.asarray(c).astype(int).tolist(),
    }


def run_variant_case(variant: L.LSTMVariant, backend: str = "xla"
                     ) -> Dict[str, Any]:
    """Build + execute one layer case (regen entry point)."""
    return execute_case(build_variant_case(variant), backend)


def build_lm_case() -> Tuple[Any, Any, Any, np.ndarray]:
    """Deterministic quantized smoke LSTM LM + prompt (params, qlayers,
    cfg, prompt)."""
    from repro.configs.registry import SMOKE_CONFIGS
    from repro.models import lstm_lm, model_zoo

    cfg = SMOKE_CONFIGS["lstm-rnnt"]
    bundle = model_zoo.build(cfg)
    params, _ = bundle.init(jax.random.PRNGKey(0))
    calib = jax.random.randint(jax.random.PRNGKey(2), (4, 8), 0,
                               cfg.vocab_size)
    qlayers = lstm_lm.quantize_stack(params, cfg, calib)
    prompt = np.random.default_rng(0).integers(
        0, cfg.vocab_size, size=(LM_PROMPT_LEN,)).astype(np.int32)
    return params, qlayers, cfg, prompt


def run_lm_case(backend: str = "xla") -> Dict[str, Any]:
    """Greedy-decode the LM case; returns {tokens, h, c} int lists."""
    import jax.numpy as jnp

    from repro.models import lstm_lm

    params, qlayers, cfg, prompt = build_lm_case()
    prefill = jax.jit(lambda p, t, s: lstm_lm.quant_prefill(
        p, qlayers, cfg, t, s, backend=backend))
    decode = jax.jit(lambda p, t, s: lstm_lm.quant_decode_step(
        p, qlayers, cfg, t, s, backend=backend))
    state = lstm_lm.init_quant_decode_state(qlayers, 1)
    logits, state = prefill(params, jnp.asarray(prompt[None]), state)
    tokens = [int(jnp.argmax(logits, -1)[0])]
    for _ in range(LM_GEN - 1):
        tok = jnp.asarray([[tokens[-1]]], jnp.int32)
        logits, state = decode(params, tok, state)
        tokens.append(int(jnp.argmax(logits, -1)[0]))
    return {
        "tokens": tokens,
        "h": [np.asarray(h).astype(int).tolist() for h in state["h"]],
        "c": [np.asarray(c).astype(int).tolist() for c in state["c"]],
    }


def generate_goldens() -> Dict[str, Any]:
    """All golden cases, generated on the xla backend."""
    out: Dict[str, Any] = {"variants": {}, "lm": run_lm_case(backend="xla")}
    for variant in L.ALL_VARIANTS:
        out["variants"][variant_key(variant)] = run_variant_case(
            variant, backend="xla")
    return out


def write_goldens(path: str) -> None:
    with open(path, "w") as f:
        json.dump(generate_goldens(), f, separators=(",", ":"))
        f.write("\n")


def load_goldens(path: str) -> Dict[str, Any]:
    with open(path) as f:
        return json.load(f)
