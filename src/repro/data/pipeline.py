"""Deterministic synthetic data pipeline: sharded, restartable, seekable.

Streams LM batches with *learnable structure* (per-document affine next-token
rule ``x_{t+1} = (a * x_t + b) mod V`` with noise) so training demonstrably
reduces loss.  The iterator state is a single step counter -- checkpointing
the pipeline is exact and O(1), and any shard of any step is reproducible
from (seed, step, shard), which is what restart/elasticity requires.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    noise: float = 0.05
    frontend_tokens: int = 0  # emit stub frontend embeddings when > 0
    d_model: int = 0


class SyntheticLM:
    """Stateless-per-step batch source; ``state`` is just the step index."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        B, S, V = cfg.global_batch, cfg.seq_len, cfg.vocab_size
        a = rng.integers(1, min(V - 1, 97), (B, 1))
        b = rng.integers(0, V, (B, 1))
        x0 = rng.integers(0, V, (B, 1))
        toks = np.zeros((B, S + 1), np.int64)
        toks[:, :1] = x0
        for t in range(S):
            toks[:, t + 1] = (a[:, 0] * toks[:, t] + b[:, 0]) % V
        flip = rng.random((B, S + 1)) < cfg.noise
        toks = np.where(flip, rng.integers(0, V, (B, S + 1)), toks)
        batch = {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }
        if cfg.frontend_tokens:
            batch["frontend_embeds"] = rng.standard_normal(
                (B, cfg.frontend_tokens, cfg.d_model)
            ).astype(np.float32)
        return batch

    def iterate(self, start_step: int = 0) -> Iterator[Tuple[int, Dict]]:
        step = start_step
        while True:
            yield step, self.batch_at(step)
            step += 1


def shard_batch(batch: Dict[str, np.ndarray], shardings) -> Dict:
    """Host batch -> sharded device arrays per the resolved shardings."""
    return {
        k: jax.device_put(v, shardings[k]) if k in shardings else jnp.asarray(v)
        for k, v in batch.items()
    }
