"""Pallas TPU kernel: integer-only LayerNorm (paper sec 3.2.6, eqs 13-16).

Row-blocked: each grid step owns (block_rows, n) in VMEM, computes the exact
integer statistics (u64 carried as uint32 limb pairs -- no int64 on TPU),
the Newton-Raphson integer rsqrt, the s' = 2**-10 normalization, and the
L (.) x' + b affine with its fixed-point output rescale.

The row length n must fit VMEM: n <= 16384 int16 elements per row is the
library-wide contract (asserted), well within a v5e core's 128 MiB/8 VMEM.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import integer_ops as iops


def _ln_kernel(q_ref, lw_ref, lb_ref, out_ref, *, out_m0: int, out_shift: int):
    q = q_ref[...]
    out_ref[...] = iops.integer_layernorm(
        q, lw_ref[...], lb_ref[...], out_m0, out_shift
    )


@functools.partial(
    jax.jit,
    static_argnames=("out_m0", "out_shift", "block_rows", "interpret"),
)
def int_layernorm_pallas(
    q: jax.Array,  # (B, n) int16
    ln_w_q: jax.Array,  # (n,) int16
    ln_b_q: jax.Array,  # (n,) int32
    *,
    out_m0: int,
    out_shift: int,
    block_rows: int = 8,
    interpret: bool = False,
) -> jax.Array:
    B, n = q.shape
    br = min(block_rows, B)
    assert B % br == 0, (B, br)
    kernel = functools.partial(_ln_kernel, out_m0=out_m0, out_shift=out_shift)
    return pl.pallas_call(
        kernel,
        grid=(B // br,),
        in_specs=[
            pl.BlockSpec((br, n), lambda i: (i, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, n), jnp.int16),
        interpret=interpret,
    )(q, ln_w_q.reshape(1, n), ln_b_q.reshape(1, n))
