"""Pallas TPU kernel: fused integer LSTM element-wise cell update.

Covers the paper's fig 10-12 path: gate activations (sigmoid/tanh via the
gemmlowp barrel-shifter math, sec 3.2.1), the cell update
``c_t = shift(i*z, 30-n) + shift(f*c, 15)`` (sec 3.2.7) and the hidden-state
requantize ``m = rescale(o * tanh(c), 2**-30/s_m) + zp`` -- everything between
the gate matmuls and the projection matmul, in one VMEM-resident pass.

On TPU this fusion matters because the four (B, H) int16 gate tensors and the
int16 cell state would otherwise make five HBM round-trips per step; the
recurrent step is memory-bound, so fusing is a direct paper-motivated win.

Inputs are the already-rescaled int16 Q3.12 gate pre-activations (the matmuls
live in ``int8_matmul.py``); CIFG simply omits the ``i`` input (static flag).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import fixedpoint as fp


def _cell_kernel(
    i_ref,
    f_ref,
    z_ref,
    o_ref,
    c_ref,
    h_out_ref,
    c_out_ref,
    *,
    cell_int_bits: int,
    cifg: bool,
    eff_m: Tuple[int, int],
    zp_m: int,
):
    n_c = 15 - cell_int_bits
    f_act = fp.sigmoid_q15(f_ref[...], 3).astype(jnp.int32)
    z_act = fp.tanh_q15(z_ref[...], 3).astype(jnp.int32)
    if cifg:
        i_act = jnp.minimum(jnp.int32(32768) - f_act, jnp.int32(32767))
    else:
        i_act = fp.sigmoid_q15(i_ref[...], 3).astype(jnp.int32)
    iz = i_act * z_act  # Q0.30
    fc = f_act * c_ref[...].astype(jnp.int32)
    c_new32 = fp.saturating_add_i32(
        fp.rounding_divide_by_pot(iz, 30 - n_c),
        fp.rounding_divide_by_pot(fc, 15),
    )
    c_new = fp.saturate_i16(c_new32)
    o_act = fp.sigmoid_q15(o_ref[...], 3).astype(jnp.int32)
    g_c = fp.tanh_q15(c_new, cell_int_bits).astype(jnp.int32)
    m_raw = o_act * g_c  # Q0.30
    m_q = fp.multiply_by_quantized_multiplier(m_raw, eff_m[0], eff_m[1])
    h_out_ref[...] = fp.saturate_i8(m_q + jnp.int32(zp_m))
    c_out_ref[...] = c_new


@functools.partial(
    jax.jit,
    static_argnames=(
        "cell_int_bits",
        "cifg",
        "eff_m",
        "zp_m",
        "block_b",
        "block_h",
        "interpret",
    ),
)
def quant_lstm_cell_pallas(
    i16: jax.Array,  # (B, H) int16 Q3.12 (ignored when cifg)
    f16: jax.Array,
    z16: jax.Array,
    o16: jax.Array,
    c_q: jax.Array,  # (B, H) int16 Q_{m.15-m}
    *,
    cell_int_bits: int,
    cifg: bool,
    eff_m: Tuple[int, int],
    zp_m: int,
    block_b: int = 8,
    block_h: int = 512,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (m int8, c_new int16).  Elementwise: tiles freely over (B, H)."""
    B, H = f16.shape
    bb, bh = min(block_b, B), min(block_h, H)
    assert B % bb == 0 and H % bh == 0, (B, H, bb, bh)
    grid = (B // bb, H // bh)
    spec = pl.BlockSpec((bb, bh), lambda i, j: (i, j))
    kernel = functools.partial(
        _cell_kernel,
        cell_int_bits=cell_int_bits,
        cifg=cifg,
        eff_m=eff_m,
        zp_m=zp_m,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec] * 5,
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((B, H), jnp.int8),
            jax.ShapeDtypeStruct((B, H), jnp.int16),
        ],
        interpret=interpret,
    )(i16, f16, z16, o16, c_q)
