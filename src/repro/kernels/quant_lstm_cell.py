"""Pallas TPU kernel: fused integer LSTM element-wise cell update.

Covers the paper's fig 10-12 path: gate activations (sigmoid/tanh via the
gemmlowp barrel-shifter math, sec 3.2.1), the cell update
``c_t = shift(i*z, 30-n) + shift(f*c, 15)`` (sec 3.2.7) and the hidden-state
requantize ``m = rescale(o * tanh(c), 2**-30/s_m) + zp`` -- everything between
the gate matmuls and the projection matmul, in one VMEM-resident pass.

On TPU this fusion matters because the four (B, H) int16 gate tensors and the
int16 cell state would otherwise make five HBM round-trips per step; the
recurrent step is memory-bound, so fusing is a direct paper-motivated win.

Inputs are the already-rescaled int16 Q3.12 gate pre-activations (the matmuls
live in ``int8_matmul.py``); CIFG simply omits the ``i`` input (static flag).

o-gate contract (peephole variants)
-----------------------------------
The output-gate peephole reads the NEW cell state (eq 5: ``o = sigma(... +
P_o (.) c_t)``), and ``c_t`` only exists inside this fusion.  Callers
therefore must NOT pre-activate the o gate when the layer has peepholes;
instead they pass the int32 pre-peephole accumulator (``mbqm(acc_x, eff_x)
sat+ mbqm(acc_h, eff_h)``) via ``o_in`` together with ``p_o``/``eff_c_o``
(and, for LayerNorm layers, ``lw_o``/``lb_o``/``ln_out_o``), and the kernel
finishes the gate after computing ``c_new``:

    o32  = sat_add(o_in, mbqm(P_o (.) c_new, eff_c_o))
    o16  = sat16(o32)               -> integer LayerNorm (optional)
    o_act = sigmoid_q15(o16)

When LayerNorm runs in-kernel the block must span the full hidden axis
(LN reduces over H); ``quant_lstm_cell_pallas`` enforces this.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import fixedpoint as fp
from repro.core import integer_ops as iops


def largest_divisor(n: int, cap: int) -> int:
    """Largest divisor of ``n`` that is <= cap (so any (B, H) tiles cleanly)."""
    d = max(min(cap, n), 1)
    while n % d:
        d -= 1
    return d


def finish_o_gate(
    o_in: jax.Array,
    c_new: jax.Array,
    p_o: Optional[jax.Array],
    eff_c_o: Optional[Tuple[int, int]],
    lw_o: Optional[jax.Array],
    lb_o: Optional[jax.Array],
    ln_out_o: Optional[Tuple[int, int]],
) -> jax.Array:
    """Shared o-gate finisher (see module docstring).  Returns int16 Q3.12.

    Without a peephole ``o_in`` is already the final int16 pre-activation
    (LayerNorm, if any, ran outside) and passes through untouched.
    """
    if eff_c_o is None:
        assert ln_out_o is None, "in-fusion o-gate LN requires the peephole"
        return o_in
    acc_c = p_o.astype(jnp.int32) * c_new.astype(jnp.int32)
    o32 = fp.saturating_add_i32(
        o_in, fp.multiply_by_quantized_multiplier(acc_c, *eff_c_o)
    )
    o16 = fp.saturate_i16(o32)
    if ln_out_o is not None:
        o16 = iops.integer_layernorm(o16, lw_o, lb_o, ln_out_o[0], ln_out_o[1])
    return o16


def _cell_kernel(
    *refs,
    cell_int_bits: int,
    cifg: bool,
    eff_m: Tuple[int, int],
    zp_m: int,
    eff_c_o: Optional[Tuple[int, int]],
    ln_o: bool,
    ln_out_o: Optional[Tuple[int, int]],
):
    it = iter(refs)
    i_ref, f_ref, z_ref, o_ref, c_ref = (next(it) for _ in range(5))
    p_ref = next(it) if eff_c_o is not None else None
    lw_ref = next(it) if ln_o else None
    lb_ref = next(it) if ln_o else None
    h_out_ref, c_out_ref = next(it), next(it)

    n_c = 15 - cell_int_bits
    f_act = fp.sigmoid_q15(f_ref[...], 3).astype(jnp.int32)
    z_act = fp.tanh_q15(z_ref[...], 3).astype(jnp.int32)
    if cifg:
        i_act = jnp.minimum(jnp.int32(32768) - f_act, jnp.int32(32767))
    else:
        i_act = fp.sigmoid_q15(i_ref[...], 3).astype(jnp.int32)
    iz = i_act * z_act  # Q0.30
    fc = f_act * c_ref[...].astype(jnp.int32)
    c_new32 = fp.saturating_add_i32(
        fp.rounding_divide_by_pot(iz, 30 - n_c),
        fp.rounding_divide_by_pot(fc, 15),
    )
    c_new = fp.saturate_i16(c_new32)
    o16 = finish_o_gate(
        o_ref[...],
        c_new,
        p_ref[...] if p_ref is not None else None,
        eff_c_o,
        lw_ref[...] if lw_ref is not None else None,
        lb_ref[...] if lb_ref is not None else None,
        ln_out_o,
    )
    o_act = fp.sigmoid_q15(o16, 3).astype(jnp.int32)
    g_c = fp.tanh_q15(c_new, cell_int_bits).astype(jnp.int32)
    m_raw = o_act * g_c  # Q0.30
    m_q = fp.multiply_by_quantized_multiplier(m_raw, eff_m[0], eff_m[1])
    h_out_ref[...] = fp.saturate_i8(m_q + jnp.int32(zp_m))
    c_out_ref[...] = c_new


@functools.partial(
    jax.jit,
    static_argnames=(
        "cell_int_bits",
        "cifg",
        "eff_m",
        "zp_m",
        "eff_c_o",
        "ln_out_o",
        "block_b",
        "block_h",
        "interpret",
    ),
)
def quant_lstm_cell_pallas(
    i16: jax.Array,  # (B, H) int16 Q3.12 (ignored when cifg)
    f16: jax.Array,
    z16: jax.Array,
    o_in: jax.Array,  # (B, H) int16 gate, OR int32 accumulator (peephole)
    c_q: jax.Array,  # (B, H) int16 Q_{m.15-m}
    *,
    cell_int_bits: int,
    cifg: bool,
    eff_m: Tuple[int, int],
    zp_m: int,
    p_o: Optional[jax.Array] = None,  # (H,) int16 peephole weights
    eff_c_o: Optional[Tuple[int, int]] = None,
    lw_o: Optional[jax.Array] = None,  # (H,) int16 LN weight (o gate)
    lb_o: Optional[jax.Array] = None,  # (H,) int32 LN bias (o gate)
    ln_out_o: Optional[Tuple[int, int]] = None,
    block_b: int = 8,
    block_h: int = 512,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (m int8, c_new int16).  Elementwise: tiles freely over (B, H),
    except in-kernel o-gate LayerNorm which pins the block to the full H axis.

    See the module docstring for the o-gate peephole/LayerNorm contract.
    """
    B, H = f16.shape
    if eff_c_o is not None:
        assert p_o is not None and o_in.dtype == jnp.int32, (
            "o-gate peephole fusion takes the int32 pre-peephole accumulator"
        )
    else:
        assert ln_out_o is None, "in-fusion o-gate LN requires the peephole"
    bb = largest_divisor(B, block_b)
    # LN reduces over the full hidden axis: the H tile must cover it.
    bh = H if ln_out_o is not None else largest_divisor(H, block_h)
    grid = (B // bb, H // bh)
    spec = pl.BlockSpec((bb, bh), lambda i, j: (i, j))
    vec_spec = pl.BlockSpec((bh,), lambda i, j: (j,))
    inputs = [i16, f16, z16, o_in, c_q]
    in_specs = [spec] * 5
    ln_o = ln_out_o is not None
    if eff_c_o is not None:
        inputs.append(p_o)
        in_specs.append(vec_spec)
    if ln_o:
        inputs += [lw_o, lb_o]
        in_specs += [vec_spec, vec_spec]
    kernel = functools.partial(
        _cell_kernel,
        cell_int_bits=cell_int_bits,
        cifg=cifg,
        eff_m=eff_m,
        zp_m=zp_m,
        eff_c_o=eff_c_o,
        ln_o=ln_o,
        ln_out_o=ln_out_o,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((B, H), jnp.int8),
            jax.ShapeDtypeStruct((B, H), jnp.int16),
        ],
        interpret=interpret,
    )(*inputs)
