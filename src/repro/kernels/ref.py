"""Pure oracles for every Pallas kernel.

Two tiers:
  * ``*_jnp``: the XLA-path implementations from ``repro.core`` (these are
    themselves validated against numpy), used for allclose kernel tests.
  * ``*_np``:  bit-faithful numpy/int64 references implementing the paper's
    published equations directly (TFLite semantics), used to prove the
    limb-based TPU adaptations are exact.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import fixedpoint as fp
from repro.core import integer_ops as iops


# --- int8 matmul -----------------------------------------------------------


def int8_matmul_jnp(x_q, w_q, fold, m0, shift, out_dtype=jnp.int8, zp_out=0):
    acc = iops.matmul_i8_i32(x_q, w_q) + fold
    if out_dtype == jnp.int32:
        return acc
    y = fp.multiply_by_quantized_multiplier(acc, m0, shift) + jnp.int32(zp_out)
    info = jnp.iinfo(out_dtype)
    return jnp.clip(y, info.min, info.max).astype(out_dtype)


def int8_matmul_np(x_q, w_q, fold):
    """Exact int64 accumulation oracle (pre-rescale)."""
    return (
        x_q.astype(np.int64) @ w_q.astype(np.int64) + fold.astype(np.int64)
    ).astype(np.int64)


# --- fused LSTM cell -------------------------------------------------------


def quant_lstm_cell_jnp(
    i16, f16, z16, o_in, c_q, *, cell_int_bits, cifg, eff_m, zp_m,
    p_o=None, eff_c_o=None, lw_o=None, lb_o=None, ln_out_o=None
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """XLA twin of ``quant_lstm_cell_pallas`` (same o-gate contract: with a
    peephole, ``o_in`` is the int32 pre-peephole accumulator finished against
    ``c_new`` inside this fusion)."""
    from repro.kernels.quant_lstm_cell import finish_o_gate

    n_c = 15 - cell_int_bits
    f_act = fp.sigmoid_q15(f16, 3).astype(jnp.int32)
    z_act = fp.tanh_q15(z16, 3).astype(jnp.int32)
    if cifg:
        i_act = jnp.minimum(jnp.int32(32768) - f_act, jnp.int32(32767))
    else:
        i_act = fp.sigmoid_q15(i16, 3).astype(jnp.int32)
    c_new = fp.saturate_i16(
        fp.saturating_add_i32(
            fp.rounding_divide_by_pot(i_act * z_act, 30 - n_c),
            fp.rounding_divide_by_pot(f_act * c_q.astype(jnp.int32), 15),
        )
    )
    o16 = finish_o_gate(o_in, c_new, p_o, eff_c_o, lw_o, lb_o, ln_out_o)
    o_act = fp.sigmoid_q15(o16, 3).astype(jnp.int32)
    g_c = fp.tanh_q15(c_new, cell_int_bits).astype(jnp.int32)
    m_q = fp.saturate_i8(
        fp.multiply_by_quantized_multiplier(o_act * g_c, eff_m[0], eff_m[1])
        + jnp.int32(zp_m)
    )
    return m_q, c_new


# --- integer layernorm -----------------------------------------------------


def int_layernorm_jnp(q, lw, lb, out_m0, out_shift):
    return iops.integer_layernorm(q, lw, lb, out_m0, out_shift)


# --- recurrent stage of the hoisted-GEMM LSTM executors --------------------


def lstm_gate_preacts(vals, spec, acc_x, acc_h, c_q):
    """Per-step gate pre-activations from the packed int32 accumulators.

    ``acc_x`` is gate column block ``g`` of the (possibly hoisted) input
    product ``x_q @ W_cat + fold_x_cat``; ``acc_h`` the recurrent product.
    Every rescale runs in the reference order (mbqm(x) sat+ mbqm(h)
    [sat+ mbqm(P (.) c)] -> sat16 -> LN), so slicing a time-batched
    ``acc_x`` is bit-identical to computing it per step.

    Returns ``(i16, f16, z16, o_in, o_kw)`` ready for the fused cell --
    with a peephole, ``o_in`` is the int32 pre-peephole o accumulator and
    ``o_kw`` carries the in-cell finisher params (see
    ``kernels/quant_lstm_cell.py``).
    """
    H = spec.cfg_d_hidden
    g16 = {}
    o_kw = {}
    o_in = None
    for k, g in enumerate(spec.variant.gates):
        gs = spec.gate_spec(g)
        gate = fp.saturating_add_i32(
            fp.multiply_by_quantized_multiplier(
                acc_x[..., k * H:(k + 1) * H], *gs.eff_x
            ),
            fp.multiply_by_quantized_multiplier(
                acc_h[..., k * H:(k + 1) * H], *gs.eff_h
            ),
        )
        if g == "o" and spec.use_peephole:
            # eq 5: the o peephole reads c_new, which only exists inside the
            # fused cell -- hand over the int32 accumulator (+ LN params).
            o_in = gate
            o_kw = dict(p_o=vals["P"]["o"], eff_c_o=gs.eff_c)
            if spec.use_layernorm:
                o_kw.update(
                    lw_o=vals["L"]["o"], lb_o=vals["Lb"]["o"],
                    ln_out_o=gs.ln_out,
                )
            continue
        if gs.eff_c is not None:  # i/f peephole on the previous cell state
            acc_c = iops.matmul_i16_elementwise(vals["P"][g], c_q)
            gate = fp.saturating_add_i32(
                gate, fp.multiply_by_quantized_multiplier(acc_c, *gs.eff_c)
            )
        gate16 = fp.saturate_i16(gate)
        if spec.use_layernorm:
            gate16 = iops.integer_layernorm(
                gate16, vals["L"][g], vals["Lb"][g],
                gs.ln_out[0], gs.ln_out[1],
            )
        g16[g] = gate16
    if o_in is None:
        o_in = g16["o"]
    i16 = g16.get("i", g16["f"])  # placeholder when CIFG (cell ignores it)
    return i16, g16["f"], g16["z"], o_in, o_kw


def lstm_project_jnp(vals, spec, m_q):
    """Optional projection: int8 hidden ``m`` -> int8 output ``h``."""
    if not spec.use_projection:
        return m_q
    acc = iops.matmul_i8_i32(m_q, vals["W_proj"]) + vals["fold_proj"]
    h_new = fp.multiply_by_quantized_multiplier(acc, *spec.eff_proj)
    return fp.saturate_i8(h_new + jnp.int32(spec.zp_h_out))


def quant_lstm_recurrent_jnp(vals, spec, acc_x_t, h_q, c_q):
    """Pure-jnp recurrent stage: one timestep given the precomputed input
    accumulator slice.  This is what the persistent Pallas sequence kernel
    (``kernels/quant_lstm_scan.py``) traces inside its body; the ``xla``
    scan body (``ops.quant_lstm_recurrent_step``) shares the same
    ``lstm_gate_preacts`` / ``lstm_project_jnp`` helpers and differs only
    in dispatching the cell fusion through the backend layer, so the two
    lowerings share every gate/projection definition.
    """
    acc_h = iops.matmul_i8_i32(h_q, vals["R_cat"]) + vals["fold_hb_cat"]
    i16, f16, z16, o_in, o_kw = lstm_gate_preacts(
        vals, spec, acc_x_t, acc_h, c_q)
    m_q, c_new = quant_lstm_cell_jnp(
        i16, f16, z16, o_in, c_q,
        cell_int_bits=spec.cell_int_bits, cifg=spec.use_cifg,
        eff_m=spec.eff_m, zp_m=spec.zp_m, **o_kw,
    )
    return lstm_project_jnp(vals, spec, m_q), c_new


# --- recurrent stage of the hoisted-GEMM GRU executor ----------------------


def gru_gate_preacts(vals, spec, acc_x, acc_h):
    """Per-step GRU gate pre-activations from the packed [r|u|n] int32
    accumulators (reset-after form, ``core/recipe.quantize_gru_layer``).

    ``r``/``u`` follow the LSTM gate path exactly: rescale both
    accumulators to the gate scale, saturating-add, sat16, optional LN.
    The candidate ``n`` applies the reset gate to the *rescaled* recurrent
    term before adding the input term -- ``r`` is Q0.15, so
    ``rdp(r * gh16, 15)`` stays at the gate scale -- matching the float
    ``n = tanh(xW + r (.) (hR + b))``.

    Returns ``(r15, u15, n16)``: r/u as Q0.15 sigmoid activations (int32),
    n as the int16 pre-tanh value.
    """

    def block(g):
        k = spec.gate_names.index(g)
        H = spec.cfg_d_hidden
        return acc_x[..., k * H:(k + 1) * H], acc_h[..., k * H:(k + 1) * H]

    def maybe_ln(g, gate16):
        if spec.use_layernorm:
            gs = spec.gate_spec(g)
            return iops.integer_layernorm(
                gate16, vals["L"][g], vals["Lb"][g],
                gs.ln_out[0], gs.ln_out[1],
            )
        return gate16

    acts = {}
    for g in ("r", "u"):
        gs = spec.gate_spec(g)
        ax, ah = block(g)
        gate16 = fp.saturate_i16(
            fp.saturating_add_i32(
                fp.multiply_by_quantized_multiplier(ax, *gs.eff_x),
                fp.multiply_by_quantized_multiplier(ah, *gs.eff_h),
            )
        )
        acts[g] = fp.sigmoid_q15(maybe_ln(g, gate16), 3).astype(jnp.int32)

    gs = spec.gate_spec("n")
    ax, ah = block("n")
    gh16 = fp.saturate_i16(
        fp.multiply_by_quantized_multiplier(ah, *gs.eff_h)
    ).astype(jnp.int32)
    rg = fp.rounding_divide_by_pot(acts["r"] * gh16, 15)
    n16 = fp.saturate_i16(
        fp.saturating_add_i32(
            fp.multiply_by_quantized_multiplier(ax, *gs.eff_x), rg
        )
    )
    return acts["r"], acts["u"], maybe_ln("n", n16)


def quant_gru_recurrent_jnp(vals, spec, acc_x_t, h_q):
    """Pure-jnp GRU recurrent stage: one timestep given the precomputed
    input accumulator slice.  ``h' = u (.) h + (1 - u) (.) n`` runs exactly
    in integers: the carry term needs only a 2**-15 shift (input and output
    hidden share ONE (s, zp) format by construction -- see QGRUSpec), the
    candidate term rescales Q0.30 -> h units.  Both products fit int32
    (|u| <= 2**15, |h - zp| <= 255 -> < 2**23; |(2**15-u)*n| < 2**30).
    """
    acc_h = iops.matmul_i8_i32(h_q, vals["R_cat"]) + vals["fold_hb_cat"]
    _, u15, n16 = gru_gate_preacts(vals, spec, acc_x_t, acc_h)
    n_act = fp.tanh_q15(n16, 3).astype(jnp.int32)
    carry = u15 * (h_q.astype(jnp.int32) - jnp.int32(spec.zp_h))
    blend = (jnp.int32(32768) - u15) * n_act
    h_new = fp.saturating_add_i32(
        fp.multiply_by_quantized_multiplier(carry, *spec.eff_carry),
        fp.multiply_by_quantized_multiplier(blend, *spec.eff_n),
    )
    return fp.saturate_i8(h_new + jnp.int32(spec.zp_h_out))


# --- cell-generic recurrent step (``core/cell.py`` contract) ---------------


def recurrent_step_jnp(vals, spec, acc_x_t, state):
    """One timestep of any registered cell over its flat state tuple.

    ``state`` is ordered per ``cell.state_leaves(spec)``; the returned tuple
    has the same structure and its leaf 0 is the emitted output ``ys[t]``.
    Both sequence executors (the ``xla`` scan and the persistent Pallas
    kernel) trace exactly this function, so adding a cell here ships it on
    every backend at once.
    """
    cell = getattr(spec, "cell", "lstm")
    if cell == "lstm":
        h_new, c_new = quant_lstm_recurrent_jnp(
            vals, spec, acc_x_t, state[0], state[1])
        return (h_new, c_new)
    if cell == "gru":
        return (quant_gru_recurrent_jnp(vals, spec, acc_x_t, state[0]),)
    raise NotImplementedError(f"no recurrent_step_jnp for cell {cell!r}")


def _mbqm_np(x: np.ndarray, m0: int, shift: int) -> np.ndarray:
    """numpy int64 MultiplyByQuantizedMultiplier (gemmlowp semantics)."""
    x = x.astype(np.int64)
    left = max(shift, 0)
    right = max(-shift, 0)
    x = np.clip(x << left, -(2**31), 2**31 - 1)
    ab = x * int(m0)
    nudge = np.where(ab >= 0, 1 << 30, 1 - (1 << 30))
    y = (ab + nudge) // (1 << 31)
    y = np.where(ab + nudge < 0, -((-(ab + nudge)) >> 31), (ab + nudge) >> 31)
    if right:
        mask = (1 << right) - 1
        rem = y & mask
        thr = (mask >> 1) + (y < 0)
        y = (y >> right) + (rem > thr)
    return y


def int_layernorm_np(q, lw, lb, out_m0: int, out_shift: int) -> np.ndarray:
    """Paper eqs 13-16 with exact int64 statistics (TFLite-style oracle).

    Uses float128-free integer math for V = n*Sum(q^2) - Sum(q)^2 and a
    high-precision rsqrt; output differs from the limb/Newton JAX path by at
    most 1 LSB of q' (tested).
    """
    q = q.astype(np.int64)
    n = q.shape[-1]
    sum_q = q.sum(-1, keepdims=True)
    sum_q2 = (q * q).sum(-1, keepdims=True)
    V = n * sum_q2 - sum_q * sum_q
    dev = n * q - sum_q
    with np.errstate(divide="ignore", invalid="ignore"):
        inv = np.where(V > 0, 1.0 / np.sqrt(V.astype(np.float64)), 0.0)
    qprime = np.round(1024.0 * dev * inv).astype(np.int64)
    qprime = np.clip(qprime, -32768, 32767)
    acc = qprime * lw.astype(np.int64) + lb.astype(np.int64)
    acc = np.clip(acc, -(2**31), 2**31 - 1)
    out = _mbqm_np(acc, out_m0, out_shift)
    return np.clip(out, -32768, 32767).astype(np.int16)
