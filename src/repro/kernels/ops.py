"""Jitted public wrappers for the Pallas kernels with backend dispatch.

Backends:
  * ``xla``              -- pure-jnp path (default on CPU; what the multi-pod
                            dry-run lowers so cost_analysis sees real FLOPs).
  * ``pallas``           -- compiled Pallas kernels (TPU runtime target).
  * ``pallas_interpret`` -- Pallas interpreter (CPU correctness validation).

Select globally via ``set_backend`` or per-call with ``backend=``.
"""
from __future__ import annotations

import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import ref
from .int8_matmul import int8_matmul_pallas
from .int_layernorm import int_layernorm_pallas
from .quant_lstm_cell import quant_lstm_cell_pallas

_BACKEND = os.environ.get("REPRO_KERNEL_BACKEND", "xla")
_VALID = ("xla", "pallas", "pallas_interpret")


def set_backend(name: str) -> None:
    global _BACKEND
    assert name in _VALID, name
    _BACKEND = name


def get_backend() -> str:
    return _BACKEND


def _resolve(backend: Optional[str]) -> str:
    b = backend or _BACKEND
    assert b in _VALID, b
    return b


def int8_matmul(
    x_q: jax.Array,
    w_q: jax.Array,
    fold: jax.Array,
    m0: jax.Array,
    shift: jax.Array,
    *,
    out_dtype=jnp.int8,
    zp_out: int = 0,
    backend: Optional[str] = None,
    **block_kw,
) -> jax.Array:
    b = _resolve(backend)
    if b == "xla":
        return ref.int8_matmul_jnp(
            x_q, w_q, fold, m0, shift, out_dtype=out_dtype, zp_out=zp_out
        )
    return int8_matmul_pallas(
        x_q,
        w_q,
        fold,
        m0,
        shift,
        out_dtype=out_dtype,
        zp_out=zp_out,
        interpret=(b == "pallas_interpret"),
        **block_kw,
    )


def quant_lstm_cell(
    i16, f16, z16, o16, c_q, *, cell_int_bits, cifg, eff_m, zp_m,
    backend: Optional[str] = None, **block_kw
) -> Tuple[jax.Array, jax.Array]:
    b = _resolve(backend)
    if b == "xla":
        return ref.quant_lstm_cell_jnp(
            i16, f16, z16, o16, c_q,
            cell_int_bits=cell_int_bits, cifg=cifg, eff_m=eff_m, zp_m=zp_m,
        )
    return quant_lstm_cell_pallas(
        i16, f16, z16, o16, c_q,
        cell_int_bits=cell_int_bits, cifg=cifg, eff_m=eff_m, zp_m=zp_m,
        interpret=(b == "pallas_interpret"), **block_kw,
    )


def int_layernorm(
    q, ln_w_q, ln_b_q, *, out_m0: int, out_shift: int,
    backend: Optional[str] = None, **block_kw
) -> jax.Array:
    b = _resolve(backend)
    if b == "xla":
        return ref.int_layernorm_jnp(q, ln_w_q, ln_b_q, out_m0, out_shift)
    return int_layernorm_pallas(
        q, ln_w_q, ln_b_q, out_m0=out_m0, out_shift=out_shift,
        interpret=(b == "pallas_interpret"), **block_kw,
    )
