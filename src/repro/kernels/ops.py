"""Jitted public wrappers for the Pallas kernels with backend dispatch.

Backends:
  * ``xla``              -- pure-jnp path (default on CPU; what the multi-pod
                            dry-run lowers so cost_analysis sees real FLOPs).
  * ``pallas``           -- compiled Pallas kernels (TPU runtime target).
  * ``pallas_interpret`` -- Pallas interpreter (CPU correctness validation).
                            ``interpret`` is accepted as an alias.

Select globally via ``set_backend`` or per-call with ``backend=``.

Besides the per-kernel wrappers this module hosts the **fused sequence-level
integer LSTM executor** (``quant_lstm_step`` / ``quant_lstm_seq``): each
timestep runs ONE packed ``(B, d_in) x (d_in, G*H)`` int8 MXU matmul plus one
recurrent ``(B, d_out) x (d_out, G*H)`` matmul over the ``[i|f|z|o]``
column-concatenated weights from ``core/recipe.py``, then feeds the fused
``quant_lstm_cell`` elementwise kernel -- 2 ``dot_general`` calls per step
instead of the reference executor's 8, with bit-identical integer results.
"""
from __future__ import annotations

import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import fixedpoint as fp
from repro.core import integer_ops as iops
from . import ref
from .int8_matmul import int8_matmul_pallas
from .int_layernorm import int_layernorm_pallas
from .quant_lstm_cell import quant_lstm_cell_pallas

_BACKEND = os.environ.get("REPRO_KERNEL_BACKEND", "xla")
_VALID = ("xla", "pallas", "pallas_interpret")
_ALIAS = {"interpret": "pallas_interpret"}


def set_backend(name: str) -> None:
    global _BACKEND
    name = _ALIAS.get(name, name)
    assert name in _VALID, name
    _BACKEND = name


def get_backend() -> str:
    return _BACKEND


def _resolve(backend: Optional[str]) -> str:
    b = backend or _BACKEND
    b = _ALIAS.get(b, b)
    assert b in _VALID, b
    return b


def int8_matmul(
    x_q: jax.Array,
    w_q: jax.Array,
    fold: jax.Array,
    m0: jax.Array,
    shift: jax.Array,
    *,
    out_dtype=jnp.int8,
    zp_out: int = 0,
    backend: Optional[str] = None,
    **block_kw,
) -> jax.Array:
    b = _resolve(backend)
    if b == "xla":
        return ref.int8_matmul_jnp(
            x_q, w_q, fold, m0, shift, out_dtype=out_dtype, zp_out=zp_out
        )
    return int8_matmul_pallas(
        x_q,
        w_q,
        fold,
        m0,
        shift,
        out_dtype=out_dtype,
        zp_out=zp_out,
        interpret=(b == "pallas_interpret"),
        **block_kw,
    )


def quant_lstm_cell(
    i16, f16, z16, o_in, c_q, *, cell_int_bits, cifg, eff_m, zp_m,
    p_o=None, eff_c_o=None, lw_o=None, lb_o=None, ln_out_o=None,
    backend: Optional[str] = None, **block_kw
) -> Tuple[jax.Array, jax.Array]:
    """Fused elementwise cell update.  With a peephole layer, ``o_in`` is the
    int32 pre-peephole o-gate accumulator and the gate is finished against
    ``c_new`` inside the fusion (see ``kernels/quant_lstm_cell.py``)."""
    b = _resolve(backend)
    okw = dict(p_o=p_o, eff_c_o=eff_c_o, lw_o=lw_o, lb_o=lb_o,
               ln_out_o=ln_out_o)
    if b == "xla":
        return ref.quant_lstm_cell_jnp(
            i16, f16, z16, o_in, c_q,
            cell_int_bits=cell_int_bits, cifg=cifg, eff_m=eff_m, zp_m=zp_m,
            **okw,
        )
    return quant_lstm_cell_pallas(
        i16, f16, z16, o_in, c_q,
        cell_int_bits=cell_int_bits, cifg=cifg, eff_m=eff_m, zp_m=zp_m,
        interpret=(b == "pallas_interpret"), **okw, **block_kw,
    )


def int_layernorm(
    q, ln_w_q, ln_b_q, *, out_m0: int, out_shift: int,
    backend: Optional[str] = None, **block_kw
) -> jax.Array:
    b = _resolve(backend)
    if b == "xla":
        return ref.int_layernorm_jnp(q, ln_w_q, ln_b_q, out_m0, out_shift)
    return int_layernorm_pallas(
        q, ln_w_q, ln_b_q, out_m0=out_m0, out_shift=out_shift,
        interpret=(b == "pallas_interpret"), **block_kw,
    )


# ---------------------------------------------------------------------------
# Fused sequence-level integer LSTM executor (packed [i|f|z|o] matmuls)
# ---------------------------------------------------------------------------


def quant_lstm_step(
    arrays: Dict[str, Any],
    spec,  # core.recipe.QLSTMSpec (static)
    x_q: jax.Array,  # int8 (B, d_in)
    h_q: jax.Array,  # int8 (B, d_out)
    c_q: jax.Array,  # int16 (B, H)
    *,
    backend: Optional[str] = None,
    **block_kw,
) -> Tuple[jax.Array, jax.Array]:
    """One fused integer LSTM timestep: 2 packed matmuls + fused cell.

    Bit-exact with the reference per-gate executor in
    ``repro.models.quant_lstm`` (slicing column block g of the packed int32
    product is the per-gate matmul; every rescale runs in the same order).
    Returns (h_new int8, c_new int16).
    """
    b = _resolve(backend)
    gates = spec.variant.gates  # [i|f|z|o] order; CIFG drops "i"
    H = spec.cfg_d_hidden
    acc_x = iops.matmul_i8_i32(x_q, arrays["W_cat"]) + arrays["fold_x_cat"]
    acc_h = iops.matmul_i8_i32(h_q, arrays["R_cat"]) + arrays["fold_hb_cat"]

    g16: Dict[str, jax.Array] = {}
    o_kw: Dict[str, Any] = {}
    o_in = None
    for k, g in enumerate(gates):
        gs = spec.gate_spec(g)
        gate = fp.saturating_add_i32(
            fp.multiply_by_quantized_multiplier(
                acc_x[..., k * H:(k + 1) * H], *gs.eff_x
            ),
            fp.multiply_by_quantized_multiplier(
                acc_h[..., k * H:(k + 1) * H], *gs.eff_h
            ),
        )
        if g == "o" and spec.use_peephole:
            # eq 5: the o peephole reads c_new, which only exists inside the
            # fused cell -- hand over the int32 accumulator (+ LN params).
            o_in = gate
            o_kw = dict(p_o=arrays["P"]["o"], eff_c_o=gs.eff_c)
            if spec.use_layernorm:
                o_kw.update(
                    lw_o=arrays["L"]["o"], lb_o=arrays["Lb"]["o"],
                    ln_out_o=gs.ln_out,
                )
            continue
        if gs.eff_c is not None:  # i/f peephole on the previous cell state
            acc_c = iops.matmul_i16_elementwise(arrays["P"][g], c_q)
            gate = fp.saturating_add_i32(
                gate, fp.multiply_by_quantized_multiplier(acc_c, *gs.eff_c)
            )
        gate16 = fp.saturate_i16(gate)
        if spec.use_layernorm:
            gate16 = iops.integer_layernorm(
                gate16, arrays["L"][g], arrays["Lb"][g],
                gs.ln_out[0], gs.ln_out[1],
            )
        g16[g] = gate16
    if o_in is None:
        o_in = g16["o"]
    i16 = g16.get("i", g16["f"])  # placeholder when CIFG (kernel ignores it)

    m_q, c_new = quant_lstm_cell(
        i16, g16["f"], g16["z"], o_in, c_q,
        cell_int_bits=spec.cell_int_bits, cifg=spec.use_cifg,
        eff_m=spec.eff_m, zp_m=spec.zp_m, backend=b, **o_kw, **block_kw,
    )
    if spec.use_projection:
        acc = iops.matmul_i8_i32(m_q, arrays["W_proj"]) + arrays["fold_proj"]
        h_new = fp.multiply_by_quantized_multiplier(acc, *spec.eff_proj)
        h_new = fp.saturate_i8(h_new + jnp.int32(spec.zp_h_out))
    else:
        h_new = m_q
    return h_new, c_new


def quant_lstm_seq(
    arrays: Dict[str, Any],
    spec,
    xs_q: jax.Array,  # int8 (B, T, d_in)
    h0_q: jax.Array,
    c0_q: jax.Array,
    *,
    backend: Optional[str] = None,
    **block_kw,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Scan ``quant_lstm_step`` over time: int8 (B, T, d_in) -> (B, T, d_out)."""
    b = _resolve(backend)

    def step(carry, x_t):
        h, c = carry
        h, c = quant_lstm_step(
            arrays, spec, x_t, h, c, backend=b, **block_kw
        )
        return (h, c), h

    (h, c), ys = jax.lax.scan(step, (h0_q, c0_q), jnp.swapaxes(xs_q, 0, 1))
    return jnp.swapaxes(ys, 0, 1), (h, c)


def quant_lstm_seq_masked(
    arrays: Dict[str, Any],
    spec,
    xs_q: jax.Array,  # int8 (B, T, d_in)
    h0_q: jax.Array,
    c0_q: jax.Array,
    valid_len: jax.Array,  # int32 (B,), per-row number of live timesteps
    *,
    backend: Optional[str] = None,
    **block_kw,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Ragged-length fused executor: row b advances only for t < valid_len[b].

    The chunked-prefill workhorse: a ``(B, K)`` token block where every row
    owns a different number of real tokens (a slot mid-generation feeds 1, a
    slot with 3 prompt tokens left feeds 3, an empty slot feeds 0).  Each
    timestep runs the same ``quant_lstm_step`` as the unmasked scan and then
    freezes ``(h, c)`` for rows already past their valid length, so a row's
    state trajectory is **bitwise identical** to feeding its valid prefix one
    token at a time -- rows are computed independently (per-row matmuls, LN
    reduces over hidden only) and ``where`` with a true mask returns the new
    value unchanged.  Frozen rows burn compute on stale inputs but their
    results are discarded, which is what keeps the program shape static.
    """
    b = _resolve(backend)

    def step(carry, inp):
        h, c = carry
        x_t, t = inp
        h_new, c_new = quant_lstm_step(
            arrays, spec, x_t, h, c, backend=b, **block_kw
        )
        live = (t < valid_len)[:, None]
        h = jnp.where(live, h_new, h)
        c = jnp.where(live, c_new, c)
        return (h, c), h

    T = xs_q.shape[1]
    ts = jnp.arange(T, dtype=valid_len.dtype)
    (h, c), ys = jax.lax.scan(
        step, (h0_q, c0_q), (jnp.swapaxes(xs_q, 0, 1), ts))
    return jnp.swapaxes(ys, 0, 1), (h, c)
