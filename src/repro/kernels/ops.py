"""Jitted public wrappers for the Pallas kernels with backend dispatch.

Backends:
  * ``xla``              -- pure-jnp path (default on CPU; what the multi-pod
                            dry-run lowers so cost_analysis sees real FLOPs).
  * ``pallas``           -- compiled Pallas kernels (TPU runtime target).
  * ``pallas_interpret`` -- Pallas interpreter (CPU correctness validation).
                            ``interpret`` is accepted as an alias.

Select globally via ``set_backend`` or per-call with ``backend=``.

Besides the per-kernel wrappers this module hosts the **fused sequence-level
integer recurrent executors** -- cell-agnostic since PR 8
(``core/cell.py``): a quantized layer is ``(arrays, spec)`` with
``spec.cell`` naming the cell, and its state is the flat tuple declared by
``cell.state_leaves(spec)``.  They run in two stages (PR 4 structure):

  1. **input-projection stage** (``quant_recurrent_input_proj``): the whole
     sequence's input product ``reshape(xs_q, (B*T, d_in)) @ W_cat +
     fold_x_cat`` as ONE time-batched int8 MXU GEMM -- it does not depend on
     the scan carry, and integer arithmetic makes hoisting it out of the
     recurrent loop bit-exact by construction;
  2. **recurrent stage** (``quant_recurrent_step``): per timestep, one
     packed ``(B, d_out) x (d_out, G*H)`` recurrent matmul over the
     column-concatenated gate weights from ``core/recipe.py`` (LSTM
     ``[i|f|z|o]``, GRU ``[r|u|n]``) plus the cell's elementwise update,
     consuming the per-step ``(B, G*H)`` int32 slice of the hoisted
     accumulator.

``quant_recurrent_seq`` / ``quant_recurrent_seq_masked`` lower the
recurrent stage as a ``lax.scan`` on the ``xla`` backend and as the
**persistent Pallas sequence kernel** (``kernels/quant_lstm_scan.py``: one
``pallas_call`` looping over T with the state tuple resident in VMEM
scratch) on ``pallas`` / ``pallas_interpret``.
``quant_recurrent_seq_stepwise`` keeps the pre-hoist executor (input GEMM
inside the scan body) as the baseline that tests and
``benchmarks/prefill_throughput.py`` compare against -- all paths are
bit-identical.  The ``quant_lstm_*`` names are kept as LSTM-shaped wrappers
threading ``(h0, c0)`` explicitly.
"""
from __future__ import annotations

import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import fixedpoint as fp
from repro.core import integer_ops as iops
from . import ref
from .int8_matmul import int8_matmul_pallas
from .int_layernorm import int_layernorm_pallas
from .quant_lstm_cell import quant_lstm_cell_pallas
from .quant_lstm_scan import quant_recurrent_seq_scan_pallas

_BACKEND = os.environ.get("REPRO_KERNEL_BACKEND", "xla")
_VALID = ("xla", "pallas", "pallas_interpret")
_ALIAS = {"interpret": "pallas_interpret"}


def set_backend(name: str) -> None:
    """Select the global kernel backend (``interpret`` aliases
    ``pallas_interpret``).  Raises ``ValueError`` on unknown names -- a
    plain raise, not ``assert``, so the check survives ``python -O``."""
    global _BACKEND
    name = _ALIAS.get(name, name)
    if name not in _VALID:
        raise ValueError(
            f"unknown kernel backend {name!r}: valid backends are "
            f"{_VALID} (alias 'interpret' -> 'pallas_interpret')")
    _BACKEND = name


def get_backend() -> str:
    return _BACKEND


def _resolve(backend: Optional[str]) -> str:
    b = backend or _BACKEND
    b = _ALIAS.get(b, b)
    if b not in _VALID:
        raise ValueError(
            f"unknown kernel backend {b!r}: valid backends are "
            f"{_VALID} (alias 'interpret' -> 'pallas_interpret')")
    return b


def int8_matmul(
    x_q: jax.Array,
    w_q: jax.Array,
    fold: jax.Array,
    m0: jax.Array,
    shift: jax.Array,
    *,
    out_dtype=jnp.int8,
    zp_out: int = 0,
    backend: Optional[str] = None,
    **block_kw,
) -> jax.Array:
    b = _resolve(backend)
    if b == "xla":
        return ref.int8_matmul_jnp(
            x_q, w_q, fold, m0, shift, out_dtype=out_dtype, zp_out=zp_out
        )
    return int8_matmul_pallas(
        x_q,
        w_q,
        fold,
        m0,
        shift,
        out_dtype=out_dtype,
        zp_out=zp_out,
        interpret=(b == "pallas_interpret"),
        **block_kw,
    )


def quant_lstm_cell(
    i16, f16, z16, o_in, c_q, *, cell_int_bits, cifg, eff_m, zp_m,
    p_o=None, eff_c_o=None, lw_o=None, lb_o=None, ln_out_o=None,
    backend: Optional[str] = None, **block_kw
) -> Tuple[jax.Array, jax.Array]:
    """Fused elementwise cell update.  With a peephole layer, ``o_in`` is the
    int32 pre-peephole o-gate accumulator and the gate is finished against
    ``c_new`` inside the fusion (see ``kernels/quant_lstm_cell.py``)."""
    b = _resolve(backend)
    okw = dict(p_o=p_o, eff_c_o=eff_c_o, lw_o=lw_o, lb_o=lb_o,
               ln_out_o=ln_out_o)
    if b == "xla":
        return ref.quant_lstm_cell_jnp(
            i16, f16, z16, o_in, c_q,
            cell_int_bits=cell_int_bits, cifg=cifg, eff_m=eff_m, zp_m=zp_m,
            **okw,
        )
    return quant_lstm_cell_pallas(
        i16, f16, z16, o_in, c_q,
        cell_int_bits=cell_int_bits, cifg=cifg, eff_m=eff_m, zp_m=zp_m,
        interpret=(b == "pallas_interpret"), **okw, **block_kw,
    )


def int_layernorm(
    q, ln_w_q, ln_b_q, *, out_m0: int, out_shift: int,
    backend: Optional[str] = None, **block_kw
) -> jax.Array:
    b = _resolve(backend)
    if b == "xla":
        return ref.int_layernorm_jnp(q, ln_w_q, ln_b_q, out_m0, out_shift)
    return int_layernorm_pallas(
        q, ln_w_q, ln_b_q, out_m0=out_m0, out_shift=out_shift,
        interpret=(b == "pallas_interpret"), **block_kw,
    )


# ---------------------------------------------------------------------------
# Fused sequence-level integer recurrent executors (packed gate matmuls),
# two-stage since PR 4: hoisted time-batched input GEMM -> recurrent scan.
# Cell-agnostic since PR 8: state is the flat tuple from core/cell.py.
# ---------------------------------------------------------------------------


def _empty_seq(xs_q, state0):
    """T == 0 result: no outputs, initial carry (a grid=(0,) pallas_call
    would never write its final-state blocks, so short-circuit uniformly)."""
    B = xs_q.shape[0]
    ys = jnp.zeros((B, 0, state0[0].shape[-1]), state0[0].dtype)
    return ys, tuple(state0)


def quant_recurrent_input_proj(
    arrays: Dict[str, Any],
    xs_q: jax.Array,  # int8 (B, T, d_in)
) -> jax.Array:
    """Hoisted input-projection stage: the whole sequence's packed input
    accumulator ``reshape(xs_q, (B*T, d_in)) @ W_cat + fold_x_cat`` as ONE
    int8 MXU GEMM -> int32 ``(B, T, G*H)``.

    ``x_t @ W_cat`` is carry-independent, and integer accumulation is exact
    under any batching, so slicing step t of this tensor is bit-identical to
    the per-step matmul the pre-hoist executor ran inside the scan -- while
    raising the GEMM's arithmetic intensity from one ``(B, d_in)`` row-block
    per dispatch to the full ``(B*T, d_in)`` sequence.  The packed layout is
    the same for every cell, so this stage needs no dispatch at all.
    """
    B, T, d_in = xs_q.shape
    GH = arrays["W_cat"].shape[1]  # explicit: reshape(-1) rejects T == 0
    acc = iops.matmul_i8_i32(
        xs_q.reshape(B * T, d_in), arrays["W_cat"]
    ) + arrays["fold_x_cat"]
    return acc.reshape(B, T, GH)


quant_lstm_input_proj = quant_recurrent_input_proj  # pre-PR-8 name


def _cell_recurrent_step(arrays, spec, acc_x_t, state, backend, block_kw):
    """One cell step from the hoisted accumulator slice -> new state tuple.

    The LSTM routes through ``quant_lstm_recurrent_step`` so its fused
    elementwise cell kernel still honours per-call backend dispatch on the
    ``xla``-scan path; other cells run ``ref.recurrent_step_jnp`` directly
    (their ``pallas`` lowering is the persistent sequence kernel, which
    traces the very same function).
    """
    if getattr(spec, "cell", "lstm") == "lstm":
        h, c = quant_lstm_recurrent_step(
            arrays, spec, acc_x_t, state[0], state[1],
            backend=backend, **block_kw)
        return (h, c)
    return ref.recurrent_step_jnp(arrays, spec, acc_x_t, state)


def quant_lstm_recurrent_step(
    arrays: Dict[str, Any],
    spec,  # core.recipe.QLSTMSpec (static)
    acc_x_t: jax.Array,  # int32 (B, G*H): step slice of the hoisted GEMM
    h_q: jax.Array,  # int8 (B, d_out)
    c_q: jax.Array,  # int16 (B, H)
    *,
    backend: Optional[str] = None,
    **block_kw,
) -> Tuple[jax.Array, jax.Array]:
    """Recurrent stage of one timestep: packed recurrent matmul + gate
    rescales + fused cell (+ projection), consuming the precomputed input
    accumulator slice.  Returns (h_new int8, c_new int16).

    Bit-exact with the reference per-gate executor in
    ``repro.models.quant_lstm`` (slicing column block g of the packed int32
    product is the per-gate matmul; every rescale runs in the same order).
    """
    b = _resolve(backend)
    acc_h = iops.matmul_i8_i32(h_q, arrays["R_cat"]) + arrays["fold_hb_cat"]
    i16, f16, z16, o_in, o_kw = ref.lstm_gate_preacts(
        arrays, spec, acc_x_t, acc_h, c_q)
    m_q, c_new = quant_lstm_cell(
        i16, f16, z16, o_in, c_q,
        cell_int_bits=spec.cell_int_bits, cifg=spec.use_cifg,
        eff_m=spec.eff_m, zp_m=spec.zp_m, backend=b, **o_kw, **block_kw,
    )
    return ref.lstm_project_jnp(arrays, spec, m_q), c_new


def quant_recurrent_step(
    arrays: Dict[str, Any],
    spec,  # core.recipe.Q*Spec (static, names the cell)
    x_q: jax.Array,  # int8 (B, d_in)
    state: Tuple[jax.Array, ...],  # per cell.state_leaves(spec)
    *,
    backend: Optional[str] = None,
    **block_kw,
) -> Tuple[jax.Array, ...]:
    """One fused integer recurrent timestep: 2 packed matmuls + cell update.

    The single-token (decode) entry point for any registered cell:
    input-projection and recurrent stages run back to back on one
    ``(B, d_in)`` token block.  Returns the new state tuple; leaf 0 is the
    emitted output.
    """
    b = _resolve(backend)
    acc_x = iops.matmul_i8_i32(x_q, arrays["W_cat"]) + arrays["fold_x_cat"]
    return _cell_recurrent_step(arrays, spec, acc_x, tuple(state), b, block_kw)


def quant_recurrent_seq(
    arrays: Dict[str, Any],
    spec,
    xs_q: jax.Array,  # int8 (B, T, d_in)
    state0: Tuple[jax.Array, ...],
    *,
    backend: Optional[str] = None,
    **block_kw,
) -> Tuple[jax.Array, Tuple[jax.Array, ...]]:
    """Hoisted sequence executor: int8 (B, T, d_in) -> (B, T, d_out).

    Stage 1 runs the whole sequence's input GEMM once
    (``quant_recurrent_input_proj``); stage 2 consumes per-step ``(B, G*H)``
    slices -- as a ``lax.scan`` of the cell step on the ``xla`` backend, or
    as the persistent Pallas sequence kernel (one ``pallas_call`` looping
    over T with the state tuple in VMEM scratch) on ``pallas`` /
    ``pallas_interpret``.  All lowerings are bit-identical to
    ``quant_recurrent_seq_stepwise`` (``block_kw`` only reaches the LSTM's
    per-step cell kernel on that path; the sequence kernel ignores it).
    """
    b = _resolve(backend)
    state0 = tuple(state0)
    if xs_q.shape[1] == 0:  # empty sequence: carry unchanged, like the scan
        return _empty_seq(xs_q, state0)
    acc_x_all = quant_recurrent_input_proj(arrays, xs_q)
    if b != "xla":
        return quant_recurrent_seq_scan_pallas(
            arrays, spec, acc_x_all, state0,
            interpret=(b == "pallas_interpret"))

    def step(carry, acc_t):
        new = _cell_recurrent_step(arrays, spec, acc_t, carry, b, block_kw)
        return new, new[0]

    state, ys = jax.lax.scan(step, state0, jnp.swapaxes(acc_x_all, 0, 1))
    return jnp.swapaxes(ys, 0, 1), state


def quant_recurrent_seq_stepwise(
    arrays: Dict[str, Any],
    spec,
    xs_q: jax.Array,  # int8 (B, T, d_in)
    state0: Tuple[jax.Array, ...],
    *,
    backend: Optional[str] = None,
    **block_kw,
) -> Tuple[jax.Array, Tuple[jax.Array, ...]]:
    """Pre-hoist executor: scan ``quant_recurrent_step`` with the input GEMM
    inside the scan body (one small ``(B, d_in)`` matmul per step).

    Kept as the baseline the hoisted executors are tested bit-exact against
    and benchmarked over (``benchmarks/prefill_throughput.py``); not on any
    serving path.
    """
    b = _resolve(backend)

    def step(carry, x_t):
        new = quant_recurrent_step(
            arrays, spec, x_t, carry, backend=b, **block_kw)
        return new, new[0]

    state, ys = jax.lax.scan(
        step, tuple(state0), jnp.swapaxes(xs_q, 0, 1))
    return jnp.swapaxes(ys, 0, 1), state


def quant_recurrent_seq_masked(
    arrays: Dict[str, Any],
    spec,
    xs_q: jax.Array,  # int8 (B, T, d_in)
    state0: Tuple[jax.Array, ...],
    valid_len: jax.Array,  # int32 (B,), per-row number of live timesteps
    *,
    backend: Optional[str] = None,
    **block_kw,
) -> Tuple[jax.Array, Tuple[jax.Array, ...]]:
    """Ragged-length fused executor: row b advances only for t < valid_len[b].

    The chunked-prefill workhorse: a ``(B, K)`` token block where every row
    owns a different number of real tokens (a slot mid-generation feeds 1, a
    slot with 3 prompt tokens left feeds 3, an empty slot feeds 0).  The
    input GEMM is hoisted exactly as in ``quant_recurrent_seq`` (dead
    positions burn GEMM flops on stale inputs, but their results are
    discarded, which is what keeps the program shape static); each recurrent
    step then freezes every state leaf for rows already past their valid
    length, so a row's state trajectory is **bitwise identical** to feeding
    its valid prefix one token at a time -- rows are computed independently
    (per-row matmuls, LN reduces over hidden only) and ``where`` with a true
    mask returns the new value unchanged.  As in ``quant_recurrent_seq``,
    ``block_kw`` only reaches the LSTM's per-step cell kernel on the ``xla``
    scan path; the sequence kernel ignores it.
    """
    b = _resolve(backend)
    state0 = tuple(state0)
    if xs_q.shape[1] == 0:  # empty sequence: carry unchanged, like the scan
        return _empty_seq(xs_q, state0)
    acc_x_all = quant_recurrent_input_proj(arrays, xs_q)
    if b != "xla":
        return quant_recurrent_seq_scan_pallas(
            arrays, spec, acc_x_all, state0, valid_len,
            interpret=(b == "pallas_interpret"))

    def step(carry, inp):
        acc_t, t = inp
        new = _cell_recurrent_step(arrays, spec, acc_t, carry, b, block_kw)
        live = (t < valid_len)[:, None]
        frozen = tuple(
            jnp.where(live, n, o) for n, o in zip(new, carry))
        return frozen, frozen[0]

    T = xs_q.shape[1]
    ts = jnp.arange(T, dtype=valid_len.dtype)
    state, ys = jax.lax.scan(
        step, state0, (jnp.swapaxes(acc_x_all, 0, 1), ts))
    return jnp.swapaxes(ys, 0, 1), state


# -- LSTM-shaped wrappers (pre-PR-8 signatures, thread (h0, c0) explicitly) --


def quant_lstm_step(
    arrays: Dict[str, Any],
    spec,  # core.recipe.QLSTMSpec (static)
    x_q: jax.Array,  # int8 (B, d_in)
    h_q: jax.Array,  # int8 (B, d_out)
    c_q: jax.Array,  # int16 (B, H)
    *,
    backend: Optional[str] = None,
    **block_kw,
) -> Tuple[jax.Array, jax.Array]:
    """One fused integer LSTM timestep: 2 packed matmuls + fused cell."""
    h, c = quant_recurrent_step(
        arrays, spec, x_q, (h_q, c_q), backend=backend, **block_kw)
    return h, c


def quant_lstm_seq(
    arrays: Dict[str, Any],
    spec,
    xs_q: jax.Array,  # int8 (B, T, d_in)
    h0_q: jax.Array,
    c0_q: jax.Array,
    *,
    backend: Optional[str] = None,
    **block_kw,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Hoisted LSTM sequence executor (see ``quant_recurrent_seq``)."""
    return quant_recurrent_seq(
        arrays, spec, xs_q, (h0_q, c0_q), backend=backend, **block_kw)


def quant_lstm_seq_stepwise(
    arrays: Dict[str, Any],
    spec,
    xs_q: jax.Array,  # int8 (B, T, d_in)
    h0_q: jax.Array,
    c0_q: jax.Array,
    *,
    backend: Optional[str] = None,
    **block_kw,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Pre-hoist LSTM executor (see ``quant_recurrent_seq_stepwise``)."""
    return quant_recurrent_seq_stepwise(
        arrays, spec, xs_q, (h0_q, c0_q), backend=backend, **block_kw)


def quant_lstm_seq_masked(
    arrays: Dict[str, Any],
    spec,
    xs_q: jax.Array,  # int8 (B, T, d_in)
    h0_q: jax.Array,
    c0_q: jax.Array,
    valid_len: jax.Array,  # int32 (B,), per-row number of live timesteps
    *,
    backend: Optional[str] = None,
    **block_kw,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Ragged-length LSTM executor (see ``quant_recurrent_seq_masked``)."""
    return quant_recurrent_seq_masked(
        arrays, spec, xs_q, (h0_q, c0_q), valid_len,
        backend=backend, **block_kw)
