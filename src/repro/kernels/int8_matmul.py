"""Pallas TPU kernel: tiled int8 x int8 -> int32 matmul with fused requantize.

The paper's hot loop (sec 6) is ``Sum_k W[k,n] * x[m,k] + b'[n]`` feeding a
fixed-point rescale.  On TPU the int8 operands hit the MXU (2x bf16
throughput) and the rescale runs on the VPU in the same kernel, so the int32
accumulator never round-trips to HBM -- that is the TPU analogue of the
paper's "no on-the-fly dequantization" principle.

Tiling: grid (M/bm, N/bn, K/bk) with an (bm, bn) int32 VMEM accumulator;
K is the innermost (arbitrary) dimension, M/N are parallel.  Block shapes
default to MXU-aligned 128 multiples; VMEM working set is
bm*bk + bk*bn (int8) + bm*bn*4 (acc) bytes.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import fixedpoint as fp


def _kernel(
    x_ref,
    w_ref,
    fold_ref,
    m0_ref,
    shift_ref,
    out_ref,
    acc_ref,
    *,
    k_steps: int,
    out_dtype,
    zp_out: int,
):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...],
        w_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _finish():
        acc = acc_ref[...] + fold_ref[...]  # folded zero-point + bias (sec 6)
        if out_dtype == jnp.int32:
            out_ref[...] = acc
        else:
            y = fp.multiply_by_quantized_multiplier(
                acc, m0_ref[...], shift_ref[...]
            )
            y = y + jnp.int32(zp_out)
            info = jnp.iinfo(out_dtype)
            out_ref[...] = jnp.clip(y, info.min, info.max).astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "block_m",
        "block_n",
        "block_k",
        "out_dtype",
        "zp_out",
        "interpret",
    ),
)
def int8_matmul_pallas(
    x_q: jax.Array,  # (M, K) int8
    w_q: jax.Array,  # (K, N) int8
    fold: jax.Array,  # (N,) int32 -- folded zero-point correction + bias
    m0: jax.Array,  # (N,) int32 per-channel multiplier mantissa
    shift: jax.Array,  # (N,) int32 per-channel multiplier exponent
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    out_dtype=jnp.int8,
    zp_out: int = 0,
    interpret: bool = False,
) -> jax.Array:
    M, K = x_q.shape
    K2, N = w_q.shape
    assert K == K2
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (
        f"shapes ({M},{K})x({K},{N}) must tile by ({bm},{bn},{bk})"
    )
    k_steps = K // bk
    grid = (M // bm, N // bn, k_steps)
    kernel = functools.partial(
        _kernel, k_steps=k_steps, out_dtype=out_dtype, zp_out=zp_out
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(
        x_q,
        w_q,
        fold.reshape(1, N),
        m0.reshape(1, N),
        shift.reshape(1, N),
    )
