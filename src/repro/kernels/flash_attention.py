"""Pallas TPU kernel: blockwise online-softmax attention (forward).

The TPU-target form of ``repro.layers.attention.flash_attention``: logits,
running max and denominator stay in VMEM scratch; only Q/K/V/O touch HBM.
This kernel is what the roofline's fused-attention memory correction models
(DESIGN.md sec 2); the XLA path remains the autodiff/serving default.

Grid: (batch*heads, Sq/block_q) parallel x (Sk/block_k) arbitrary; one
(block_q, head_dim) f32 accumulator + (block_q,) running stats per step.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            k_steps: int, block_q: int, block_k: int, causal: bool,
            window: int, scale: float):
    ki = pl.program_id(2)
    qi = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]  # (block_q, D)
    k = k_ref[0]  # (block_k, D)
    v = v_ref[0]
    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale
    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window > 0:
        mask = mask & (k_pos > q_pos - window)
    logits = jnp.where(mask, logits, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, logits.max(axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new[:, None])
    l_new = l_prev * alpha + p.sum(axis=1)
    pv = jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == k_steps - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"),
)
def flash_attention_pallas(
    q: jax.Array,  # (BH, Sq, D) -- batch*heads flattened
    k: jax.Array,  # (BH, Sk, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    BH, Sq, D = q.shape
    Sk = k.shape[1]
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, Sk, bq, bk)
    k_steps = Sk // bk
    grid = (BH, Sq // bq, k_steps)
    scale = 1.0 / np.sqrt(D)
    kernel = functools.partial(
        _kernel, k_steps=k_steps, block_q=bq, block_k=bk, causal=causal,
        window=window, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
