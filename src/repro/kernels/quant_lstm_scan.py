"""Persistent Pallas sequence kernel: the integer recurrent stage, any cell.

One ``pallas_call`` runs the ENTIRE sequence: the grid is ``(T,)`` (TPU grid
iteration is sequential), every recurrent-stage array (packed weights,
peephole / LN / projection parameters -- whatever the cell's quantizer
emitted) is mapped to a constant-index block so it stays resident in VMEM
across steps, and the cell's flat state tuple (``core/cell.py``:
``state_leaves``) lives in VMEM scratch for the whole sweep -- one scratch
buffer per leaf, seeded at ``t == 0``.  Each grid step fuses

    recurrent matmul (int8 MXU)  ->  per-gate fixed-point rescales
    [-> integer LayerNorm / peephole]  ->  cell update
    [-> projection matmul]  ->  write ys[t], update the carry

which eliminates the per-timestep dispatch overhead and the per-step state
HBM round-trips the scan-of-steps executor pays: between consecutive
timesteps nothing leaves VMEM.  The input-dependent work arrives
precomputed -- the kernel consumes per-step ``(B, 1, G*H)`` int32 blocks of
the hoisted time-batched input GEMM (``ops.quant_recurrent_input_proj``),
so the only matmul on the critical scan path is the genuinely sequential
``h_{t-1} @ R_cat`` product.

The step math is ``ref.recurrent_step_jnp`` -- the same cell dispatch the
``xla`` scan executor runs -- traced inside the kernel body, so the two
lowerings are bit-identical by construction (integer ops only; validated
against the goldens and the per-gate reference for all 16 LSTM variants and
both GRU variants).  The cell's arrays dict is flattened with
``jax.tree_util`` (deterministic key order) into one ref per leaf and
rebuilt inside the kernel, so a new cell needs NO kernel changes: whatever
``quantize_<cell>_layer`` packs simply rides along into VMEM.

The masked variant takes a per-row ``valid_len`` and freezes every state
leaf for rows past their valid prefix -- the chunked-prefill contract of
``ops.quant_recurrent_seq_masked``.

Sizing note: blocks span the full ``(B, ...)`` extents (integer LayerNorm
reduces over the whole hidden axis, and the carry must stay resident), so
``B * (G*H)`` int32 plus the packed weights must fit in VMEM; serving-shape
blocks (B <= 64, H <= 2048) do.  Time is the grid, so T is unbounded.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import cell as C

from . import ref

# Consumed by the hoisted input GEMM, never by the recurrent stage.
_INPUT_GEMM_KEYS = ("W_cat", "fold_x_cat")


def _recurrent_vals(arrays: Dict[str, Any]):
    """Deterministic flat view of the recurrent-stage arrays.

    ``jax.tree_util`` flattens dicts in sorted-key order, so the leaf list
    and its treedef are a stable function of the arrays' key structure --
    the kernel rebuilds the dict from one ref per leaf.
    """
    rec = {k: v for k, v in arrays.items() if k not in _INPUT_GEMM_KEYS}
    return jax.tree_util.tree_flatten(rec)


def _scan_kernel(*refs, spec, treedef, n_vals: int, n_state: int,
                 masked: bool):
    it = iter(refs)
    acc_ref = next(it)  # (B, 1, G*H) int32: step slice of the hoisted GEMM
    val_refs = [next(it) for _ in range(n_vals)]  # VMEM-resident all sweep
    s0_refs = [next(it) for _ in range(n_state)]  # t=0 carry seeds
    vl_ref = next(it) if masked else None
    ys_ref = next(it)
    out_refs = [next(it) for _ in range(n_state)]  # final carry outputs
    scrs = [next(it) for _ in range(n_state)]  # VMEM carry, one per leaf

    t = pl.program_id(0)

    @pl.when(t == 0)
    def _seed_carry():
        for scr, s0 in zip(scrs, s0_refs):
            scr[...] = s0[...]

    state = tuple(scr[...] for scr in scrs)
    vals = jax.tree_util.tree_unflatten(treedef, [r[...] for r in val_refs])
    new_state = ref.recurrent_step_jnp(
        vals, spec, acc_ref[...][:, 0, :], state)
    if masked:
        live = (vl_ref[...] > t)[:, None]
        new_state = tuple(
            jnp.where(live, new, old)
            for new, old in zip(new_state, state))
    ys_ref[...] = new_state[0][:, None, :]  # leaf 0 is the emitted output
    for scr, new in zip(scrs, new_state):
        scr[...] = new

    @pl.when(t == pl.num_programs(0) - 1)
    def _emit_final_state():
        for out, new in zip(out_refs, new_state):
            out[...] = new


@functools.partial(jax.jit, static_argnames=("spec", "interpret"))
def quant_recurrent_seq_scan_pallas(
    arrays: Dict[str, Any],
    spec,  # core.recipe.Q*Spec (static, names the cell)
    acc_x_all: jax.Array,  # int32 (B, T, G*H): hoisted input accumulator
    state0: Tuple[jax.Array, ...],  # per cell.state_leaves(spec)
    valid_len: Optional[jax.Array] = None,  # int32 (B,): masked variant
    *,
    interpret: bool = False,
) -> Tuple[jax.Array, Tuple[jax.Array, ...]]:
    """Run the recurrent stage for a whole sequence in ONE kernel launch.

    Returns ``(ys int8 (B, T, d_out), state_final)`` -- bit-identical to
    scanning ``ops.quant_recurrent_step`` over the same slices.
    """
    B, T, GH = acc_x_all.shape
    cell = C.get_cell(spec)
    leaves = cell.state_leaves(spec)
    d_out = cell.d_out(spec)
    masked = valid_len is not None
    state0 = tuple(state0)
    vals_flat, treedef = _recurrent_vals(arrays)

    def const(shape):
        """Whole-array block revisited every grid step (stays in VMEM)."""
        return pl.BlockSpec(shape, lambda t, _n=len(shape): (0,) * _n)

    inputs = [acc_x_all, *vals_flat, *state0]
    in_specs = [pl.BlockSpec((B, 1, GH), lambda t: (0, t, 0))]
    in_specs += [const(v.shape) for v in vals_flat]
    in_specs += [const((B, leaf.width)) for leaf in leaves]
    if masked:
        inputs.append(valid_len)
        in_specs.append(const((B,)))

    outs = pl.pallas_call(
        functools.partial(
            _scan_kernel, spec=spec, treedef=treedef,
            n_vals=len(vals_flat), n_state=len(leaves), masked=masked),
        grid=(T,),
        in_specs=in_specs,
        out_specs=(
            [pl.BlockSpec((B, 1, d_out), lambda t: (0, t, 0))]
            + [const((B, leaf.width)) for leaf in leaves]
        ),
        out_shape=(
            [jax.ShapeDtypeStruct((B, T, d_out), jnp.int8)]
            + [jax.ShapeDtypeStruct((B, leaf.width), leaf.dtype)
               for leaf in leaves]
        ),
        scratch_shapes=[
            pltpu.VMEM((B, leaf.width), leaf.dtype) for leaf in leaves
        ],
        interpret=interpret,
    )(*inputs)
    return outs[0], tuple(outs[1:])


def quant_lstm_seq_scan_pallas(
    arrays: Dict[str, Any],
    spec,  # core.recipe.QLSTMSpec (static)
    acc_x_all: jax.Array,
    h0_q: jax.Array,
    c0_q: jax.Array,
    valid_len: Optional[jax.Array] = None,
    *,
    interpret: bool = False,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """LSTM-shaped wrapper kept for callers that thread ``(h0, c0)``."""
    ys, state = quant_recurrent_seq_scan_pallas(
        arrays, spec, acc_x_all, (h0_q, c0_q), valid_len,
        interpret=interpret)
    return ys, state
