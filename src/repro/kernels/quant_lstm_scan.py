"""Persistent Pallas sequence kernel: the integer LSTM recurrent stage.

One ``pallas_call`` runs the ENTIRE sequence: the grid is ``(T,)`` (TPU grid
iteration is sequential), the packed recurrent weights / peephole / LN /
projection parameters are mapped to constant-index blocks so they stay
resident in VMEM across steps, and the ``(h, c)`` carry lives in VMEM
scratch for the whole sweep.  Each grid step fuses

    recurrent matmul (int8 MXU)  ->  per-gate fixed-point rescales
    [-> integer LayerNorm / peephole]  ->  fused cell update
    [-> projection matmul]  ->  write ys[t], update the carry

which eliminates the per-timestep dispatch overhead and the per-step h/c
HBM round-trips the scan-of-steps executor pays: between consecutive
timesteps nothing leaves VMEM.  The input-dependent work arrives
precomputed -- the kernel consumes per-step ``(B, 1, G*H)`` int32 blocks of
the hoisted time-batched input GEMM (``ops.quant_lstm_input_proj``), so the
only matmul on the critical scan path is the genuinely sequential
``h_{t-1} @ R_cat`` product.

The step math is ``ref.quant_lstm_recurrent_jnp`` -- the same function the
``xla`` scan executor runs -- traced inside the kernel body, so the two
lowerings are bit-identical by construction (integer ops only; validated
against the goldens and the per-gate reference for all 16 variants).

The masked variant takes a per-row ``valid_len`` and freezes ``(h, c)`` for
rows past their valid prefix -- the chunked-prefill contract of
``ops.quant_lstm_seq_masked``.

Sizing note: blocks span the full ``(B, ...)`` extents (integer LayerNorm
reduces over the whole hidden axis, and the carry must stay resident), so
``B * (G*H)`` int32 plus the packed weights must fit in VMEM; serving-shape
blocks (B <= 64, H <= 2048) do.  Time is the grid, so T is unbounded.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import ref


def _peephole_gates(spec) -> Tuple[str, ...]:
    # recipe.py quantizes P only for non-z gates (CIFG already dropped "i")
    return tuple(g for g in spec.variant.gates if g != "z")


def _scan_kernel(*refs, spec, masked: bool):
    it = iter(refs)
    acc_ref = next(it)  # (B, 1, G*H) int32: step slice of the hoisted GEMM
    r_ref = next(it)  # (d_out, G*H) int8, VMEM-resident all sweep
    fhb_ref = next(it)  # (G*H,) int32
    h0_ref = next(it)  # (B, d_out) int8
    c0_ref = next(it)  # (B, H) int16
    vals: Dict[str, Any] = {}
    if spec.use_peephole:
        vals["P"] = {g: next(it)[...] for g in _peephole_gates(spec)}
    if spec.use_layernorm:
        vals["L"] = {g: next(it)[...] for g in spec.variant.gates}
        vals["Lb"] = {g: next(it)[...] for g in spec.variant.gates}
    if spec.use_projection:
        vals["W_proj"] = next(it)[...]
        vals["fold_proj"] = next(it)[...]
    vl_ref = next(it) if masked else None
    ys_ref, h_out_ref, c_out_ref = next(it), next(it), next(it)
    h_scr, c_scr = next(it), next(it)  # VMEM carry, persistent across steps

    t = pl.program_id(0)

    @pl.when(t == 0)
    def _seed_carry():
        h_scr[...] = h0_ref[...]
        c_scr[...] = c0_ref[...]

    h = h_scr[...]
    c = c_scr[...]
    vals["R_cat"] = r_ref[...]
    vals["fold_hb_cat"] = fhb_ref[...]
    h_new, c_new = ref.quant_lstm_recurrent_jnp(
        vals, spec, acc_ref[...][:, 0, :], h, c)
    if masked:
        live = (vl_ref[...] > t)[:, None]
        h_new = jnp.where(live, h_new, h)
        c_new = jnp.where(live, c_new, c)
    ys_ref[...] = h_new[:, None, :]
    h_scr[...] = h_new
    c_scr[...] = c_new

    @pl.when(t == pl.num_programs(0) - 1)
    def _emit_final_state():
        h_out_ref[...] = h_new
        c_out_ref[...] = c_new


@functools.partial(jax.jit, static_argnames=("spec", "interpret"))
def quant_lstm_seq_scan_pallas(
    arrays: Dict[str, Any],
    spec,  # core.recipe.QLSTMSpec (static)
    acc_x_all: jax.Array,  # int32 (B, T, G*H): hoisted input accumulator
    h0_q: jax.Array,  # int8 (B, d_out)
    c0_q: jax.Array,  # int16 (B, H)
    valid_len: Optional[jax.Array] = None,  # int32 (B,): masked variant
    *,
    interpret: bool = False,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Run the recurrent stage for a whole sequence in ONE kernel launch.

    Returns ``(ys int8 (B, T, d_out), (h_final, c_final))`` -- bit-identical
    to scanning ``ops.quant_lstm_recurrent_step`` over the same slices.
    """
    B, T, GH = acc_x_all.shape
    H = spec.cfg_d_hidden
    d_out = spec.cfg_d_proj if spec.use_projection else H
    masked = valid_len is not None

    def const(shape):
        """Whole-array block revisited every grid step (stays in VMEM)."""
        return pl.BlockSpec(shape, lambda t, _n=len(shape): (0,) * _n)

    inputs = [acc_x_all, arrays["R_cat"], arrays["fold_hb_cat"], h0_q, c0_q]
    in_specs = [
        pl.BlockSpec((B, 1, GH), lambda t: (0, t, 0)),
        const(arrays["R_cat"].shape),
        const((GH,)),
        const((B, d_out)),
        const((B, H)),
    ]
    if spec.use_peephole:
        for g in _peephole_gates(spec):
            inputs.append(arrays["P"][g])
            in_specs.append(const((H,)))
    if spec.use_layernorm:
        for key in ("L", "Lb"):
            for g in spec.variant.gates:
                inputs.append(arrays[key][g])
                in_specs.append(const((H,)))
    if spec.use_projection:
        inputs += [arrays["W_proj"], arrays["fold_proj"]]
        in_specs += [const(arrays["W_proj"].shape), const((d_out,))]
    if masked:
        inputs.append(valid_len)
        in_specs.append(const((B,)))

    ys, h, c = pl.pallas_call(
        functools.partial(_scan_kernel, spec=spec, masked=masked),
        grid=(T,),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((B, 1, d_out), lambda t: (0, t, 0)),
            const((B, d_out)),
            const((B, H)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, d_out), jnp.int8),
            jax.ShapeDtypeStruct((B, d_out), jnp.int8),
            jax.ShapeDtypeStruct((B, H), jnp.int16),
        ],
        scratch_shapes=[
            pltpu.VMEM((B, d_out), jnp.int8),
            pltpu.VMEM((B, H), jnp.int16),
        ],
        interpret=interpret,
    )(*inputs)
    return ys, (h, c)
