"""Token embedding + logits head with vocab (tensor) parallelism."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .qmm import emb_logits, emb_lookup, mm


def embed_init(key, vocab: int, d_model: int, params: Dict, specs: Dict,
               tie: bool, dtype=jnp.bfloat16):
    k1, k2 = jax.random.split(key)
    emb = (jax.random.normal(k1, (vocab, d_model), jnp.float32) * 0.02).astype(dtype)
    params["embedding"], specs["embedding"] = emb, ("vocab", "embed")
    if not tie:
        head = (jax.random.normal(k2, (d_model, vocab), jnp.float32) * 0.02).astype(dtype)
        params["lm_head"], specs["lm_head"] = head, ("embed", "vocab")


def embed_tokens(params: Dict, tokens: jax.Array) -> jax.Array:
    return emb_lookup(params["embedding"], tokens)


def logits_head(params: Dict, x: jax.Array) -> jax.Array:
    if "lm_head" in params:
        return mm(x, params["lm_head"])
    return emb_logits(params["embedding"], x)


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  ignore_id: int = -1) -> jax.Array:
    """Mean token CE; logits may be vocab-sharded (GSPMD handles logsumexp)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
    mask = (labels != ignore_id).astype(jnp.float32)
    loss = (lse - ll) * mask
    return loss.sum() / jnp.maximum(mask.sum(), 1.0)
