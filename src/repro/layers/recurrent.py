"""RG-LRU recurrent block (RecurrentGemma / Griffin temporal mixing).

recurrence:  r_t = sigmoid(W_r x_t);  i_t = sigmoid(W_i x_t)
             a_t = exp(c * softplus(Lambda) * r_t * log a)   [gated decay]
             h_t = a_t (.) h_{t-1} + sqrt(1 - a_t^2) (.) (i_t (.) x_t)

plus the Griffin block structure: conv1d(4) -> RG-LRU inside a gated linear
unit.  Decode carries {"h", "conv"} state; the 1:2 attention:recurrent
pattern is assembled in repro.models.recurrentgemma.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .common import dense_init, causal_conv1d
from .qmm import mm

_C = 8.0  # Griffin's fixed recurrence sharpness constant


def rglru_init(key, d_model: int, d_rnn: int, d_conv: int, params: Dict,
               specs: Dict, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 6)
    params["rg_in"], specs["rg_in"] = dense_init(
        ks[0], (d_model, 2 * d_rnn), ("embed", "mlp"), dtype)
    params["conv_w"], specs["conv_w"] = dense_init(
        ks[1], (d_conv, d_rnn), (None, "mlp"), dtype, scale=0.5)
    params["conv_b"], specs["conv_b"] = jnp.zeros((d_rnn,), dtype), ("mlp",)
    params["rg_gate_r"], specs["rg_gate_r"] = dense_init(
        ks[2], (d_rnn, d_rnn), ("mlp", "mlp2"), dtype)
    params["rg_gate_i"], specs["rg_gate_i"] = dense_init(
        ks[3], (d_rnn, d_rnn), ("mlp", "mlp2"), dtype)
    # Lambda init so that a = sigmoid(Lambda) in [0.9, 0.999]
    lam = np.random.default_rng(0).uniform(0.9, 0.999, d_rnn)
    params["rg_lambda"], specs["rg_lambda"] = (
        jnp.asarray(np.log(lam / (1 - lam)), jnp.float32), ("mlp",))
    params["rg_out"], specs["rg_out"] = dense_init(
        ks[4], (d_rnn, d_model), ("mlp", "embed"), dtype)


def _rglru_scan(x: jax.Array, r: jax.Array, i: jax.Array, log_a: jax.Array,
                h0: Optional[jax.Array]) -> Tuple[jax.Array, jax.Array]:
    """x, r, i: (B, T, D); log_a: (D,) negative; returns (y, h_T)."""
    B, T, D = x.shape
    if h0 is None:
        h0 = jnp.zeros((B, D), jnp.float32)
    log_a_t = (-_C) * jax.nn.softplus(log_a)[None, None] * r.astype(jnp.float32)
    a_t = jnp.exp(log_a_t)  # (B, T, D) in (0, 1)
    gated_x = (i * x).astype(jnp.float32)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a_t), 1e-12))

    def step(h, inputs):
        a, bx = inputs
        h = a * h + bx
        return h, h

    xs = (jnp.moveaxis(a_t, 1, 0), jnp.moveaxis(beta * gated_x, 1, 0))
    h_T, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), h_T


def rglru_apply(
    params: Dict,
    x: jax.Array,  # (B, T, d_model)
    state: Optional[Dict[str, jax.Array]] = None,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    xz = mm(x, params["rg_in"])
    xs, z = jnp.split(xz, 2, axis=-1)
    conv_cache = state["conv"] if state is not None else None
    xs, new_conv = causal_conv1d(xs, params["conv_w"], params["conv_b"], conv_cache)
    r = jax.nn.sigmoid(mm(xs, params["rg_gate_r"]))
    i = jax.nn.sigmoid(mm(xs, params["rg_gate_i"]))
    h0 = state["h"] if state is not None else None
    y, h_T = _rglru_scan(xs, r, i, params["rg_lambda"], h0)
    y = y.astype(x.dtype) * jax.nn.gelu(z)
    out = mm(y, params["rg_out"])
    new_state = {"h": h_T, "conv": new_conv} if state is not None else None
    return out, new_state
