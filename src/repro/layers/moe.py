"""Mixture-of-Experts with expert parallelism via shard_map + capacity dispatch.

Scheme (see DESIGN.md "EP mapping"):
  * the mesh's "model" axis is factored into ep (expert-parallel) x ff_tp
    (tensor-parallel within each expert): ep = min(n_experts, model_size).
  * inside shard_map each model-rank owns n_experts/ep experts; tokens are
    routed locally with a static per-expert capacity (Switch-style; dropped
    tokens fall through on the residual), experts run as dense batched
    matmuls, and a psum over "model" recombines the top-k expert outputs.
  * grok-1 (8 experts on a 16-wide model axis) uses ep=8, ff_tp=2; kimi-k2
    (384 experts) uses ep=16, ff_tp=1 with 24 resident experts per rank.

FLOPs are ~capacity_factor x the useful expert FLOPs -- no one-hot dispatch
einsums, so cost_analysis stays honest for the roofline.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import dense_init
from .qmm import expert_einsum, is_quant


def moe_init(key, d_model: int, d_ff: int, n_experts: int, params: Dict,
             specs: Dict, prefix: str = "moe", dtype=jnp.bfloat16):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    params[f"{prefix}_router"], specs[f"{prefix}_router"] = dense_init(
        k1, (d_model, n_experts), ("embed", None), jnp.float32)
    params[f"{prefix}_gate"], specs[f"{prefix}_gate"] = dense_init(
        k2, (n_experts, d_model, d_ff), ("experts", "embed", "expert_mlp"), dtype)
    params[f"{prefix}_up"], specs[f"{prefix}_up"] = dense_init(
        k3, (n_experts, d_model, d_ff), ("experts", "embed", "expert_mlp"), dtype)
    params[f"{prefix}_down"], specs[f"{prefix}_down"] = dense_init(
        k4, (n_experts, d_ff, d_model), ("experts", "expert_mlp", "embed"), dtype)


def _local_expert_ffn(x: jax.Array, gate_w, up_w, down_w) -> jax.Array:
    """x: (E_loc, C, d) batched over local experts; SwiGLU."""
    h = jax.nn.silu(expert_einsum("ecd,edf->ecf", x, gate_w)) * expert_einsum(
        "ecd,edf->ecf", x, up_w
    )
    return expert_einsum("ecf,efd->ecd", h, down_w)


def moe_apply_local(
    params: Dict,
    x: jax.Array,  # (T, d) local tokens (already flattened)
    *,
    n_experts: int,
    topk: int,
    capacity_factor: float,
    ep_rank: jax.Array,  # scalar int32: this rank's position on the ep axis
    ep_size: int,
    model_axis: Optional[str],
    prefix: str = "moe",
) -> jax.Array:
    """Body run inside shard_map.  Expert weights arrive pre-sliced to
    (E_loc, d, ff_loc).  Returns the combined (T, d) expert output."""
    T, d = x.shape
    e_loc = n_experts // ep_size
    capacity = max(int(T * topk * capacity_factor / n_experts) * e_loc, e_loc)
    capacity = min(capacity, T * topk)

    logits = (x.astype(jnp.float32) @ params[f"{prefix}_router"].astype(jnp.float32)).astype(
        jnp.float32
    )  # (T, E)
    gate_vals, gate_idx = jax.lax.top_k(logits, topk)  # (T, k)
    gate_p = jax.nn.softmax(gate_vals, axis=-1)  # normalize over selected

    # flatten (token, k) assignments
    flat_expert = gate_idx.reshape(-1)  # (T*k,)
    flat_prob = gate_p.reshape(-1)
    flat_token = jnp.repeat(jnp.arange(T), topk)

    # keep only experts owned by this rank: [ep_rank*e_loc, (ep_rank+1)*e_loc)
    local_e = flat_expert - ep_rank * e_loc
    mine = (local_e >= 0) & (local_e < e_loc)

    # rank assignments by (expert, arrival) to give each a capacity slot
    sort_key = jnp.where(mine, local_e, e_loc)  # non-mine sort to the end
    order = jnp.argsort(sort_key, stable=True)
    sorted_e = sort_key[order]
    # position within expert group = index - start of group
    group_start = jnp.searchsorted(sorted_e, jnp.arange(e_loc + 1))
    pos_in_group = jnp.arange(sorted_e.shape[0]) - group_start[
        jnp.clip(sorted_e, 0, e_loc)
    ]
    cap_per_e = capacity // e_loc
    keep = (sorted_e < e_loc) & (pos_in_group < cap_per_e)
    slot = jnp.where(
        keep, jnp.clip(sorted_e, 0, e_loc - 1) * cap_per_e + pos_in_group, capacity
    )

    # scatter tokens into (capacity+1, d) buffer (last row = drop bin)
    buf = jnp.zeros((capacity + 1, d), x.dtype)
    tok_idx = flat_token[order]
    buf = buf.at[slot].set(x[tok_idx], mode="drop")
    expert_in = buf[:capacity].reshape(e_loc, cap_per_e, d)

    out = _local_expert_ffn(
        expert_in, params[f"{prefix}_gate"], params[f"{prefix}_up"],
        params[f"{prefix}_down"],
    )  # (E_loc, cap, d)

    # gather back: each kept assignment reads its slot, weighted by gate prob
    out_flat = jnp.concatenate(
        [out.reshape(capacity, d), jnp.zeros((1, d), out.dtype)], axis=0
    )
    contrib = out_flat[slot] * flat_prob[order][:, None].astype(out.dtype)
    y = jnp.zeros((T, d), out.dtype).at[tok_idx].add(
        jnp.where(keep[:, None], contrib, 0)
    )
    if model_axis is not None:
        y = jax.lax.psum(y, model_axis)
    return y
