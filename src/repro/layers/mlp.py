"""MLP variants: SwiGLU / GeGLU / GELU, with TP-friendly logical specs."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .common import dense_init, ACTIVATIONS
from .qmm import mm


def mlp_init(key, d_model: int, d_ff: int, kind: str, params: Dict, specs: Dict,
             prefix: str = "mlp", dtype=jnp.bfloat16):
    k1, k2, k3 = jax.random.split(key, 3)
    if kind in ("swiglu", "geglu"):
        params[f"{prefix}_gate"], specs[f"{prefix}_gate"] = dense_init(
            k1, (d_model, d_ff), ("embed", "mlp"), dtype)
        params[f"{prefix}_up"], specs[f"{prefix}_up"] = dense_init(
            k2, (d_model, d_ff), ("embed", "mlp"), dtype)
    else:
        params[f"{prefix}_up"], specs[f"{prefix}_up"] = dense_init(
            k2, (d_model, d_ff), ("embed", "mlp"), dtype)
    params[f"{prefix}_down"], specs[f"{prefix}_down"] = dense_init(
        k3, (d_ff, d_model), ("mlp", "embed"), dtype)


def mlp_apply(params: Dict, x: jax.Array, kind: str, prefix: str = "mlp",
              constrain=None) -> jax.Array:
    if kind in ("swiglu", "geglu"):
        act = jax.nn.silu if kind == "swiglu" else jax.nn.gelu
        h = act(mm(x, params[f"{prefix}_gate"])) * mm(x, params[f"{prefix}_up"])
    else:
        h = jax.nn.gelu(mm(x, params[f"{prefix}_up"]))
    if constrain is not None:
        h = constrain(h, ("batch", "seq", "mlp"))
    return mm(h, params[f"{prefix}_down"])
