"""Quantization-aware matmul helpers.

A weight is either a plain array or a quantized dict {"q": int8, "s": f32}
with per-output-channel scales (the paper's symmetric max/127 recipe applied
per channel -- the standard strengthening for transformer weights).  ``mm``
and friends dequantize *inside* the consumer so XLA reads int8 from HBM --
on decode (memory-bound) that is a direct 2x/4x memory-term win.
"""
from __future__ import annotations

from typing import Any, Dict, Union

import jax
import jax.numpy as jnp

QWeight = Union[jax.Array, Dict[str, jax.Array]]


def is_quant(w: QWeight) -> bool:
    return isinstance(w, dict) and "q" in w


def mm(x: jax.Array, w: QWeight) -> jax.Array:
    """x @ w with transparent int8-weight dequantization."""
    if is_quant(w):
        y = x @ w["q"].astype(x.dtype)
        return y * w["s"].astype(x.dtype)
    return x @ w


def emb_lookup(w: QWeight, ids: jax.Array) -> jax.Array:
    if is_quant(w):
        rows = jnp.take(w["q"], ids, axis=0)
        scale = jnp.take(w["s"], ids, axis=0)
        return rows.astype(jnp.bfloat16) * scale[..., None].astype(jnp.bfloat16)
    return jnp.take(w, ids, axis=0)


def emb_logits(w: QWeight, x: jax.Array) -> jax.Array:
    """x @ embedding.T (tied head); per-row scales become per-logit scales."""
    if is_quant(w):
        y = x @ w["q"].T.astype(x.dtype)
        return y * w["s"].astype(x.dtype)
    return x @ w.T


def expert_einsum(eq: str, x: jax.Array, w: QWeight) -> jax.Array:
    """Batched expert matmuls; per (expert, out-channel) scales."""
    if is_quant(w):
        y = jnp.einsum(eq, x, w["q"].astype(x.dtype))
        # scales: (E, out) broadcast over the capacity dim
        return y * w["s"][:, None, :].astype(x.dtype)
    return jnp.einsum(eq, x, w)


def quantize_weight(w: jax.Array, channel_axis: int = -1) -> Dict[str, jax.Array]:
    """Symmetric per-channel int8 (paper: s = max|W|/127)."""
    wf = w.astype(jnp.float32)
    axes = tuple(i for i in range(w.ndim) if i != channel_axis % w.ndim)
    s = jnp.maximum(jnp.max(jnp.abs(wf), axis=axes), 1e-8) / 127.0
    shape = [1] * w.ndim
    shape[channel_axis % w.ndim] = w.shape[channel_axis]
    q = jnp.clip(jnp.round(wf / s.reshape(shape)), -127, 127).astype(jnp.int8)
    return {"q": q, "s": s.astype(jnp.float32)}
