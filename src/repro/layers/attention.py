"""Attention: GQA with RoPE/qk-norm, blockwise (flash-style) softmax, caches.

Three execution paths:
  * ``flash_attention``  -- blockwise online-softmax over KV chunks (bounded
    memory; default for prefill/train when seq >= block threshold).
  * ``full_attention``   -- direct einsum path for short sequences.
  * ``decode_attention`` -- single-position query against a KV cache.

Sharding is expressed with with_sharding_constraint on q/k/v/logits using the
active rule set (see repro.runtime.sharding); the math is sharding-agnostic.

KV caches may be bf16 or int8 (per-head symmetric scales) -- the paper's
recipe applied to attention state (beyond-paper; see DESIGN.md).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30

import os

# Perf-iteration toggle (EXPERIMENTS.md §Perf): triangular causal flash
# schedule -- visits only the kv chunks at/below each q chunk's diagonal.
TRIANGULAR = os.environ.get("REPRO_TRIANGULAR_FLASH", "0") == "1"


def repeat_kv(k: jax.Array, groups: int) -> jax.Array:
    """(B, S, KVH, D) -> (B, S, KVH*groups, D) by head repetition."""
    if groups == 1:
        return k
    B, S, KVH, D = k.shape
    k = jnp.broadcast_to(k[:, :, :, None, :], (B, S, KVH, groups, D))
    return k.reshape(B, S, KVH * groups, D)


def _pick_block(n: int, target: int) -> int:
    """Largest divisor of n that is <= target (keeps blocking exact for
    non-power-of-two lengths like whisper's 1500 encoder frames)."""
    if n <= target:
        return n
    for b in range(target, 0, -1):
        if n % b == 0:
            return b
    return n


def _mask_block(q_pos, k_pos, causal: bool, window: int):
    """(bq, bk) boolean mask: True = attend."""
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m = m & (k_pos[None, :] <= q_pos[:, None])
    if window > 0:
        m = m & (k_pos[None, :] > q_pos[:, None] - window)
    return m


def full_attention(
    q: jax.Array,  # (B, Sq, H, D)
    k: jax.Array,  # (B, Sk, H, D)  (already GQA-repeated)
    v: jax.Array,
    q_offset: jax.Array | int = 0,
    causal: bool = True,
    window: int = 0,
) -> jax.Array:
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    scale = 1.0 / np.sqrt(D)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    q_pos = jnp.arange(Sq) + q_offset
    k_pos = jnp.arange(Sk)
    mask = _mask_block(q_pos, k_pos, causal, window)
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v,
                      preferred_element_type=jnp.float32).astype(v.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention(
    q: jax.Array,  # (B, Sq, H, D)
    k: jax.Array,  # (B, Sk, H, D)
    v: jax.Array,
    q_offset: int = 0,
    causal: bool = True,
    window: int = 0,
    block_q: int = 512,
    block_k: int = 512,
) -> jax.Array:
    """Blockwise online-softmax attention with a recomputing custom VJP.

    Forward memory: O(block_q * block_k) logits per chunk step; the backward
    pass recomputes chunk logits from the saved (q, k, v, out, lse) instead of
    differentiating the scan (which would materialize all S^2 chunk
    intermediates -- the difference between fitting HBM and not, on trains).

    The schedule visits the full rectangular chunk grid with masking; causal
    runs at ~2x useful FLOPs (documented; a triangular schedule is a recorded
    perf iteration in EXPERIMENTS.md).
    """
    out, _ = _flash_fwd_impl(q, k, v, q_offset, causal, window, block_q,
                             block_k)
    return out


def _flash_fwd_impl(q, k, v, q_offset, causal, window, block_q, block_k):
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    bq = _pick_block(Sq, block_q)
    bk = _pick_block(Sk, block_k)
    scale = 1.0 / np.sqrt(D)
    nq, nk = Sq // bq, Sk // bk

    qs = ((q.astype(jnp.float32) * scale).astype(q.dtype)
          ).reshape(B, nq, bq, H, D)

    def q_chunk_body(qi, q_blk):
        q_pos = q_offset + qi * bq + jnp.arange(bq)

        def kv_step(carry, ki):
            acc, m_run, l_run = carry
            k_blk = jax.lax.dynamic_slice_in_dim(k, ki * bk, bk, axis=1)
            v_blk = jax.lax.dynamic_slice_in_dim(v, ki * bk, bk, axis=1)
            logits = jnp.einsum("bqhd,bkhd->bhqk", q_blk, k_blk,
                                preferred_element_type=jnp.float32)
            k_pos = ki * bk + jnp.arange(bk)
            mask = _mask_block(q_pos, k_pos, causal, window)
            logits = jnp.where(mask[None, None], logits, NEG_INF)
            m_new = jnp.maximum(m_run, logits.max(-1))
            alpha = jnp.exp(m_run - m_new)
            p = jnp.exp(logits - m_new[..., None])
            l_new = l_run * alpha + p.sum(-1)
            pv = jnp.einsum("bhqk,bkhd->bhqd", p.astype(v_blk.dtype), v_blk,
                            preferred_element_type=jnp.float32)
            acc = acc * alpha[..., None] + pv
            return (acc, m_new, l_new), None

        acc0 = jnp.zeros((B, H, bq, D), jnp.float32)
        m0 = jnp.full((B, H, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, bq), jnp.float32)
        if causal and window == 0 and TRIANGULAR:
            # triangular schedule: q-chunk qi only visits kv chunks up to its
            # own diagonal -- halves attention FLOPs vs the full grid
            # (perf iteration REPRO_TRIANGULAR_FLASH=1; see EXPERIMENTS §Perf).
            limit = jnp.minimum(
                (q_offset + (qi + 1) * bq + bk - 1) // bk, nk).astype(jnp.int32)
            acc, m_run, l_run = jax.lax.fori_loop(
                0, limit,
                lambda ki, c: kv_step(c, ki)[0],
                (acc0, m0, l0))
        else:
            (acc, m_run, l_run), _ = jax.lax.scan(
                kv_step, (acc0, m0, l0), jnp.arange(nk))
        l_safe = jnp.maximum(l_run, 1e-30)
        out = jnp.einsum("bhqd->bqhd", acc / l_safe[..., None])
        lse = m_run + jnp.log(l_safe)  # (B, H, bq)
        return out, jnp.moveaxis(lse, 2, 1)  # (B, bq, H)

    outs, lses = jax.lax.map(
        lambda args: q_chunk_body(*args),
        (jnp.arange(nq), jnp.moveaxis(qs, 1, 0)))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, Sq, H, D).astype(v.dtype)
    lse = jnp.moveaxis(lses, 0, 1).reshape(B, Sq, H)
    return out, lse


def _flash_fwd(q, k, v, q_offset, causal, window, block_q, block_k):
    out, lse = _flash_fwd_impl(q, k, v, q_offset, causal, window, block_q,
                               block_k)
    return out, (q, k, v, out, lse)


def _flash_bwd(q_offset, causal, window, block_q, block_k, res, dout):
    q, k, v, out, lse = res
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    bq = _pick_block(Sq, block_q)
    bk = _pick_block(Sk, block_k)
    scale = 1.0 / np.sqrt(D)
    nq, nk = Sq // bq, Sk // bk
    dout = dout.astype(jnp.float32)
    # delta_i = sum_d dout_i * out_i  (flash-attention-2 backward)
    delta = jnp.einsum("bqhd,bqhd->bqh", dout, out.astype(jnp.float32))

    def q_chunk_body(qi):
        q_blk = jax.lax.dynamic_slice_in_dim(q, qi * bq, bq, axis=1)
        do_blk = jax.lax.dynamic_slice_in_dim(dout, qi * bq, bq, axis=1)
        lse_blk = jax.lax.dynamic_slice_in_dim(lse, qi * bq, bq, axis=1)
        dl_blk = jax.lax.dynamic_slice_in_dim(delta, qi * bq, bq, axis=1)
        q_pos = q_offset + qi * bq + jnp.arange(bq)
        qf = q_blk.astype(jnp.float32) * scale

        def kv_step(carry, ki):
            dq_acc, dk_acc, dv_acc = carry
            k_blk = jax.lax.dynamic_slice_in_dim(k, ki * bk, bk, axis=1)
            v_blk = jax.lax.dynamic_slice_in_dim(v, ki * bk, bk, axis=1)
            logits = jnp.einsum("bqhd,bkhd->bhqk", qf,
                                k_blk.astype(jnp.float32))
            k_pos = ki * bk + jnp.arange(bk)
            mask = _mask_block(q_pos, k_pos, causal, window)
            logits = jnp.where(mask[None, None], logits, NEG_INF)
            p = jnp.exp(logits - jnp.moveaxis(lse_blk, 2, 1)[..., None])
            dp = jnp.einsum("bqhd,bkhd->bhqk", do_blk,
                            v_blk.astype(jnp.float32))
            ds = p * (dp - jnp.moveaxis(dl_blk, 2, 1)[..., None])
            dq_acc = dq_acc + jnp.einsum(
                "bhqk,bkhd->bqhd", ds, k_blk.astype(jnp.float32)) * scale
            dk_blk = jnp.einsum("bhqk,bqhd->bkhd", ds, qf)
            dv_blk = jnp.einsum("bhqk,bqhd->bkhd", p, do_blk)

            def add_at(acc, blk):
                cur = jax.lax.dynamic_slice_in_dim(acc, ki * bk, bk, axis=1)
                return jax.lax.dynamic_update_slice_in_dim(
                    acc, cur + blk, ki * bk, axis=1)

            return (dq_acc, add_at(dk_acc, dk_blk), add_at(dv_acc, dv_blk)), None

        dq0 = jnp.zeros((B, bq, H, D), jnp.float32)
        dk0 = jnp.zeros((B, Sk, H, D), jnp.float32)
        dv0 = jnp.zeros((B, Sk, H, D), jnp.float32)
        if causal and window == 0 and TRIANGULAR:
            limit = jnp.minimum(
                (q_offset + (qi + 1) * bq + bk - 1) // bk, nk).astype(jnp.int32)
            dq_b, dk_b, dv_b = jax.lax.fori_loop(
                0, limit, lambda ki, c: kv_step(c, ki)[0], (dq0, dk0, dv0))
        else:
            (dq_b, dk_b, dv_b), _ = jax.lax.scan(
                kv_step, (dq0, dk0, dv0), jnp.arange(nk))
        return dq_b, dk_b, dv_b

    def outer(carry, qi):
        dk_tot, dv_tot = carry
        dq_b, dk_b, dv_b = q_chunk_body(qi)
        return (dk_tot + dk_b, dv_tot + dv_b), dq_b

    (dk_tot, dv_tot), dq_chunks = jax.lax.scan(
        outer,
        (jnp.zeros((B, Sk, H, D), jnp.float32),
         jnp.zeros((B, Sk, H, D), jnp.float32)),
        jnp.arange(nq))
    dq = jnp.moveaxis(dq_chunks, 0, 1).reshape(B, Sq, H, D)
    return dq.astype(q.dtype), dk_tot.astype(k.dtype), dv_tot.astype(v.dtype)


flash_attention.defvjp(_flash_fwd, _flash_bwd)


def decode_attention(
    q: jax.Array,  # (B, 1, H, D)
    k_cache: jax.Array,  # (B, S, KVH, D), bf16 or int8
    v_cache: jax.Array,
    cache_len: jax.Array,  # (B,) or scalar int32: valid prefix length
    window: int = 0,
    k_scale: Optional[jax.Array] = None,  # (B, S, KVH) for int8 caches
    v_scale: Optional[jax.Array] = None,
) -> jax.Array:
    """One-token attention against a (possibly int8-quantized) KV cache.

    For int8 caches the per-(pos, head) scales fold into the logits and into
    the probability weights, so no dequantized copy of the cache is ever
    materialized (the HBM read stays int8 -- the paper's memory win).
    """
    B, S, KVH, D = k_cache.shape
    H = q.shape[2]
    groups = H // KVH
    scale = 1.0 / np.sqrt(D)
    qg = ((q.astype(jnp.float32) * scale).astype(q.dtype)
          ).reshape(B, 1, KVH, groups, D)
    kc = k_cache.astype(q.dtype) if k_cache.dtype == jnp.int8 else k_cache
    # (B, 1, KVH, G, D) x (B, S, KVH, D) -> (B, KVH, G, S)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, kc,
                        preferred_element_type=jnp.float32)
    logits = logits[:, :, :, 0]  # (B, KVH, G, S)
    if k_scale is not None:
        logits = logits * jnp.transpose(
            k_scale.astype(jnp.float32), (0, 2, 1))[:, :, None, :]
    pos = jnp.arange(S)
    valid = pos[None] < jnp.reshape(cache_len, (-1, 1))  # (B, S)
    if window > 0:
        valid = valid & (pos[None] >= jnp.reshape(cache_len, (-1, 1)) - window)
    logits = jnp.where(valid[:, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    if v_scale is not None:
        probs = probs * jnp.transpose(
            v_scale.astype(jnp.float32), (0, 2, 1))[:, :, None, :]
    vc = v_cache.astype(q.dtype) if v_cache.dtype == jnp.int8 else v_cache
    out = jnp.einsum("bkgs,bskd->bkgd", probs.astype(q.dtype), vc,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, D).astype(q.dtype)


# --- KV cache (bf16 or int8) ------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CacheSpec:
    max_len: int
    kv_heads: int
    head_dim: int
    quantized: bool = False  # int8 per (head) symmetric, scales carried


def init_cache(batch: int, n_layers: int, spec: CacheSpec, dtype=jnp.bfloat16):
    shape = (n_layers, batch, spec.max_len, spec.kv_heads, spec.head_dim)
    if spec.quantized:
        return {
            "k": jnp.zeros(shape, jnp.int8),
            "v": jnp.zeros(shape, jnp.int8),
            "k_scale": jnp.ones(shape[:2] + (spec.max_len, spec.kv_heads), jnp.float32),
            "v_scale": jnp.ones(shape[:2] + (spec.max_len, spec.kv_heads), jnp.float32),
            "len": jnp.zeros((batch,), jnp.int32),
        }
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "len": jnp.zeros((batch,), jnp.int32),
    }


def quantize_kv(k: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per (batch, pos, head) symmetric int8 (paper recipe on attention state)."""
    s = jnp.maximum(jnp.max(jnp.abs(k.astype(jnp.float32)), axis=-1), 1e-6) / 127.0
    q = jnp.clip(jnp.round(k.astype(jnp.float32) / s[..., None]), -127, 127)
    return q.astype(jnp.int8), s


def dequantize_kv(q: jax.Array, s: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    return (q.astype(jnp.float32) * s[..., None]).astype(dtype)
