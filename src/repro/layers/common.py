"""Shared layer primitives with logical-axis sharding annotations.

Every parameter initializer returns both the array and a *logical spec*: a
tuple of logical axis names (resolved to mesh axes by
``repro.runtime.sharding``).  Models build parallel (params, specs) trees so
pjit in_shardings derive mechanically from per-arch rules.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]
Specs = Dict[str, Any]


def dense_init(key, shape: Sequence[int], spec: Tuple[Optional[str], ...],
               dtype=jnp.bfloat16, scale: Optional[float] = None):
    """Variance-scaling dense init annotated with logical axes."""
    fan_in = shape[0] if len(shape) > 1 else 1
    s = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    w = (jax.random.normal(key, shape, jnp.float32) * s).astype(dtype)
    assert len(spec) == len(shape), (spec, shape)
    return w, spec


def zeros_init(shape, spec, dtype=jnp.bfloat16):
    return jnp.zeros(shape, dtype), spec


def ones_init(shape, spec, dtype=jnp.bfloat16):
    return jnp.ones(shape, dtype), spec


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(dt)


def layernorm(x: jax.Array, w: jax.Array, b: Optional[jax.Array],
              eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)
    if b is not None:
        y = y + b.astype(jnp.float32)
    return y.astype(dt)


def norm_apply(kind: str, x, params, name: str):
    if kind == "rmsnorm":
        return rmsnorm(x, params[name])
    return layernorm(x, params[name], params.get(name + "_b"))


def norm_init(kind: str, d: int, name: str, params: Params, specs: Specs,
              dtype=jnp.bfloat16):
    params[name], specs[name] = ones_init((d,), ("embed",), dtype)
    if kind == "layernorm":
        params[name + "_b"], specs[name + "_b"] = zeros_init((d,), ("embed",), dtype)


ACTIVATIONS: Dict[str, Callable] = {
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "relu": jax.nn.relu,
    "tanh": jnp.tanh,
}


def causal_conv1d(x: jax.Array, w: jax.Array, b: Optional[jax.Array],
                  cache: Optional[jax.Array] = None):
    """Depthwise causal conv over time.  x: (B, T, D); w: (K, D).

    With ``cache`` (B, K-1, D) performs streaming (decode) convolution and
    returns (y, new_cache); otherwise pads with zeros (train/prefill) and
    returns (y, last K-1 inputs as cache).
    """
    K = w.shape[0]
    if cache is None:
        pad = jnp.zeros(x.shape[:1] + (K - 1,) + x.shape[2:], x.dtype)
    else:
        pad = cache
    xp = jnp.concatenate([pad, x], axis=1)  # (B, T+K-1, D)
    y = jnp.zeros_like(x)
    for k in range(K):
        y = y + xp[:, k : k + x.shape[1]] * w[k]
    if b is not None:
        y = y + b
    new_cache = xp[:, -(K - 1):] if K > 1 else jnp.zeros_like(xp[:, :0])
    return y, new_cache
