"""Rotary position embeddings (float path + integer Q0.15 tables)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def rope_angles(positions: jax.Array, head_dim: int, theta: float) -> Tuple[jax.Array, jax.Array]:
    """positions: (...,) int32 -> (sin, cos) of shape (..., head_dim/2) f32."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) or (S,)."""
    D = x.shape[-1]
    sin, cos = rope_angles(positions, D, theta)  # (B, S, D/2)
    if sin.ndim == 2:  # (S, D/2) -> broadcast over batch
        sin, cos = sin[None], cos[None]
    sin = sin[:, :, None, :]
    cos = cos[:, :, None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


def rope_tables_q15(max_seq: int, head_dim: int, theta: float) -> Tuple[np.ndarray, np.ndarray]:
    """Integer rotation tables: sin/cos in int16 Q0.15 (quantized serving).

    Rotation is norm-preserving, so rotating int16-widened q/k by Q0.15
    tables keeps the activation scale unchanged (beyond-paper extension of
    the recipe to attention position encoding).
    """
    half = head_dim // 2
    inv = 1.0 / (theta ** (np.arange(0, half, dtype=np.float64) / half))
    ang = np.arange(max_seq, dtype=np.float64)[:, None] * inv
    to_q15 = lambda v: np.clip(np.round(v * 32768.0), -32768, 32767).astype(np.int16)
    return to_q15(np.sin(ang)), to_q15(np.cos(ang))


def apply_rope_int(q_int: jax.Array, sin_q15: jax.Array, cos_q15: jax.Array) -> jax.Array:
    """Integer RoPE: x int16/int32 (B, S, H, D), tables (S, D/2) Q0.15.

    Output int32 in the same scale as the input (rounded); pair-wise rotation
    with Q0.15 fixed-point multiplies.
    """
    from repro.core import fixedpoint as fp

    x = q_int.astype(jnp.int32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    sin = sin_q15.astype(jnp.int32)[None, :, None, :]
    cos = cos_q15.astype(jnp.int32)[None, :, None, :]
    y1 = fp.rounding_divide_by_pot(x1 * cos - x2 * sin, 15)
    y2 = fp.rounding_divide_by_pot(x2 * cos + x1 * sin, 15)
    return jnp.concatenate([y1, y2], axis=-1)
