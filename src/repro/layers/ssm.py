"""Mamba-1 selective SSM block (falcon-mamba-7b backbone).

Train/prefill runs a chunked sequential scan over time (carry = (B, d_inner,
state)); decode is a single recurrence step.  The 16-wide state dimension is
the natural target for the paper's 16-bit-state quantization (DESIGN.md).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .common import dense_init, causal_conv1d
from .qmm import mm


def ssm_init(key, d_model: int, d_inner: int, d_state: int, d_conv: int,
             dt_rank: int, params: Dict, specs: Dict, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 8)
    params["in_proj"], specs["in_proj"] = dense_init(
        ks[0], (d_model, 2 * d_inner), ("embed", "mlp"), dtype)
    params["conv_w"], specs["conv_w"] = dense_init(
        ks[1], (d_conv, d_inner), (None, "mlp"), dtype, scale=0.5)
    params["conv_b"], specs["conv_b"] = (
        jnp.zeros((d_inner,), dtype), ("mlp",))
    params["x_proj"], specs["x_proj"] = dense_init(
        ks[2], (d_inner, dt_rank + 2 * d_state), ("mlp", None), dtype)
    params["dt_proj"], specs["dt_proj"] = dense_init(
        ks[3], (dt_rank, d_inner), (None, "mlp"), dtype)
    params["dt_bias"], specs["dt_bias"] = (
        jnp.asarray(np.log(np.expm1(np.linspace(1e-3, 0.1, d_inner))), dtype),
        ("mlp",))
    # S4D-real initialization of A (negative)
    a = np.tile(np.arange(1, d_state + 1, dtype=np.float32), (d_inner, 1))
    params["A_log"], specs["A_log"] = jnp.asarray(np.log(a), jnp.float32), ("mlp", None)
    params["D"], specs["D"] = jnp.ones((d_inner,), jnp.float32), ("mlp",)
    params["out_proj"], specs["out_proj"] = dense_init(
        ks[4], (d_inner, d_model), ("mlp", "embed"), dtype)


def _ssm_scan(u: jax.Array, delta: jax.Array, A: jax.Array, B: jax.Array,
              C: jax.Array, h0: Optional[jax.Array]) -> Tuple[jax.Array, jax.Array]:
    """Sequential selective scan.
    u, delta: (Bt, T, Di); A: (Di, N); B, C: (Bt, T, N); h0: (Bt, Di, N).
    Returns (y (Bt, T, Di), h_T)."""
    Bt, T, Di = u.shape
    N = A.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((Bt, Di, N), jnp.float32)

    dA = jnp.exp(delta.astype(jnp.float32)[..., None] * A[None, None])  # (Bt,T,Di,N)
    dBu = (delta * u).astype(jnp.float32)[..., None] * B[:, :, None, :]

    def step(h, inputs):
        dA_t, dBu_t, C_t = inputs
        h = h * dA_t + dBu_t  # (Bt, Di, N)
        y = jnp.einsum("bdn,bn->bd", h, C_t)
        return h, y

    xs = (
        jnp.moveaxis(dA, 1, 0),
        jnp.moveaxis(dBu, 1, 0),
        jnp.moveaxis(C.astype(jnp.float32), 1, 0),
    )
    h_T, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), h_T


def ssm_apply(
    params: Dict,
    x: jax.Array,  # (B, T, d_model)
    state: Optional[Dict[str, jax.Array]] = None,  # decode: {"h", "conv"}
    d_state: int = 16,
    dt_rank: int = 0,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    dtp = params["dt_proj"]
    d_inner = (dtp["q"] if isinstance(dtp, dict) else dtp).shape[1]
    xz = mm(x, params["in_proj"])
    xs, z = jnp.split(xz, 2, axis=-1)
    conv_cache = state["conv"] if state is not None else None
    xs, new_conv = causal_conv1d(xs, params["conv_w"], params["conv_b"], conv_cache)
    xs = jax.nn.silu(xs)
    proj = mm(xs, params["x_proj"])
    dt, Bc, Cc = jnp.split(proj, [dt_rank, dt_rank + d_state], axis=-1)
    delta = jax.nn.softplus(mm(dt, params["dt_proj"]) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])  # (Di, N)
    h0 = state["h"] if state is not None else None
    y, h_T = _ssm_scan(xs, delta, A, Bc, Cc, h0)
    y = y.astype(x.dtype) + xs * params["D"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = mm(y, params["out_proj"])
    new_state = {"h": h_T, "conv": new_conv} if state is not None else None
    return out, new_state
