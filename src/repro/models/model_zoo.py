"""Unified model API: build any assigned architecture from its ArchConfig.

``build(cfg)`` returns a ``ModelBundle`` of pure functions:
    init(rng)                      -> (params, logical_specs)
    loss(params, batch)            -> scalar          (train step body)
    prefill(params, batch)         -> last-token logits
    init_state(batch, max_len)     -> decode cache/state pytree
    decode(params, token, state)   -> (logits, new state)
    input_specs(shape)             -> ShapeDtypeStruct batch for the dry-run
plus ``state_specs``/``batch_specs`` logical-axis trees for sharding.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeCell
from repro.models import lstm_lm, mamba, recurrentgemma, transformer, whisper


@dataclasses.dataclass
class ModelBundle:
    cfg: ArchConfig
    init: Callable
    loss: Callable  # (params, batch, constrain, mesh) -> scalar
    prefill: Callable
    init_state: Callable
    decode: Callable
    input_specs: Callable  # (ShapeCell,) -> dict of ShapeDtypeStruct


def _tokens_specs(cfg: ArchConfig, cell: ShapeCell) -> Dict[str, Any]:
    B, S = cell.global_batch, cell.seq_len
    tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if cell.kind == "train":
        batch = {"tokens": tok, "labels": tok}
        if cfg.family == "vlm":
            S_text = S - cfg.n_frontend_tokens
            batch = {
                "tokens": jax.ShapeDtypeStruct((B, S_text), jnp.int32),
                "labels": jax.ShapeDtypeStruct((B, S_text), jnp.int32),
                "frontend_embeds": jax.ShapeDtypeStruct(
                    (B, cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16),
            }
        if cfg.family == "encdec":
            batch = {
                "tokens": tok,
                "labels": tok,
                "frontend_embeds": jax.ShapeDtypeStruct(
                    (B, whisper.N_FRAMES, cfg.d_model), jnp.bfloat16),
            }
        return batch
    if cell.kind == "prefill":
        batch = {"tokens": tok}
        if cfg.family == "vlm":
            batch["frontend_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16)
            batch["tokens"] = jax.ShapeDtypeStruct(
                (B, S - cfg.n_frontend_tokens), jnp.int32)
        if cfg.family == "encdec":
            batch["frontend_embeds"] = jax.ShapeDtypeStruct(
                (B, whisper.N_FRAMES, cfg.d_model), jnp.bfloat16)
        return batch
    # decode: one new token + a seq_len-deep cache
    return {"token": jax.ShapeDtypeStruct((B, 1), jnp.int32)}


def build(cfg: ArchConfig) -> ModelBundle:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        mod = transformer

        def prefill_fn(params, batch, constrain, mesh=None):
            return transformer.prefill(
                params, cfg, batch["tokens"], constrain, mesh,
                frontend_embeds=batch.get("frontend_embeds"))

        def init_state(batch, max_len, quantized=False):
            return transformer.init_decode_cache(
                cfg, batch, max_len, quantized=quantized)

        def decode_fn(params, token, state, constrain, mesh=None):
            return transformer.decode_step(
                params, cfg, token, state, constrain, mesh)

        def loss(params, batch, constrain, mesh=None):
            return transformer.loss_fn(params, cfg, batch, constrain, mesh)

        init = functools.partial(transformer.init_params, cfg=cfg)
    elif fam == "hybrid":
        mod = recurrentgemma
        prefill_fn = lambda p, b, c, mesh=None: recurrentgemma.prefill(
            p, cfg, b["tokens"], c, mesh)
        init_state = lambda batch, max_len, quantized=False: (
            recurrentgemma.init_decode_state(
                cfg, batch, min(cfg.attn_window, max_len)))
        decode_fn = lambda p, t, s, c, mesh=None: recurrentgemma.decode_step(
            p, cfg, t, s, c, mesh)
        loss = lambda p, b, c, mesh=None: recurrentgemma.loss_fn(
            p, cfg, b, c, mesh)
        init = functools.partial(recurrentgemma.init_params, cfg=cfg)
    elif fam == "ssm":
        mod = mamba
        prefill_fn = lambda p, b, c, mesh=None: mamba.prefill(
            p, cfg, b["tokens"], c, mesh)
        init_state = lambda batch, max_len, quantized=False: (
            mamba.init_decode_state(cfg, batch))
        decode_fn = lambda p, t, s, c, mesh=None: mamba.decode_step(
            p, cfg, t, s, c, mesh)
        loss = lambda p, b, c, mesh=None: mamba.loss_fn(p, cfg, b, c, mesh)
        init = functools.partial(mamba.init_params, cfg=cfg)
    elif fam == "encdec":
        mod = whisper
        prefill_fn = lambda p, b, c, mesh=None: whisper.prefill(
            p, cfg, b["tokens"], b["frontend_embeds"], c, mesh)
        init_state = lambda batch, max_len, quantized=False: (
            whisper.init_decode_state(cfg, batch, max_len))
        decode_fn = lambda p, t, s, c, mesh=None: whisper.decode_step(
            p, cfg, t, s, c, mesh)
        loss = lambda p, b, c, mesh=None: whisper.loss_fn(p, cfg, b, c, mesh)
        init = functools.partial(whisper.init_params, cfg=cfg)
    elif fam == "lstm":
        # the "lstm" family covers every QuantRecurrentCell-backed recurrent
        # LM (lstm-rnnt, gru-rnnt, ...): lstm_lm dispatches the per-step math
        # on cfg.rnn_cell, so one registration serves the whole cell zoo
        mod = lstm_lm
        prefill_fn = lambda p, b, c, mesh=None: lstm_lm.prefill(
            p, cfg, b["tokens"], c, mesh)
        init_state = lambda batch, max_len, quantized=False: (
            lstm_lm.init_decode_state(cfg, batch))
        decode_fn = lambda p, t, s, c, mesh=None: lstm_lm.decode_step(
            p, cfg, t, s, c, mesh)
        loss = lambda p, b, c, mesh=None: lstm_lm.loss_fn(p, cfg, b, c, mesh)
        init = functools.partial(lstm_lm.init_params, cfg=cfg)
    else:
        raise ValueError(fam)

    return ModelBundle(
        cfg=cfg,
        init=init,
        loss=loss,
        prefill=prefill_fn,
        init_state=init_state,
        decode=decode_fn,
        input_specs=functools.partial(_tokens_specs, cfg),
    )


def count_params(params) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params)
               if hasattr(x, "size"))
