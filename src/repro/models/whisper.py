"""Whisper-tiny backbone: encoder-decoder transformer with stubbed frontend.

Per the assignment, the conv/mel frontend is a STUB: ``input_specs`` provides
precomputed frame embeddings (B, n_frames, d_model).  The encoder is
bidirectional; the decoder has causal self-attention + cross-attention with
learned positions (no RoPE), matching the Whisper architecture.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.layers import attention as attn_lib
from repro.layers import embedding as emb
from repro.layers.common import dense_init, layernorm, norm_init
from repro.layers.mlp import mlp_apply, mlp_init
from repro.layers.qmm import mm

MAX_TEXT_POS = 32768 + 8
N_FRAMES = 1500


def _attn_init(key, d: int, H: int, prefix: str, params, specs):
    ks = jax.random.split(key, 4)
    hd = d // H
    params[f"{prefix}_wq"], specs[f"{prefix}_wq"] = dense_init(ks[0], (d, d), ("embed", "heads"))
    params[f"{prefix}_wk"], specs[f"{prefix}_wk"] = dense_init(ks[1], (d, d), ("embed", "heads"))
    params[f"{prefix}_wv"], specs[f"{prefix}_wv"] = dense_init(ks[2], (d, d), ("embed", "heads"))
    params[f"{prefix}_wo"], specs[f"{prefix}_wo"] = dense_init(ks[3], (d, d), ("heads", "embed"))


def _enc_layer_init(key, cfg: ArchConfig):
    params, specs = {}, {}
    ks = jax.random.split(key, 2)
    norm_init("layernorm", cfg.d_model, "norm_attn", params, specs)
    norm_init("layernorm", cfg.d_model, "norm_mlp", params, specs)
    _attn_init(ks[0], cfg.d_model, cfg.n_heads, "self", params, specs)
    mlp_init(ks[1], cfg.d_model, cfg.d_ff, "gelu", params, specs)
    return params, specs


def _dec_layer_init(key, cfg: ArchConfig):
    params, specs = {}, {}
    ks = jax.random.split(key, 3)
    norm_init("layernorm", cfg.d_model, "norm_self", params, specs)
    norm_init("layernorm", cfg.d_model, "norm_cross", params, specs)
    norm_init("layernorm", cfg.d_model, "norm_mlp", params, specs)
    _attn_init(ks[0], cfg.d_model, cfg.n_heads, "self", params, specs)
    _attn_init(ks[1], cfg.d_model, cfg.n_heads, "cross", params, specs)
    mlp_init(ks[2], cfg.d_model, cfg.d_ff, "gelu", params, specs)
    return params, specs


def init_params(key, cfg: ArchConfig) -> Tuple[Dict, Dict]:
    params: Dict[str, Any] = {}
    specs: Dict[str, Any] = {}
    ks = jax.random.split(key, 6)
    emb.embed_init(ks[0], cfg.vocab_size, cfg.d_model, params, specs, tie=True)
    params["pos_dec"], specs["pos_dec"] = dense_init(
        ks[1], (MAX_TEXT_POS, cfg.d_model), (None, "embed"), scale=0.02)
    # sinusoidal encoder positions (fixed)
    pos = np.arange(N_FRAMES)[:, None]
    dim = np.arange(cfg.d_model // 2)[None]
    ang = pos / (10000 ** (dim / (cfg.d_model // 2)))
    pe = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    params["pos_enc"], specs["pos_enc"] = (
        jnp.asarray(pe, jnp.bfloat16), (None, "embed"))
    norm_init("layernorm", cfg.d_model, "norm_enc_final", params, specs)
    norm_init("layernorm", cfg.d_model, "norm_dec_final", params, specs)
    params["enc_layers"] = [
        _enc_layer_init(k, cfg)[0]
        for k in jax.random.split(ks[2], cfg.enc_layers)]
    specs["enc_layers"] = [
        _enc_layer_init(ks[2], cfg)[1] for _ in range(cfg.enc_layers)]
    params["dec_layers"] = [
        _dec_layer_init(k, cfg)[0]
        for k in jax.random.split(ks[3], cfg.n_layers)]
    specs["dec_layers"] = [
        _dec_layer_init(ks[3], cfg)[1] for _ in range(cfg.n_layers)]
    return params, specs


def _mha(p, prefix, xq, xkv, H, causal, cache=None, pos=None):
    B, Sq, d = xq.shape
    hd = d // H
    if xkv is None:
        xkv = xq  # self-attention
    q = mm(xq, p[f"{prefix}_wq"]).reshape(B, Sq, H, hd)
    if cache is not None and prefix == "cross":
        k, v = cache["k"], cache["v"]  # precomputed encoder K/V
        o = attn_lib.decode_attention(q, k, v, jnp.int32(k.shape[1]))
        return mm(o.reshape(B, Sq, d), p[f"{prefix}_wo"]), cache
    k = mm(xkv, p[f"{prefix}_wk"]).reshape(B, -1, H, hd)
    v = mm(xkv, p[f"{prefix}_wv"]).reshape(B, -1, H, hd)
    if cache is not None:  # decode self-attention
        kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), pos, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), pos, axis=1)
        o = attn_lib.decode_attention(q, kc, vc, pos + 1)
        return mm(o.reshape(B, Sq, d), p[f"{prefix}_wo"]), {"k": kc, "v": vc}
    Sk = k.shape[1]
    if Sk > 2048:
        o = attn_lib.flash_attention(q, k, v, causal=causal)
    else:
        o = attn_lib.full_attention(q, k, v, causal=causal)
    return mm(o.reshape(B, Sq, d), p[f"{prefix}_wo"]), None


def encode(params, cfg: ArchConfig, frames: jax.Array, constrain) -> jax.Array:
    """frames: (B, N_FRAMES, d_model) precomputed embeddings (frontend stub)."""
    x = frames + params["pos_enc"][None, : frames.shape[1]]
    x = constrain(x, ("batch", "seq", "embed"))
    for p in params["enc_layers"]:
        h, _ = _mha(p, "self", layernorm(x, p["norm_attn"], p.get("norm_attn_b")),
                    None, cfg.n_heads, causal=False)
        x = x + h
        x = x + mlp_apply(p, layernorm(x, p["norm_mlp"], p.get("norm_mlp_b")),
                          "gelu")
    return layernorm(x, params["norm_enc_final"], params.get("norm_enc_final_b"))


def decode_train(params, cfg: ArchConfig, tokens, enc_out, constrain):
    B, S = tokens.shape
    x = emb.embed_tokens(params, tokens) + params["pos_dec"][None, :S]
    x = constrain(x, ("batch", "seq", "embed"))
    for p in params["dec_layers"]:
        h, _ = _mha(p, "self", layernorm(x, p["norm_self"], p.get("norm_self_b")),
                    None, cfg.n_heads, causal=True)
        x = x + h
        h, _ = _mha(p, "cross", layernorm(x, p["norm_cross"], p.get("norm_cross_b")),
                    enc_out, cfg.n_heads, causal=False)
        x = x + h
        x = x + mlp_apply(p, layernorm(x, p["norm_mlp"], p.get("norm_mlp_b")),
                          "gelu")
    x = layernorm(x, params["norm_dec_final"], params.get("norm_dec_final_b"))
    return emb.logits_head(params, x)


def loss_fn(params, cfg: ArchConfig, batch, constrain, mesh=None):
    enc_out = encode(params, cfg, batch["frontend_embeds"], constrain)
    logits = decode_train(params, cfg, batch["tokens"], enc_out, constrain)
    logits = constrain(logits, ("batch", "seq", "vocab"))
    return emb.cross_entropy(logits, batch["labels"])


def init_decode_state(cfg: ArchConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16):
    hd = cfg.d_model // cfg.n_heads
    return {
        "self": [{
            "k": jnp.zeros((batch, max_len, cfg.n_heads, hd), dtype),
            "v": jnp.zeros((batch, max_len, cfg.n_heads, hd), dtype),
        } for _ in range(cfg.n_layers)],
        "cross": [{
            "k": jnp.zeros((batch, N_FRAMES, cfg.n_heads, hd), dtype),
            "v": jnp.zeros((batch, N_FRAMES, cfg.n_heads, hd), dtype),
        } for _ in range(cfg.n_layers)],
        "len": jnp.zeros((), jnp.int32),
    }


def prefill(params, cfg, tokens, frames, constrain, mesh=None):
    enc_out = encode(params, cfg, frames, constrain)
    logits = decode_train(params, cfg, tokens, enc_out, constrain)
    return logits[:, -1]


def decode_step(params, cfg: ArchConfig, token, states, constrain, mesh=None):
    pos = states["len"]
    x = emb.embed_tokens(params, token)
    x = x + jax.lax.dynamic_slice_in_dim(params["pos_dec"], pos, 1, axis=0)[None, 0:1]
    new_self = []
    for p, sc, cc in zip(params["dec_layers"], states["self"], states["cross"]):
        h, nsc = _mha(p, "self", layernorm(x, p["norm_self"], p.get("norm_self_b")),
                      None, cfg.n_heads, causal=True, cache=sc, pos=pos)
        x = x + h
        new_self.append(nsc)
        h, _ = _mha(p, "cross", layernorm(x, p["norm_cross"], p.get("norm_cross_b")),
                    None, cfg.n_heads, causal=False, cache=cc)
        x = x + h
        x = x + mlp_apply(p, layernorm(x, p["norm_mlp"], p.get("norm_mlp_b")),
                          "gelu")
    x = layernorm(x, params["norm_dec_final"], params.get("norm_dec_final_b"))
    logits = emb.logits_head(params, x[:, -1])
    new_states = {"self": new_self, "cross": states["cross"], "len": pos + 1}
    return logits, new_states
