"""Paper recipe applied to transformer serving: int8 weights + int8 KV cache.

``quantize_bundle`` wraps any ModelBundle so that:
  * every large (>=2D, >=16k-element) float weight becomes {"q": int8,
    "s": f32 per-channel} -- symmetric max/127, Table-2's weight rule;
  * embedding rows quantize per-row (gather stays int8 in HBM);
  * the decode KV cache stores int8 with per-(pos, head) scales
    (``quantized=True`` plumbing in the cache init + attention).

The forward code paths consume either representation transparently via
repro.layers.qmm, so the same model definition serves both precisions --
the "first-class feature" integration of the paper's technique.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.layers import qmm
from repro.models.model_zoo import ModelBundle

# whitelist of weight-matrix leaf names (per Table 2's weight rule); routers
# stay f32 (production MoE practice), norms/biases/dynamics params untouched
_WEIGHT_NAMES = (
    "wq", "wk", "wv", "wo", "mlp_gate", "mlp_up", "mlp_down", "moe_gate",
    "moe_up", "moe_down", "shared_gate", "shared_up", "shared_down",
    "embedding", "lm_head", "in_proj", "x_proj", "dt_proj", "out_proj",
    "rg_in", "rg_gate_r", "rg_gate_i", "rg_out", "W_proj",
    "self_wq", "self_wk", "self_wv", "self_wo",
    "cross_wq", "cross_wk", "cross_wv", "cross_wo",
)
_MIN_SIZE = 1 << 14


def _should_quantize(path: str, leaf) -> bool:
    name = path.rsplit("/", 1)[-1]
    if name not in _WEIGHT_NAMES:
        return False
    if not hasattr(leaf, "ndim") or leaf.ndim < 2:
        return False
    if leaf.dtype not in (jnp.bfloat16, jnp.float32, jnp.float16):
        return False
    return int(leaf.size) >= _MIN_SIZE


def quantize_param_tree(params) -> Any:
    """Concrete (traceable) int8 per-channel quantization of a param tree.

    Scales reduce ONLY the contraction dim (-2 for ``x @ w`` weights, -1 for
    the embedding's gather/logits dual use), preserving every leading stack
    dim -- so scan-over-layers slicing stays structurally intact:
    {"q": (L, in, out), "s": (L, out)} slices to {"q": (in, out), "s": (out,)}.
    """

    def walk(path, leaf):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        if _should_quantize(key, leaf):
            wf = leaf.astype(jnp.float32)
            if "embedding" in key:  # (vocab, d): per-row
                s = jnp.maximum(jnp.max(jnp.abs(wf), axis=-1), 1e-8) / 127.0
                q = jnp.clip(jnp.round(wf / s[..., None]), -127, 127)
                return {"q": q.astype(jnp.int8), "s": s}
            s = jnp.maximum(jnp.max(jnp.abs(wf), axis=-2), 1e-8) / 127.0
            q = jnp.clip(jnp.round(wf / s[..., None, :]), -127, 127)
            return {"q": q.astype(jnp.int8), "s": s}
        return leaf

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    leaves = [walk(p, l) for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, leaves)


def quantize_specs(specs, params_shapes) -> Any:
    """Mirror the logical-spec tree for quantized leaves."""

    def walk(path, spec_leaf):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        return spec_leaf

    # spec trees parallel the params tree but with tuple leaves; quantized
    # leaves expand to {"q": spec, "s": (spec[-1] or None,)}
    def expand(spec, shape_leaf, key):
        if _should_quantize(key, shape_leaf):
            if "embedding" in key:
                return {"q": spec, "s": spec[:-1] if spec else (None,)}
            return {"q": spec,
                    "s": (spec[:-2] + spec[-1:]) if spec else (None,)}
        return spec

    flat_shapes, treedef = jax.tree_util.tree_flatten_with_path(params_shapes)
    flat_specs = treedef.flatten_up_to(specs)
    out = []
    for (path, shape_leaf), spec in zip(flat_shapes, flat_specs):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append(expand(spec, shape_leaf, key))
    return jax.tree_util.tree_unflatten(treedef, out)


def quantize_bundle(bundle: ModelBundle) -> ModelBundle:
    orig_init = bundle.init

    def init(key):
        params, specs = orig_init(key)
        qparams = quantize_param_tree(params)
        qspecs = quantize_specs(specs, params)
        return qparams, qspecs

    def init_state(batch, max_len, quantized=True):
        return bundle.init_state(batch, max_len, quantized=True)

    return dataclasses.replace(bundle, init=init, init_state=init_state)
