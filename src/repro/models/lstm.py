"""Float LSTM reference: all topology variants covered by the paper (sec 2).

Variants (composable flags, eqs 1-7):
  * peephole connections  P (.) c      [Gers et al.]
  * CIFG: coupled input/forget gate    i = 1 - f     [Greff et al.]
  * projection layer      h = W_proj m + b_proj      [Sak et al.]
  * layer normalization   norm(.) (.) L + b          [Ba et al.]

This float graph is (a) the accuracy baseline, (b) the calibration vehicle
(via ``TapCollector`` taps at every Table-2 tensor), and (c) the QAT graph
(W and R deliberately kept un-concatenated per fig 16 so each matmul carries
its own fake-quant scale).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fake_quant as fq

GATES = ("i", "f", "z", "o")  # input, forget, update (cell), output


@dataclasses.dataclass(frozen=True)
class LSTMVariant:
    use_layernorm: bool = False
    use_projection: bool = False
    use_peephole: bool = False
    use_cifg: bool = False

    @property
    def gates(self) -> Tuple[str, ...]:
        return tuple(g for g in GATES if not (self.use_cifg and g == "i"))

    @property
    def name(self) -> str:
        parts = []
        parts.append("LN" if self.use_layernorm else "noLN")
        parts.append("Proj" if self.use_projection else "noProj")
        parts.append("PH" if self.use_peephole else "noPH")
        if self.use_cifg:
            parts.append("CIFG")
        return "-".join(parts)


ALL_VARIANTS = tuple(
    LSTMVariant(ln, proj, ph, cifg)
    for ln in (False, True)
    for proj in (False, True)
    for ph in (False, True)
    for cifg in (False, True)
)


@dataclasses.dataclass(frozen=True)
class LSTMConfig:
    d_input: int
    d_hidden: int
    d_proj: int = 0  # 0 => no projection
    variant: LSTMVariant = LSTMVariant()

    @property
    def d_output(self) -> int:
        return self.d_proj if self.variant.use_projection else self.d_hidden


def init_lstm_params(key, cfg: LSTMConfig, dtype=jnp.float32) -> Dict[str, Any]:
    """One LSTM layer's parameters; per-gate W/R kept separate (fig 16)."""
    v = cfg.variant
    keys = jax.random.split(key, 16)
    k = iter(keys)

    def dense(key, shape, fan_in):
        return (jax.random.normal(key, shape) / np.sqrt(fan_in)).astype(dtype)

    params: Dict[str, Any] = {"W": {}, "R": {}, "b": {}}
    for g in v.gates:
        params["W"][g] = dense(next(k), (cfg.d_input, cfg.d_hidden), cfg.d_input)
        params["R"][g] = dense(next(k), (cfg.d_output, cfg.d_hidden), cfg.d_output)
        params["b"][g] = jnp.zeros((cfg.d_hidden,), dtype)
    if v.use_peephole:
        params["P"] = {
            g: 0.1 * jax.random.normal(next(k), (cfg.d_hidden,)).astype(dtype)
            for g in v.gates
            if g != "z"
        }
    if v.use_layernorm:
        params["L"] = {g: jnp.ones((cfg.d_hidden,), dtype) for g in v.gates}
    if v.use_projection:
        params["W_proj"] = dense(next(k), (cfg.d_hidden, cfg.d_proj), cfg.d_hidden)
        params["b_proj"] = jnp.zeros((cfg.d_proj,), dtype)
    return params


def _layernorm_stats(x: jax.Array) -> jax.Array:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-12)


def lstm_cell(
    params: Dict[str, Any],
    cfg: LSTMConfig,
    x: jax.Array,
    h: jax.Array,
    c: jax.Array,
    collector=None,
    qat: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """One float LSTM step (eqs 1-7).  x: (B, d_in); h: (B, d_out); c: (B, d_h).

    ``collector``: optional TapCollector registering every Table-2 range.
    ``qat``: apply straight-through fake quant at the Table-2 tap points.
    """
    v = cfg.variant

    def tap(name, t):
        return collector.tap(name, t) if collector is not None else t

    def maybe_fq(t, **kw):
        return fq.fake_quant_asymmetric(t, **kw) if qat else t

    x = tap("x", x)
    h = tap("h", h)
    if qat:
        x = fq.fake_quant_asymmetric(x, bits=8)
        h = fq.fake_quant_asymmetric(h, bits=8)

    def gate_preact(g: str, c_for_peephole: Optional[jax.Array]):
        W = params["W"][g]
        R = params["R"][g]
        if qat:
            W = fq.fake_quant_symmetric(W, bits=8)
            R = fq.fake_quant_symmetric(R, bits=8)
        acc = x @ W + h @ R
        if v.use_peephole and g != "z" and c_for_peephole is not None:
            P = params["P"][g]
            if qat:
                P = fq.fake_quant_symmetric(P, bits=16)
            acc = acc + P * c_for_peephole
        acc = tap(f"g_{g}", acc)  # Table-2 row g_lambda (LN output scale)
        if v.use_layernorm:
            acc = _layernorm_stats(acc) * params["L"][g] + params["b"][g]
        else:
            acc = acc + params["b"][g]
        if qat:
            acc = fq.fake_quant_q(acc, fractional_bits=12)  # Q3.12 activation in
        return acc

    f_t = jax.nn.sigmoid(gate_preact("f", c))
    z_t = jnp.tanh(gate_preact("z", None))
    if v.use_cifg:
        i_t = 1.0 - f_t
    else:
        i_t = jax.nn.sigmoid(gate_preact("i", c))
    c_new = i_t * z_t + f_t * c
    c_new = tap("c", c_new)
    if qat:
        c_new = fq.fake_quant_symmetric(c_new, bits=16, pot=True)
    o_t = jax.nn.sigmoid(gate_preact("o", c_new))
    m_t = o_t * jnp.tanh(c_new)
    m_t = tap("m", m_t)
    if v.use_projection:
        if qat:
            m_t = fq.fake_quant_asymmetric(m_t, bits=8)
        Wp = params["W_proj"]
        if qat:
            Wp = fq.fake_quant_symmetric(Wp, bits=8)
        h_new = m_t @ Wp + params["b_proj"]
    else:
        h_new = m_t
    h_new = tap("h_out", h_new)
    return h_new, c_new


def lstm_layer(
    params: Dict[str, Any],
    cfg: LSTMConfig,
    xs: jax.Array,
    h0: Optional[jax.Array] = None,
    c0: Optional[jax.Array] = None,
    collector=None,
    qat: bool = False,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Run a layer over time.  xs: (B, T, d_in) -> (B, T, d_out)."""
    B = xs.shape[0]
    if h0 is None:
        h0 = jnp.zeros((B, cfg.d_output), xs.dtype)
    if c0 is None:
        c0 = jnp.zeros((B, cfg.d_hidden), xs.dtype)

    if collector is not None:
        # Calibration path: unrolled python loop so taps aggregate across
        # steps without threading carry types through lax.scan.
        h, c = h0, c0
        outs = []
        for t in range(xs.shape[1]):
            h, c = lstm_cell(params, cfg, xs[:, t], h, c, collector, qat)
            outs.append(h)
        return jnp.stack(outs, axis=1), (h, c)

    def step(carry, x_t):
        h, c = carry
        h, c = lstm_cell(params, cfg, x_t, h, c, None, qat)
        return (h, c), h

    (h, c), ys = jax.lax.scan(step, (h0, c0), jnp.swapaxes(xs, 0, 1))
    return jnp.swapaxes(ys, 0, 1), (h, c)


def sparsify_params(params: Dict[str, Any], sparsity: float) -> Dict[str, Any]:
    """Magnitude pruning of the matmul weights (paper Table 1: 50% sparse)."""

    def prune(w):
        if w.ndim != 2:
            return w
        k = int(round(w.size * sparsity))
        if k == 0:
            return w
        thresh = jnp.sort(jnp.abs(w).ravel())[k - 1]
        return jnp.where(jnp.abs(w) <= thresh, 0.0, w)

    out = jax.tree_util.tree_map(prune, params)
    return out
