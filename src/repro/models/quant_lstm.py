"""Integer-only LSTM execution (the paper's core contribution, sec 3.2).

Every tensor op here is integer: int8 matmuls into int32 accumulators,
fixed-point rescales (SRDHM + shifts), int16 gemmlowp transcendentals, and
the exact limb-based integer LayerNorm.  The only float touchpoints are the
boundary helpers ``quantize_input`` / ``dequantize_output``.

Also implements the *hybrid* baseline ([Alvarez et al. 2016] / TFLite dynamic
range): int8 weights with on-the-fly float-range activation quantization --
the comparison row in the paper's Table 1.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import cell as rcell
from repro.core import fixedpoint as fp
from repro.core import integer_ops as iops
from repro.core.recipe import QLSTMSpec
from repro.kernels import ops as kops


def quantize_input(x: jax.Array, scale: float, zero_point: int) -> jax.Array:
    q = jnp.round(x / scale) + zero_point
    return jnp.clip(q, -128, 127).astype(jnp.int8)


def dequantize_output(q: jax.Array, scale: float, zero_point: int) -> jax.Array:
    return (q.astype(jnp.float32) - zero_point) * scale


def _gate_accumulators(
    arrays: Dict[str, Any],
    spec: QLSTMSpec,
    g: str,
    x_q: jax.Array,
    h_q: jax.Array,
    c_q: Optional[jax.Array],
) -> jax.Array:
    """Integer gate pre-activation -> int16 (fig 3 / fig 6 execution).

    Reads gate g's column block of the packed [i|f|z|o] weights -- the same
    buffers the fused executor consumes whole.
    """
    gs = spec.gate_spec(g)
    sl = spec.gate_block(g)
    acc_x = iops.matmul_i8_i32(x_q, arrays["W_cat"][:, sl]) + arrays["fold_x_cat"][sl]
    acc_h = iops.matmul_i8_i32(h_q, arrays["R_cat"][:, sl]) + arrays["fold_hb_cat"][sl]
    gate = fp.multiply_by_quantized_multiplier(acc_x, *gs.eff_x)
    gate = fp.saturating_add_i32(
        gate, fp.multiply_by_quantized_multiplier(acc_h, *gs.eff_h)
    )
    if gs.eff_c is not None and c_q is not None:
        acc_c = iops.matmul_i16_elementwise(arrays["P"][g], c_q)
        gate = fp.saturating_add_i32(
            gate, fp.multiply_by_quantized_multiplier(acc_c, *gs.eff_c)
        )
    return fp.saturate_i16(gate)


def _gate(
    arrays: Dict[str, Any],
    spec: QLSTMSpec,
    g: str,
    x_q: jax.Array,
    h_q: jax.Array,
    c_q: Optional[jax.Array],
) -> jax.Array:
    """Gate pre-activation in Q3.12 int16 (after optional integer LN)."""
    gate16 = _gate_accumulators(arrays, spec, g, x_q, h_q, c_q)
    if spec.use_layernorm:
        gs = spec.gate_spec(g)
        gate16 = iops.integer_layernorm(
            gate16,
            arrays["L"][g],
            arrays["Lb"][g],
            gs.ln_out[0],
            gs.ln_out[1],
        )
    return gate16


def quant_lstm_cell(
    arrays: Dict[str, Any],
    spec: QLSTMSpec,
    x_q: jax.Array,
    h_q: jax.Array,
    c_q: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    """One integer LSTM step.  x_q: int8 (B, d_in); h_q: int8; c_q: int16.

    Returns (h_new int8, c_new int16).
    """
    n_c = 15 - spec.cell_int_bits  # fractional bits of the cell state

    f16 = _gate(arrays, spec, "f", x_q, h_q, c_q)
    f_act = fp.sigmoid_q15(f16, 3).astype(jnp.int32)  # Q0.15
    z16 = _gate(arrays, spec, "z", x_q, h_q, None)
    z_act = fp.tanh_q15(z16, 3).astype(jnp.int32)  # Q0.15

    if spec.use_cifg:
        # i = 1 - f in Q0.15: 32768 - f, clamped into int16 (sec 3.2.9)
        i_act = jnp.minimum(jnp.int32(32768) - f_act, jnp.int32(32767))
    else:
        i16 = _gate(arrays, spec, "i", x_q, h_q, c_q)
        i_act = fp.sigmoid_q15(i16, 3).astype(jnp.int32)

    # c_t = shift(i*z, 30 - n_c) + shift(f*c, 15)   (sec 3.2.7, fig 12)
    iz = i_act * z_act  # Q0.30, |.| <= 2**30
    fc = f_act * c_q.astype(jnp.int32)  # Q0.15 * cell-units
    c_new = fp.saturating_add_i32(
        fp.rounding_divide_by_pot(iz, 30 - n_c),
        fp.rounding_divide_by_pot(fc, 15),
    )
    c_new = fp.saturate_i16(c_new)

    o16 = _gate(arrays, spec, "o", x_q, h_q, c_new)
    o_act = fp.sigmoid_q15(o16, 3).astype(jnp.int32)

    # m = o (.) tanh(c): tanh consumes the cell's own Q_{m.15-m} directly
    # (sec 3.2.2: no rescale to Q3.12; tanh_fp handles any integer_bits >= 0)
    g_c = fp.tanh_q15(c_new, spec.cell_int_bits).astype(jnp.int32)
    m_raw = o_act * g_c  # Q0.30
    m_q = fp.multiply_by_quantized_multiplier(m_raw, *spec.eff_m) + jnp.int32(
        spec.zp_m
    )
    m_q = fp.saturate_i8(m_q)

    if spec.use_projection:
        acc = iops.matmul_i8_i32(m_q, arrays["W_proj"]) + arrays["fold_proj"]
        h_new = fp.multiply_by_quantized_multiplier(acc, *spec.eff_proj)
        h_new = fp.saturate_i8(h_new + jnp.int32(spec.zp_h_out))
    else:
        h_new = m_q
    return h_new, c_new


def _initial_state(
    spec: QLSTMSpec,
    B: int,
    h0_q: Optional[jax.Array],
    c0_q: Optional[jax.Array],
) -> Tuple[jax.Array, jax.Array]:
    d_out = spec.cfg_d_proj if spec.use_projection else spec.cfg_d_hidden
    if h0_q is None:
        h0_q = jnp.full((B, d_out), spec.zp_h_out, jnp.int8)
    if c0_q is None:
        c0_q = jnp.zeros((B, spec.cfg_d_hidden), jnp.int16)
    return h0_q, c0_q


def reset_state_rows(
    spec: QLSTMSpec,
    h_q: jax.Array,
    c_q: jax.Array,
    row: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    """Reset batch row ``row`` of one layer's decode state to its initial
    value (hidden at its zero point, cell at integer zero).

    ``row`` may be a traced scalar, so the same jitted reset serves every
    slot of a continuous-batching decode batch.
    """
    h_q = h_q.at[row].set(jnp.int8(spec.zp_h_out))
    c_q = c_q.at[row].set(jnp.int16(0))
    return h_q, c_q


def initial_recurrent_state(spec, batch: int) -> Tuple[jax.Array, ...]:
    """t=0 state tuple for any registered cell (``core/cell.py``)."""
    return rcell.get_cell(spec).init_state(spec, batch)


def reset_recurrent_state_rows(
    spec,
    state: Tuple[jax.Array, ...],
    row: jax.Array,
) -> Tuple[jax.Array, ...]:
    """Reset batch row ``row`` of one layer's decode state to t=0 (``row``
    may be a traced scalar -- the engine's jitted slot reset)."""
    return rcell.get_cell(spec).reset_rows(spec, state, row)


def quant_recurrent_layer(
    arrays: Dict[str, Any],
    spec,
    xs_q: jax.Array,
    state0: Optional[Tuple[jax.Array, ...]] = None,
    *,
    backend: Optional[str] = None,
    valid_len: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Tuple[jax.Array, ...]]:
    """Integer layer over time, any cell.  int8 (B, T, d_in) -> (B, T, d_out).

    Dispatches through the two-stage hoisted sequence executor in
    ``repro.kernels.ops``: the whole sequence's packed gate input product
    runs as ONE time-batched int8 GEMM outside the recurrent loop, and the
    scan consumes per-step int32 slices, leaving only the recurrent matmul +
    cell update on the sequential path.  ``backend`` selects how the
    recurrent stage lowers -- ``"xla"`` (default: ``lax.scan``), ``"pallas"``
    (TPU: the persistent sequence kernel, one launch per layer with the
    state tuple in VMEM scratch), or ``"interpret"`` (the same kernel on the
    Pallas interpreter, CPU); all three are bit-exact with each other.

    ``valid_len`` (int32 ``(B,)``) selects the ragged masked executor: row b
    advances only for timesteps ``t < valid_len[b]`` and keeps its state
    frozen beyond that -- the chunked-prefill path of the serving engine.
    """
    if state0 is None:
        state0 = initial_recurrent_state(spec, xs_q.shape[0])
    if valid_len is not None:
        return kops.quant_recurrent_seq_masked(
            arrays, spec, xs_q, state0, valid_len, backend=backend
        )
    return kops.quant_recurrent_seq(
        arrays, spec, xs_q, state0, backend=backend
    )


def quant_lstm_layer(
    arrays: Dict[str, Any],
    spec: QLSTMSpec,
    xs_q: jax.Array,
    h0_q: Optional[jax.Array] = None,
    c0_q: Optional[jax.Array] = None,
    *,
    backend: Optional[str] = None,
    valid_len: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """LSTM-shaped wrapper over ``quant_recurrent_layer`` (pre-PR-8
    signature; bit-exact with the per-gate ``quant_lstm_layer_ref``)."""
    h0_q, c0_q = _initial_state(spec, xs_q.shape[0], h0_q, c0_q)
    return quant_recurrent_layer(
        arrays, spec, xs_q, (h0_q, c0_q),
        backend=backend, valid_len=valid_len)


def quant_lstm_layer_ref(
    arrays: Dict[str, Any],
    spec: QLSTMSpec,
    xs_q: jax.Array,
    h0_q: Optional[jax.Array] = None,
    c0_q: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Reference executor: per-gate matmuls (8 dot_generals per step).

    Kept as the readable ground truth the fused packed path is tested
    against bit-for-bit.
    """
    h0_q, c0_q = _initial_state(spec, xs_q.shape[0], h0_q, c0_q)

    def step(carry, x_t):
        h, c = carry
        h, c = quant_lstm_cell(arrays, spec, x_t, h, c)
        return (h, c), h

    (h, c), ys = jax.lax.scan(step, (h0_q, c0_q), jnp.swapaxes(xs_q, 0, 1))
    return jnp.swapaxes(ys, 0, 1), (h, c)


# ---------------------------------------------------------------------------
# Hybrid baseline (dynamic-range quantization; Table 1 middle rows)
# ---------------------------------------------------------------------------


def hybrid_matmul(x: jax.Array, w_q: jax.Array, s_w: float) -> jax.Array:
    """Dynamic-range hybrid matmul: float activations quantized on the fly.

    Per-batch symmetric int8 activation quantization, int8 matmul, float
    dequantization -- the [6]-style baseline the paper improves upon.
    """
    max_abs = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8)
    s_x = max_abs / 127.0
    x_q = jnp.clip(jnp.round(x / s_x), -127, 127).astype(jnp.int8)
    acc = iops.matmul_i8_i32(x_q, w_q)
    return acc.astype(jnp.float32) * (s_x * s_w)


def hybrid_weights(params: Dict[str, Any]) -> Tuple[Dict[str, Any], Dict[str, float]]:
    """Pre-quantize all matmul weights to symmetric int8 (stored once)."""
    import numpy as np

    wq: Dict[str, Any] = {"W": {}, "R": {}}
    scales: Dict[str, float] = {}
    for kind in ("W", "R"):
        for g, w in params[kind].items():
            w = np.asarray(w, np.float64)
            s = max(np.abs(w).max(), 1e-8) / 127.0
            wq[kind][g] = jnp.asarray(
                np.clip(np.round(w / s), -127, 127), jnp.int8
            )
            scales[f"{kind}_{g}"] = float(s)
    if "W_proj" in params:
        w = np.asarray(params["W_proj"], np.float64)
        s = max(np.abs(w).max(), 1e-8) / 127.0
        wq["W_proj"] = jnp.asarray(np.clip(np.round(w / s), -127, 127), jnp.int8)
        scales["W_proj"] = float(s)
    return wq, scales
