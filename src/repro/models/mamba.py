"""Falcon-Mamba-7B: attention-free Mamba-1 stack (64 layers, d_state=16)."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.layers import embedding as emb
from repro.layers import ssm as ssm_lib
from repro.layers.common import norm_apply, norm_init


def _layer_init(key, cfg: ArchConfig):
    params, specs = {}, {}
    norm_init(cfg.norm_type, cfg.d_model, "norm", params, specs)
    ssm_lib.ssm_init(key, cfg.d_model, cfg.d_inner, cfg.d_state, cfg.d_conv,
                     cfg.dt_rank(), params, specs)
    return params, specs


def init_params(key, cfg: ArchConfig) -> Tuple[Dict, Dict]:
    params: Dict[str, Any] = {}
    specs: Dict[str, Any] = {}
    k_emb, k_layers = jax.random.split(key)
    emb.embed_init(k_emb, cfg.vocab_size, cfg.d_model, params, specs,
                   cfg.tie_embeddings)
    norm_init(cfg.norm_type, cfg.d_model, "norm_final", params, specs)
    params["layers"] = jax.vmap(lambda k: _layer_init(k, cfg)[0])(
        jax.random.split(k_layers, cfg.n_layers))
    _, lspec = _layer_init(k_layers, cfg)
    specs["layers"] = jax.tree_util.tree_map(
        lambda s: ("layers",) + s, lspec, is_leaf=lambda s: isinstance(s, tuple))
    return params, specs


def forward(params, cfg: ArchConfig, tokens, constrain, mesh=None,
            train: bool = False, states: Optional[Dict] = None):
    x = emb.embed_tokens(params, tokens)
    x = constrain(x, ("batch", "seq", "embed"))

    def step(carry, scanned):
        h = carry
        if states is None:
            p = scanned
            y, _ = ssm_lib.ssm_apply(
                p, norm_apply(cfg.norm_type, h, p, "norm"), None,
                cfg.d_state, cfg.dt_rank())
            return h + y, None
        p, st = scanned
        y, nst = ssm_lib.ssm_apply(
            p, norm_apply(cfg.norm_type, h, p, "norm"), st,
            cfg.d_state, cfg.dt_rank())
        return h + y, nst

    body = step
    if train and cfg.remat != "none":
        body = jax.checkpoint(step)

    def run_stack(carry, stacked):
        if cfg.scan_layers:
            return jax.lax.scan(body, carry, stacked)
        ys = []
        for i in range(cfg.n_layers):
            sl = jax.tree_util.tree_map(lambda a: a[i], stacked)
            carry, y = body(carry, sl)
            ys.append(y)
        if ys and ys[0] is not None:
            ys = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *ys)
        else:
            ys = None
        return carry, ys

    if states is None:
        x, _ = run_stack(x, params["layers"])
        new_states = None
    else:
        x, new_layer_states = run_stack(
            x, (params["layers"], states["layers"]))
        new_states = {"layers": new_layer_states, "len": states["len"] + 1}
    x = norm_apply(cfg.norm_type, x, params, "norm_final")
    logits = emb.logits_head(params, x)
    logits = constrain(logits, ("batch", "seq", "vocab"))
    return logits, new_states


def loss_fn(params, cfg: ArchConfig, batch, constrain, mesh=None):
    logits, _ = forward(params, cfg, batch["tokens"], constrain, mesh, True)
    return emb.cross_entropy(logits, batch["labels"])


def init_decode_state(cfg: ArchConfig, batch: int, dtype=jnp.bfloat16):
    return {
        "layers": {
            "h": jnp.zeros((cfg.n_layers, batch, cfg.d_inner, cfg.d_state),
                           jnp.float32),
            "conv": jnp.zeros((cfg.n_layers, batch, cfg.d_conv - 1, cfg.d_inner),
                              dtype),
        },
        "len": jnp.zeros((), jnp.int32),
    }


def prefill(params, cfg, tokens, constrain, mesh=None):
    logits, _ = forward(params, cfg, tokens, constrain, mesh, train=False)
    return logits[:, -1]


def decode_step(params, cfg, token, states, constrain, mesh=None):
    logits, new_states = forward(params, cfg, token, constrain, mesh,
                                 train=False, states=states)
    return logits[:, -1], new_states
