"""RecurrentGemma-9B style hybrid: (RG-LRU, RG-LRU, local-attention) pattern.

38 layers = 12 x (rec, rec, attn) + (rec, rec).  Recurrent layers carry a
(B, d_rnn) state + conv cache; attention layers use a sliding-window (2048)
MQA cache, so decode state is O(window) -- the arch is sub-quadratic and runs
the long_500k cell.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.layers import attention as attn_lib
from repro.layers import embedding as emb
from repro.layers import recurrent as rec
from repro.layers.common import norm_apply, norm_init
from repro.layers.mlp import mlp_apply, mlp_init
from repro.layers.rotary import apply_rope


def _rec_layer_init(key, cfg: ArchConfig):
    params, specs = {}, {}
    ks = jax.random.split(key, 3)
    norm_init(cfg.norm_type, cfg.d_model, "norm_mix", params, specs)
    norm_init(cfg.norm_type, cfg.d_model, "norm_mlp", params, specs)
    rec.rglru_init(ks[0], cfg.d_model, cfg.d_rnn, cfg.d_conv, params, specs)
    mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_type, params, specs)
    return params, specs


def _attn_layer_init(key, cfg: ArchConfig):
    from repro.models.transformer import _layer_init

    return _layer_init(key, cfg, moe_layer=False)


def init_params(key, cfg: ArchConfig) -> Tuple[Dict, Dict]:
    params: Dict[str, Any] = {}
    specs: Dict[str, Any] = {}
    k_emb, k_rec, k_attn = jax.random.split(key, 3)
    emb.embed_init(k_emb, cfg.vocab_size, cfg.d_model, params, specs,
                   cfg.tie_embeddings)
    norm_init(cfg.norm_type, cfg.d_model, "norm_final", params, specs)
    n_rec, n_attn = _layer_counts(cfg)
    params["rec_layers"] = jax.vmap(lambda k: _rec_layer_init(k, cfg)[0])(
        jax.random.split(k_rec, n_rec))
    _, rspec = _rec_layer_init(k_rec, cfg)
    specs["rec_layers"] = jax.tree_util.tree_map(
        lambda s: ("layers",) + s, rspec, is_leaf=lambda s: isinstance(s, tuple))
    params["attn_layers"] = jax.vmap(lambda k: _attn_layer_init(k, cfg)[0])(
        jax.random.split(k_attn, n_attn))
    _, aspec = _attn_layer_init(k_attn, cfg)
    specs["attn_layers"] = jax.tree_util.tree_map(
        lambda s: ("layers",) + s, aspec, is_leaf=lambda s: isinstance(s, tuple))
    return params, specs


def _layer_counts(cfg: ArchConfig) -> Tuple[int, int]:
    """(n_recurrent, n_attention) for the 1-attn:2-rec pattern."""
    pat = cfg.block_pattern or ("rec", "rec", "attn")
    full = cfg.n_layers // len(pat)
    rem = cfg.n_layers - full * len(pat)
    n_attn = full * pat.count("attn") + sum(1 for p in pat[:rem] if p == "attn")
    return cfg.n_layers - n_attn, n_attn


def _rec_block(p, cfg, x, state, train):
    h, new_state = rec.rglru_apply(
        p, norm_apply(cfg.norm_type, x, p, "norm_mix"), state)
    x = x + h
    y = mlp_apply(p, norm_apply(cfg.norm_type, x, p, "norm_mlp"), cfg.mlp_type)
    return x + y, new_state


def _attn_block(p, cfg, x, positions, constrain, cache, train):
    from repro.models.transformer import _block

    h, _, new_cache = _block(p, cfg, x, positions, constrain, None, False,
                             train, cache=cache)
    return h, new_cache


def forward(params, cfg: ArchConfig, tokens, constrain, mesh=None,
            train: bool = False, states: Optional[Dict] = None):
    """Interleaved pattern executed as: scan(rec pairs) interspersed with
    attention layers.  For HLO compactness we scan the two homogeneous stacks
    in pattern order: rec layers are consumed two-at-a-time around each attn
    layer (matching the (rec, rec, attn) repeating unit)."""
    x = emb.embed_tokens(params, tokens)
    x = constrain(x, ("batch", "seq", "embed"))
    positions = jnp.arange(x.shape[1])
    n_rec, n_attn = _layer_counts(cfg)

    rec_states = states["rec"] if states is not None else None
    attn_caches = states["attn"] if states is not None else None
    pos = states["len"] if states is not None else None
    new_rec, new_attn = [], []

    ri, ai = 0, 0
    pat = cfg.block_pattern or ("rec", "rec", "attn")
    for li in range(cfg.n_layers):
        kind = pat[li % len(pat)]
        if kind == "rec" and ri < n_rec:
            p = jax.tree_util.tree_map(lambda a: a[ri], params["rec_layers"])
            st = None
            if rec_states is not None:
                st = jax.tree_util.tree_map(lambda a: a[ri], rec_states)
            x, nst = _rec_block(p, cfg, x, st, train)
            if nst is not None:
                new_rec.append(nst)
            ri += 1
        else:
            p = jax.tree_util.tree_map(lambda a: a[ai], params["attn_layers"])
            cache = None
            if attn_caches is not None:
                cache = {
                    "k": attn_caches["k"][ai],
                    "v": attn_caches["v"][ai],
                    "pos": pos,
                }
            if cache is None:
                x2, _ = _attn_forward_train(p, cfg, x, positions, constrain)
            else:
                x2, ncache = _attn_forward_decode(p, cfg, x, cache, constrain)
                new_attn.append(ncache)
            x = x2
            ai += 1
    x = norm_apply(cfg.norm_type, x, params, "norm_final")
    logits = emb.logits_head(params, x)
    logits = constrain(logits, ("batch", "seq", "vocab"))
    new_states = None
    if states is not None:
        new_states = {
            "rec": jax.tree_util.tree_map(lambda *a: jnp.stack(a), *new_rec),
            "attn": jax.tree_util.tree_map(lambda *a: jnp.stack(a), *new_attn),
            "len": pos + 1,
        }
    return logits, new_states


def _attn_forward_train(p, cfg, x, positions, constrain):
    from repro.models.transformer import _attention_block
    from repro.layers.mlp import mlp_apply

    h, _ = _attention_block(
        p, cfg, norm_apply(cfg.norm_type, x, p, "norm_attn"), positions,
        constrain, None)
    x = x + h
    y = mlp_apply(p, norm_apply(cfg.norm_type, x, p, "norm_mlp"), cfg.mlp_type)
    return x + y, None


def _attn_forward_decode(p, cfg, x, cache, constrain):
    from repro.models.transformer import _attention_block

    h, ncache = _attention_block(
        p, cfg, norm_apply(cfg.norm_type, x, p, "norm_attn"),
        jnp.reshape(cache["pos"], (1,)), constrain, cache)
    x = x + h
    y = mlp_apply(p, norm_apply(cfg.norm_type, x, p, "norm_mlp"), cfg.mlp_type)
    return x + y, ncache


def loss_fn(params, cfg: ArchConfig, batch, constrain, mesh=None):
    logits, _ = forward(params, cfg, batch["tokens"], constrain, mesh, True)
    return emb.cross_entropy(logits, batch["labels"])


def init_decode_state(cfg: ArchConfig, batch: int, window: int,
                      dtype=jnp.bfloat16):
    n_rec, n_attn = _layer_counts(cfg)
    return {
        "rec": {
            "h": jnp.zeros((n_rec, batch, cfg.d_rnn), jnp.float32),
            "conv": jnp.zeros((n_rec, batch, cfg.d_conv - 1, cfg.d_rnn), dtype),
        },
        "attn": {
            "k": jnp.zeros((n_attn, batch, window, cfg.n_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((n_attn, batch, window, cfg.n_kv_heads, cfg.head_dim), dtype),
        },
        "len": jnp.zeros((), jnp.int32),
    }


def prefill(params, cfg, tokens, constrain, mesh=None):
    logits, _ = forward(params, cfg, tokens, constrain, mesh, train=False)
    return logits[:, -1]


def decode_step(params, cfg, token, states, constrain, mesh=None):
    logits, new_states = forward(params, cfg, token, constrain, mesh,
                                 train=False, states=states)
    return logits[:, -1], new_states
