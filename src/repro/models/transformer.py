"""Decoder-only transformer LM: dense GQA + optional MoE + frontend stubs.

Covers qwen3-4b, stablelm-1.6b, yi-34b, qwen1.5-0.5b, internvl2-2b (patch-
embedding stub prepended), grok-1-314b and kimi-k2-1t-a32b (MoE).

Layers are scanned (stacked params on a leading "layers" axis) so the HLO
stays compact for 60+ layer configs; MoE runs expert-parallel via shard_map
(see repro.layers.moe).  Activation sharding constraints use logical axes
resolved by the active rule set.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.layers import attention as attn
from repro.layers import embedding as emb
from repro.layers import moe as moe_lib
from repro.layers import qmm
from repro.layers.common import dense_init, norm_apply, norm_init, rmsnorm
from repro.layers.mlp import mlp_apply, mlp_init
from repro.layers.rotary import apply_rope


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------


def _layer_init(key, cfg: ArchConfig, moe_layer: bool) -> Tuple[Dict, Dict]:
    params: Dict[str, Any] = {}
    specs: Dict[str, Any] = {}
    ks = jax.random.split(key, 12)
    d, H, KVH, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    norm_init(cfg.norm_type, d, "norm_attn", params, specs)
    norm_init(cfg.norm_type, d, "norm_mlp", params, specs)
    params["wq"], specs["wq"] = dense_init(ks[0], (d, H * hd), ("embed", "heads"))
    params["wk"], specs["wk"] = dense_init(ks[1], (d, KVH * hd), ("embed", "kv"))
    params["wv"], specs["wv"] = dense_init(ks[2], (d, KVH * hd), ("embed", "kv"))
    params["wo"], specs["wo"] = dense_init(ks[3], (H * hd, d), ("heads", "embed"))
    if cfg.qkv_bias:
        for n, w in (("bq", H * hd), ("bk", KVH * hd), ("bv", KVH * hd)):
            params[n], specs[n] = jnp.zeros((w,), jnp.bfloat16), ("heads",)
    if cfg.qk_norm:
        params["q_norm"], specs["q_norm"] = jnp.ones((hd,), jnp.bfloat16), (None,)
        params["k_norm"], specs["k_norm"] = jnp.ones((hd,), jnp.bfloat16), (None,)
    if moe_layer:
        moe_lib.moe_init(ks[4], d, cfg.moe_d_ff, cfg.n_experts, params, specs)
        if cfg.n_shared_experts:
            mlp_init(ks[5], d, cfg.moe_d_ff * cfg.n_shared_experts,
                     cfg.mlp_type, params, specs, prefix="shared")
    else:
        d_ff = cfg.dense_d_ff or cfg.d_ff
        mlp_init(ks[5], d, d_ff, cfg.mlp_type, params, specs)
    return params, specs


def init_params(key, cfg: ArchConfig) -> Tuple[Dict, Dict]:
    params: Dict[str, Any] = {}
    specs: Dict[str, Any] = {}
    k_emb, k_layers, k_dense, k_final = jax.random.split(key, 4)
    emb.embed_init(k_emb, cfg.vocab_size, cfg.d_model, params, specs,
                   cfg.tie_embeddings)
    norm_init(cfg.norm_type, cfg.d_model, "norm_final", params, specs)

    n_scan = cfg.n_layers - cfg.n_dense_layers
    moe_layer = cfg.n_experts > 0
    if cfg.n_dense_layers:
        dp = jax.vmap(lambda k: _layer_init(k, cfg, moe_layer=False)[0])(
            jax.random.split(k_dense, cfg.n_dense_layers)
        )
        _, dspec = _layer_init(k_dense, cfg, moe_layer=False)
        params["dense_layers"] = dp
        specs["dense_layers"] = jax.tree_util.tree_map(
            lambda s: ("layers",) + s, dspec,
            is_leaf=lambda s: isinstance(s, tuple),
        )
    lp = jax.vmap(lambda k: _layer_init(k, cfg, moe_layer)[0])(
        jax.random.split(k_layers, n_scan)
    )
    _, lspec = _layer_init(k_layers, cfg, moe_layer)
    params["layers"] = lp
    specs["layers"] = jax.tree_util.tree_map(
        lambda s: ("layers",) + s, lspec, is_leaf=lambda s: isinstance(s, tuple)
    )
    return params, specs


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------


def _qk_normalize(cfg, q, k, p):
    if not cfg.qk_norm:
        return q, k
    return rmsnorm(q, p["q_norm"]), rmsnorm(k, p["k_norm"])


def _attention_block(
    p: Dict,
    cfg: ArchConfig,
    x: jax.Array,  # (B, S, d)
    positions: jax.Array,  # (S,) or (B, S)
    constrain: Callable,
    cache: Optional[Dict] = None,
    layer_idx: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[Dict]]:
    B, S, d = x.shape
    H, KVH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = qmm.mm(x, p["wq"])
    k = qmm.mm(x, p["wk"])
    v = qmm.mm(x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KVH, hd)
    v = v.reshape(B, S, KVH, hd)
    q, k = _qk_normalize(cfg, q, k, p)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, ("batch", "seq", "heads", "head_dim"))
    k = constrain(k, ("batch", "seq", "kv", "head_dim"))
    v = constrain(v, ("batch", "seq", "kv", "head_dim"))

    if cache is None:
        kr = attn.repeat_kv(k, H // KVH)
        vr = attn.repeat_kv(v, H // KVH)
        if S > 1024:
            o = attn.flash_attention(q, kr, vr, causal=True,
                                     window=cfg.attn_window)
        else:
            o = attn.full_attention(q, kr, vr, causal=True,
                                    window=cfg.attn_window)
        new_cache = None
    else:
        # decode: ring-buffer write at pos % S_cache (sliding-window caches
        # wrap; RoPE'd K/V are permutation-invariant under the slot mask)
        k_cache, v_cache, pos = cache["k"], cache["v"], cache["pos"]
        s_cache = k_cache.shape[1]
        wpos = pos % s_cache
        quantized = k_cache.dtype == jnp.int8
        if quantized:
            kq, ks = attn.quantize_kv(k)
            vq, vs = attn.quantize_kv(v)
            k_scale = jax.lax.dynamic_update_slice_in_dim(
                cache["k_scale"], ks.astype(cache["k_scale"].dtype), wpos, axis=1)
            v_scale = jax.lax.dynamic_update_slice_in_dim(
                cache["v_scale"], vs.astype(cache["v_scale"].dtype), wpos, axis=1)
            k, v = kq, vq
        else:
            k_scale = v_scale = None
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            k_cache, k.astype(k_cache.dtype), wpos, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            v_cache, v.astype(v_cache.dtype), wpos, axis=1)
        valid = jnp.minimum(pos + 1, s_cache)
        o = attn.decode_attention(q, k_cache, v_cache, valid, window=0,
                                  k_scale=k_scale, v_scale=v_scale)
        new_cache = {"k": k_cache, "v": v_cache}
        if quantized:
            new_cache["k_scale"] = k_scale
            new_cache["v_scale"] = v_scale
    o = o.reshape(B, S, H * hd)
    return qmm.mm(o, p["wo"]), new_cache


def _moe_or_mlp(p: Dict, cfg: ArchConfig, x: jax.Array, constrain, mesh,
                is_moe: bool, train: bool):
    B, S, d = x.shape
    if not is_moe:
        return mlp_apply(p, x, cfg.mlp_type, constrain=constrain), 0.0
    tokens = x.reshape(B * S, d)
    aux = 0.0
    if train:
        logits = tokens.astype(jnp.float32) @ p["moe_router"]
        probs = jax.nn.softmax(logits, -1)
        frac = jnp.mean(
            jax.nn.one_hot(jnp.argmax(logits, -1), cfg.n_experts), axis=0
        )
        aux = cfg.n_experts * jnp.sum(frac * probs.mean(0))
    if mesh is None or mesh.size == 1:
        y = moe_lib.moe_apply_local(
            p, tokens, n_experts=cfg.n_experts, topk=cfg.topk,
            capacity_factor=cfg.capacity_factor,
            ep_rank=jnp.int32(0), ep_size=1, model_axis=None,
        )
    else:
        from jax.sharding import PartitionSpec as P

        from repro.runtime.sharding import shard_map_compat

        ep = cfg.n_experts % mesh.shape["model"] == 0
        wspec = P("model", None, None) if ep else P(None, None, "model")
        dspec = P("model", None, None) if ep else P(None, "model", None)
        # int8 dict weights {"q": (E,d,f), "s": (E,f)} need matching spec trees
        wsspec = P("model", None) if ep else P(None, "model")
        dsspec = P("model", None) if ep else P(None, None)

        def spec_of(w, mat, scale):
            return {"q": mat, "s": scale} if qmm.is_quant(w) else mat

        gate_spec = spec_of(p["moe_gate"], wspec, wsspec)
        up_spec = spec_of(p["moe_up"], wspec, wsspec)
        down_spec = spec_of(p["moe_down"], dspec, dsspec)
        ep_size = mesh.shape["model"] if ep else 1
        dp_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)

        def body(router, gate, up, down, toks):
            rank = jax.lax.axis_index("model") if ep else jnp.int32(0)
            lp = {"moe_router": router, "moe_gate": gate, "moe_up": up,
                  "moe_down": down}
            return moe_lib.moe_apply_local(
                lp, toks, n_experts=cfg.n_experts, topk=cfg.topk,
                capacity_factor=cfg.capacity_factor,
                ep_rank=rank, ep_size=ep_size, model_axis="model",
            )

        y = shard_map_compat(
            body, mesh=mesh,
            in_specs=(P(None, None), gate_spec, up_spec, down_spec,
                      P(dp_axes, None)),
            out_specs=P(dp_axes, None),
        )(p["moe_router"], p["moe_gate"], p["moe_up"], p["moe_down"], tokens)
    y = y.reshape(B, S, d)
    if cfg.n_shared_experts:
        y = y + mlp_apply(p, x, cfg.mlp_type, prefix="shared", constrain=None)
    return y, aux


def _block(p, cfg: ArchConfig, x, positions, constrain, mesh, is_moe, train,
           cache=None):
    h, new_cache = _attention_block(p, cfg, norm_apply(cfg.norm_type, x, p, "norm_attn"),
                                    positions, constrain, cache)
    x = x + h
    y, aux = _moe_or_mlp(p, cfg, norm_apply(cfg.norm_type, x, p, "norm_mlp"),
                         constrain, mesh, is_moe, train)
    return x + y, aux, new_cache


def _run_layers(params, cfg: ArchConfig, x, positions, constrain, mesh,
                train: bool, caches: Optional[Dict] = None):
    """Scan over stacked layers (dense prefix first when configured)."""
    is_moe = cfg.n_experts > 0
    total_aux = 0.0
    pos = None if caches is None else caches["len"]

    def mk_step(moe_flag):
        def step(carry, scanned):
            h, aux_acc = carry
            if caches is None:
                p = scanned
                h2, aux, _ = _block(p, cfg, h, positions, constrain, mesh,
                                    moe_flag, train)
                return (h2, aux_acc + aux), None
            p, layer_cache = scanned
            layer_cache = dict(layer_cache, pos=pos)
            h2, aux, new_cache = _block(p, cfg, h, positions, constrain, mesh,
                                        moe_flag, train, cache=layer_cache)
            return (h2, aux_acc + aux), new_cache
        return step

    remat = cfg.remat != "none" and train
    remat_policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                    if cfg.remat == "dots" else None)

    def run_stack(step, carry, stacked, n: int):
        """lax.scan over stacked layers, or an unrolled python loop when
        cfg.scan_layers=False (the dry-run uses unrolled HLO so that
        cost_analysis counts every layer; see DESIGN.md 'scan accounting')."""
        if cfg.scan_layers:
            return jax.lax.scan(step, carry, stacked)
        ys = []
        for i in range(n):
            sl = jax.tree_util.tree_map(lambda a: a[i], stacked)
            carry, y = step(carry, sl)
            ys.append(y)
        if ys and ys[0] is not None:
            ys = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *ys)
        else:
            ys = None
        return carry, ys

    if cfg.n_dense_layers:
        step = mk_step(False)
        if remat:
            step = jax.checkpoint(step, policy=remat_policy)
        if caches is None:
            (x, total_aux), _ = run_stack(
                step, (x, total_aux), params["dense_layers"],
                cfg.n_dense_layers)
        else:
            (x, total_aux), dense_caches = run_stack(
                step, (x, total_aux),
                (params["dense_layers"], caches["dense"]), cfg.n_dense_layers)
    step = mk_step(is_moe)
    if remat:
        step = jax.checkpoint(step, policy=remat_policy)
    n_scan = cfg.n_layers - cfg.n_dense_layers
    if caches is None:
        (x, total_aux), _ = run_stack(step, (x, total_aux), params["layers"],
                                      n_scan)
        new_caches = None
    else:
        (x, total_aux), main_caches = run_stack(
            step, (x, total_aux), (params["layers"], caches["main"]), n_scan)
        new_caches = {"main": main_caches, "len": caches["len"] + 1}
        if cfg.n_dense_layers:
            new_caches["dense"] = dense_caches
    return x, total_aux, new_caches


def forward(params, cfg: ArchConfig, tokens, constrain, mesh=None,
            train: bool = False, frontend_embeds: Optional[jax.Array] = None):
    """tokens (B, S) -> logits (B, S_total, vocab).  ``frontend_embeds``
    (B, F, d) are prepended (VLM patch stub)."""
    x = emb.embed_tokens(params, tokens)
    if frontend_embeds is not None:
        x = jnp.concatenate([frontend_embeds.astype(x.dtype), x], axis=1)
    x = constrain(x, ("batch", "seq", "embed"))
    positions = jnp.arange(x.shape[1])
    x, aux, _ = _run_layers(params, cfg, x, positions, constrain, mesh, train)
    x = norm_apply(cfg.norm_type, x, params, "norm_final")
    logits = emb.logits_head(params, x)
    logits = constrain(logits, ("batch", "seq", "vocab"))
    return logits, aux


def loss_fn(params, cfg: ArchConfig, batch, constrain, mesh=None):
    frontend = batch.get("frontend_embeds")
    logits, aux = forward(params, cfg, batch["tokens"], constrain, mesh,
                          train=True, frontend_embeds=frontend)
    if frontend is not None:
        logits = logits[:, frontend.shape[1]:]
    loss = emb.cross_entropy(logits, batch["labels"])
    return loss + 0.01 * aux


# --------------------------------------------------------------------------
# serving
# --------------------------------------------------------------------------


def init_decode_cache(cfg: ArchConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16, quantized: bool = False):
    n_scan = cfg.n_layers - cfg.n_dense_layers
    kv_dtype = jnp.int8 if quantized else dtype

    def mk(L):
        c = {
            "k": jnp.zeros((L, batch, max_len, cfg.n_kv_heads, cfg.head_dim),
                           kv_dtype),
            "v": jnp.zeros((L, batch, max_len, cfg.n_kv_heads, cfg.head_dim),
                           kv_dtype),
        }
        if quantized:
            c["k_scale"] = jnp.ones((L, batch, max_len, cfg.n_kv_heads),
                                    jnp.float16)
            c["v_scale"] = jnp.ones((L, batch, max_len, cfg.n_kv_heads),
                                    jnp.float16)
        return c

    cache = {"main": mk(n_scan), "len": jnp.zeros((), jnp.int32)}
    if cfg.n_dense_layers:
        cache["dense"] = mk(cfg.n_dense_layers)
    return cache


def prefill(params, cfg: ArchConfig, tokens, constrain, mesh=None,
            max_len: Optional[int] = None,
            frontend_embeds: Optional[jax.Array] = None):
    """Run the prompt, return (last-token logits).  For the dry-run cells the
    cache write-back is elided (prefill_32k measures prompt processing)."""
    logits, _ = forward(params, cfg, tokens, constrain, mesh, train=False,
                        frontend_embeds=frontend_embeds)
    return logits[:, -1]


def decode_step(params, cfg: ArchConfig, token, caches, constrain, mesh=None):
    """token (B, 1) + caches -> (logits (B, vocab), new caches)."""
    x = emb.embed_tokens(params, token)
    x = constrain(x, ("batch", "seq", "embed"))
    positions = jnp.reshape(caches["len"], (1,))
    x, _, new_caches = _run_layers(params, cfg, x, positions, constrain, mesh,
                                   train=False, caches=caches)
    x = norm_apply(cfg.norm_type, x, params, "norm_final")
    logits = emb.logits_head(params, x[:, -1])
    logits = constrain(logits, ("batch", "vocab"))
    return logits, new_caches
