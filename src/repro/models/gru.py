"""Float GRU reference: the second cell served through the integer stack.

The paper's recipe (Table 2) is topology-agnostic -- integer-only recurrence
with 8-bit weights and mostly 8-bit activations -- and related work (iRNN)
applies it to GRUs directly.  This module is the GRU analogue of
``models/lstm.py``: the accuracy baseline and the calibration vehicle (taps
at every Table-2 tensor) for ``core/recipe.quantize_gru_layer``.

We use the cuDNN/v3 "reset-after" form so the recurrent matmul stays one
packed ``(B, H) x (H, 3H)`` GEMM (the reset gate multiplies the *output* of
``h @ R_n``, not its input):

  r = sigmoid(x W_r + h R_r + b_r)
  u = sigmoid(x W_u + h R_u + b_u)
  n = tanh(x W_n + r (.) (h R_n + b_n))
  h' = u (.) h + (1 - u) (.) n

Variants: plain and layer-normalized (LN replaces the per-gate bias add with
``norm(.) (.) L + b`` exactly as in the LSTM).  No projection/peephole/CIFG
analogues exist for GRU, so the zoo has 2 GRU variants vs the LSTM's 16.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.lstm import _layernorm_stats

GATES = ("r", "u", "n")  # reset, update, new/candidate


@dataclasses.dataclass(frozen=True)
class GRUVariant:
    use_layernorm: bool = False

    @property
    def gates(self) -> Tuple[str, ...]:
        return GATES

    @property
    def name(self) -> str:
        return "LN" if self.use_layernorm else "noLN"


ALL_VARIANTS = tuple(GRUVariant(ln) for ln in (False, True))


@dataclasses.dataclass(frozen=True)
class GRUConfig:
    d_input: int
    d_hidden: int
    variant: GRUVariant = GRUVariant()

    @property
    def d_output(self) -> int:
        return self.d_hidden


def init_gru_params(key, cfg: GRUConfig, dtype=jnp.float32) -> Dict[str, Any]:
    """One GRU layer's parameters; per-gate W/R kept separate (fig 16)."""
    v = cfg.variant
    keys = jax.random.split(key, 8)
    k = iter(keys)

    def dense(key, shape, fan_in):
        return (jax.random.normal(key, shape) / np.sqrt(fan_in)).astype(dtype)

    params: Dict[str, Any] = {"W": {}, "R": {}, "b": {}}
    for g in v.gates:
        params["W"][g] = dense(next(k), (cfg.d_input, cfg.d_hidden), cfg.d_input)
        params["R"][g] = dense(next(k), (cfg.d_hidden, cfg.d_hidden), cfg.d_hidden)
        params["b"][g] = jnp.zeros((cfg.d_hidden,), dtype)
    if v.use_layernorm:
        params["L"] = {g: jnp.ones((cfg.d_hidden,), dtype) for g in v.gates}
    return params


def gru_cell(
    params: Dict[str, Any],
    cfg: GRUConfig,
    x: jax.Array,
    h: jax.Array,
    collector=None,
) -> jax.Array:
    """One float GRU step (reset-after form).  x: (B, d_in); h: (B, d_h).

    ``collector``: optional TapCollector registering every Table-2 range.
    Tap convention matches the LSTM: ``g_<gate>`` is the pre-activation
    BEFORE layer norm and before the bias (the bias is integer-folded), and
    for ``n`` it is taken after the reset product so calibration sees the
    value the integer kernel saturates.
    """
    v = cfg.variant

    def tap(name, t):
        return collector.tap(name, t) if collector is not None else t

    x = tap("x", x)
    h = tap("h", h)

    def sigmoid_gate(g: str):
        acc = x @ params["W"][g] + h @ params["R"][g]
        acc = tap(f"g_{g}", acc)
        if v.use_layernorm:
            acc = _layernorm_stats(acc) * params["L"][g] + params["b"][g]
        else:
            acc = acc + params["b"][g]
        return jax.nn.sigmoid(acc)

    r_t = sigmoid_gate("r")
    u_t = sigmoid_gate("u")

    # candidate: reset gate scales the recurrent contribution only
    gh = h @ params["R"]["n"]
    if v.use_layernorm:
        acc = x @ params["W"]["n"] + r_t * gh
        acc = tap("g_n", acc)
        acc = _layernorm_stats(acc) * params["L"]["n"] + params["b"]["n"]
    else:
        acc = x @ params["W"]["n"] + r_t * (gh + params["b"]["n"])
        acc = tap("g_n", acc)
    n_t = jnp.tanh(acc)

    h_new = u_t * h + (1.0 - u_t) * n_t
    h_new = tap("h_out", h_new)
    return h_new


def gru_layer(
    params: Dict[str, Any],
    cfg: GRUConfig,
    xs: jax.Array,
    h0: Optional[jax.Array] = None,
    collector=None,
) -> Tuple[jax.Array, jax.Array]:
    """Run a layer over time.  xs: (B, T, d_in) -> (B, T, d_h)."""
    B = xs.shape[0]
    if h0 is None:
        h0 = jnp.zeros((B, cfg.d_hidden), xs.dtype)

    if collector is not None:
        # Calibration path: unrolled python loop so taps aggregate across
        # steps without threading carry types through lax.scan.
        h = h0
        outs = []
        for t in range(xs.shape[1]):
            h = gru_cell(params, cfg, xs[:, t], h, collector)
            outs.append(h)
        return jnp.stack(outs, axis=1), h

    def step(h, x_t):
        h = gru_cell(params, cfg, x_t, h, None)
        return h, h

    h, ys = jax.lax.scan(step, h0, jnp.swapaxes(xs, 0, 1))
    return jnp.swapaxes(ys, 0, 1), h
