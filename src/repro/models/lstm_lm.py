"""Stacked recurrent language model: the paper's architecture as a config.

10 layers x 2048 hidden (the RNN-T encoder stack of [Sak et al.] / the
paper's Table 1 models), embedding + softmax head.  Supports float
training/serving and -- via the repro.core recipe -- fully integer-only
serving (see examples/serve_quantized.py).

Cell-agnostic since PR 8: ``cfg.rnn_cell`` selects the recurrent cell
(``"lstm"`` -- the paper's LN+projection topology with a 640-wide
projection; or ``"gru"`` -- the LN reset-after GRU, no projection stage).
The stacked decode state is ``{<cell state keys...>: [per-layer arrays],
"len": counter}`` (LSTM ``{"h", "c", "len"}``, GRU ``{"h", "len"}``); every
state helper below (init/reset/slice/stack/write) iterates the cell's
declared leaves, so the serving engine, state pool, and speculation paths
never name a leaf.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.layers import embedding as emb
from repro.models import gru as G
from repro.models import lstm as L

def rnn_cell(cfg: ArchConfig) -> str:
    """The stack's recurrent cell name (pre-PR-8 configs mean LSTM)."""
    return getattr(cfg, "rnn_cell", "lstm")


def state_keys(cfg: ArchConfig) -> Tuple[str, ...]:
    """Ordered state pytree keys of the stack's cell (leaf 0 = output)."""
    from repro.core import cell as rc

    return rc.CELLS[rnn_cell(cfg)].state_key_names


def d_proj(cfg):
    """Projection width: 2048 -> 640 (Sak et al. ratio 5/16)."""
    return max(cfg.d_rnn * 5 // 16, 8)


def stack_d_out(cfg: ArchConfig) -> int:
    """Per-layer output width (what the LM head consumes)."""
    return d_proj(cfg) if rnn_cell(cfg) == "lstm" else cfg.d_rnn


def layer_cfgs(cfg: ArchConfig):
    out = []
    for i in range(cfg.n_layers):
        d_in = cfg.d_model if i == 0 else stack_d_out(cfg)
        if rnn_cell(cfg) == "gru":
            out.append(G.GRUConfig(
                d_in, cfg.d_rnn, G.GRUVariant(use_layernorm=True)))
        else:
            variant = L.LSTMVariant(use_layernorm=True, use_projection=True)
            out.append(L.LSTMConfig(d_in, cfg.d_rnn, d_proj(cfg), variant))
    return out


def init_params(key, cfg: ArchConfig) -> Tuple[Dict, Dict]:
    params: Dict[str, Any] = {}
    specs: Dict[str, Any] = {}
    ks = jax.random.split(key, cfg.n_layers + 2)
    emb.embed_init(ks[0], cfg.vocab_size, cfg.d_model, params, specs, tie=True)
    # head consumes the stack's output width, not d_model
    head = (jax.random.normal(ks[-1], (stack_d_out(cfg), cfg.vocab_size),
                              jnp.float32) * 0.02).astype(jnp.bfloat16)
    params["lm_head"], specs["lm_head"] = head, ("embed", "vocab")
    init_layer = (G.init_gru_params if rnn_cell(cfg) == "gru"
                  else L.init_lstm_params)
    # params key stays "lstm" for every cell: it names the recurrent stack
    # slot checkpoints/shardings were built around, not the cell inside it
    params["lstm"] = [
        jax.tree_util.tree_map(
            lambda x: x.astype(jnp.float32),
            init_layer(ks[i + 1], lc))
        for i, lc in enumerate(layer_cfgs(cfg))
    ]
    # matrices shard ("embed", "mlp"); vectors shard ("mlp",)
    specs["lstm"] = [
        jax.tree_util.tree_map(
            lambda x: ("embed", "mlp") if x.ndim == 2 else ("mlp",), p)
        for p in params["lstm"]
    ]
    return params, specs


def _float_layer(p, lc, x, layer_states, collector, qat):
    """One float layer step -> (ys, per-layer state tuple, leaf 0 = output).

    ``qat`` reaches only the LSTM (the QAT experiments target the paper's
    own topology); the GRU float graph is baseline + calibration only.
    """
    if isinstance(lc, G.GRUConfig):
        h0 = None if layer_states is None else layer_states[0]
        ys, h = G.gru_layer(p, lc, x, h0, collector=collector)
        return ys, (h,)
    h0, c0 = (None, None) if layer_states is None else layer_states
    ys, (h, c) = L.lstm_layer(p, lc, x, h0, c0, collector=collector, qat=qat)
    return ys, (h, c)


def forward(params, cfg: ArchConfig, tokens, constrain, mesh=None,
            train: bool = False, states=None, collector=None, qat=False):
    keys = state_keys(cfg)
    x = emb.embed_tokens(params, tokens).astype(jnp.float32)
    x = constrain(x, ("batch", "seq", "embed"))
    new_states = []
    for i, (p, lc) in enumerate(zip(params["lstm"], layer_cfgs(cfg))):
        col = _prefixed(collector, f"l{i}/") if collector is not None else None
        layer_states = (None if states is None else
                        tuple(states[k][i] for k in keys))
        x, st = _float_layer(p, lc, x, layer_states, col, qat)
        new_states.append(st)
    logits = emb.logits_head(params, x.astype(jnp.bfloat16))
    logits = constrain(logits, ("batch", "seq", "vocab"))
    if states is None:
        return logits, None
    out = {k: [s[j] for s in new_states] for j, k in enumerate(keys)}
    out["len"] = states["len"] + tokens.shape[1]
    return logits, out


class _prefixed:
    def __init__(self, collector, prefix):
        self.collector = collector
        self.prefix = prefix

    def tap(self, name, x):
        return self.collector.tap(self.prefix + name, x)


def loss_fn(params, cfg: ArchConfig, batch, constrain, mesh=None, qat=False):
    logits, _ = forward(params, cfg, batch["tokens"], constrain, mesh,
                        train=True, qat=qat)
    return emb.cross_entropy(logits, batch["labels"])


def init_decode_state(cfg: ArchConfig, batch: int):
    widths = {"h": stack_d_out(cfg), "c": cfg.d_rnn}
    out = {
        k: [jnp.zeros((batch, widths[k]), jnp.float32)
            for _ in range(cfg.n_layers)]
        for k in state_keys(cfg)
    }
    out["len"] = jnp.zeros((), jnp.int32)
    return out


def prefill(params, cfg, tokens, constrain, mesh=None):
    logits, _ = forward(params, cfg, tokens, constrain, mesh)
    return logits[:, -1]


def decode_step(params, cfg, token, states, constrain, mesh=None):
    logits, new_states = forward(params, cfg, token, constrain, mesh,
                                 states=states)
    return logits[:, -1], new_states


# ---------------------------------------------------------------------------
# Integer-only serving (paper Table 1 "integer" rows): the recurrent stack
# runs through core.recipe + the fused executor; embedding and LM head stay
# float at the quantize/dequantize boundary.
# ---------------------------------------------------------------------------


def quantize_stack(params, cfg: ArchConfig, calib_tokens):
    """Calibrate on ``calib_tokens`` and apply the Table-2 recipe per layer.

    Returns a list of ``(arrays, spec)`` pairs (one per recurrent layer) for
    ``quant_forward``; the cell-specific quantizer is picked by the config.
    """
    from repro.core import recipe as R
    from repro.core.calibrate import Stats, TapCollector

    col = TapCollector()
    forward(params, cfg, calib_tokens, lambda x, logical=None: x,
            collector=col)
    stats = Stats()
    stats.merge(jax.device_get(col.snapshot()))
    quantize_layer = (R.quantize_gru_layer if rnn_cell(cfg) == "gru"
                      else R.quantize_lstm_layer)
    return [
        quantize_layer(p, lc, stats, prefix=f"l{i}/")
        for i, (p, lc) in enumerate(zip(params["lstm"], layer_cfgs(cfg)))
    ]


def _quant_state_keys(states) -> Tuple[str, ...]:
    """Cell state keys of a stacked quantized decode state (all but len).

    Order comes from the dict, so use this ONLY where per-key handling is
    order-independent -- under ``jax.jit`` dict pytrees iterate in SORTED
    key order, not the cell's declared leaf order.
    """
    return tuple(k for k in states if k != "len")


def _cell_state_keys(qlayers) -> Tuple[str, ...]:
    """The cell's DECLARED state-leaf order (leaf 0 = output) -- what must
    be used wherever the state dict is zipped with an ordered leaf tuple."""
    from repro.core import cell as rc

    spec = qlayers[0][1]
    return rc.get_cell(spec).state_keys(spec)


def init_quant_decode_state(qlayers, batch: int, per_slot_len: bool = False):
    """Integer decode state: every cell leaf at its declared reset value
    (e.g. int8 hidden at its zero point, int16 cell at zero).

    ``per_slot_len=True`` tracks a per-row ``(batch,)`` token counter instead
    of one scalar -- what the continuous-batching engine needs, since every
    slot is at a different position in its stream.
    """
    from repro.core import cell as rc
    from repro.models.quant_lstm import initial_recurrent_state

    keys = rc.get_cell(qlayers[0][1]).state_keys(qlayers[0][1])
    cols: Dict[str, list] = {k: [] for k in keys}
    for _, spec in qlayers:
        for k, leaf in zip(keys, initial_recurrent_state(spec, batch)):
            cols[k].append(leaf)
    out: Dict[str, Any] = dict(cols)
    out["len"] = jnp.zeros((batch,) if per_slot_len else (), jnp.int32)
    return out


def reset_quant_slot(qlayers, states, slot):
    """Reset one batch row of the stacked decode state to t=0.

    ``slot`` may be a traced int32 scalar: the continuous-batching engine
    jits this once and re-uses it for every admission.
    """
    from repro.models.quant_lstm import reset_recurrent_state_rows

    keys = _cell_state_keys(qlayers)
    out: Dict[str, Any] = {k: [] for k in keys}
    for i, (_, spec) in enumerate(qlayers):
        layer = tuple(states[k][i] for k in keys)
        for k, leaf in zip(keys, reset_recurrent_state_rows(spec, layer, slot)):
            out[k].append(leaf)
    length = states["len"]
    if length.ndim:
        length = length.at[slot].set(0)
    out["len"] = length
    return out


def write_quant_slot(states, slot, row_state):
    """Write a batch-1 state into batch row ``slot`` of a stacked state.

    The resume half of preemption: ``slice_state`` (plus a host copy) parks
    a stream's state in the pool, and this puts it back into whatever slot
    the scheduler picked -- bit-exactly, because every leaf is integer and
    row computations are batch-independent.  ``slot`` may be a traced int32
    scalar: the engine jits this once and reuses it for every resume.
    """
    out = {
        k: [leaf.at[slot].set(r[0])
            for leaf, r in zip(states[k], row_state[k])]
        for k in _quant_state_keys(states)
    }
    length = states["len"]
    if length.ndim:
        row_len = jnp.asarray(row_state["len"]).reshape(-1)[0]
        length = length.at[slot].set(row_len)
    out["len"] = length
    return out


def slice_state(states, row):
    """Extract one stream's decode state as a batch-1 state (bitwise view).

    Inverse of ``stack_state``; row computations are batch-independent, so
    slicing a slot out of a continuous-batching state and decoding it alone
    continues the stream bit-exactly.
    """
    sl = slice(row, row + 1)
    length = states["len"]
    out = {k: [leaf[sl] for leaf in states[k]]
           for k in _quant_state_keys(states)}
    out["len"] = length[sl] if length.ndim else length
    return out


def stack_state(state_list):
    """Concatenate per-stream decode states along the batch axis.

    Every state must come from the same ``qlayers``; scalar ``len`` entries
    are broadcast to one counter per stacked row.
    """
    keys = _quant_state_keys(state_list[0])
    n_layers = len(state_list[0][keys[0]])
    out = {
        k: [jnp.concatenate([s[k][i] for s in state_list], axis=0)
            for i in range(n_layers)]
        for k in keys
    }
    out["len"] = jnp.concatenate([
        s["len"] if s["len"].ndim else s["len"][None] for s in state_list])
    return out


def _quant_stack(params, qlayers, tokens, states, backend, valid_len=None):
    """Run the integer recurrent stack over a ``(B, T)`` token block.

    Each layer quantizes its float input with its own calibrated (s_x, zp_x),
    runs the hoisted two-stage integer executor (``backend`` = xla | pallas |
    interpret) -- the layer's whole ``(B, T)`` input block goes through one
    time-batched packed GEMM before the recurrent scan / persistent Pallas
    sequence kernel -- and dequantizes for the next layer.  Returns the
    float stack output ``(B, T, d_out)`` plus the new per-layer states.

    ``valid_len`` (int32 ``(B,)``) selects the ragged masked executor: row b
    consumes only its first ``valid_len[b]`` tokens and freezes its
    per-layer state (and ``len`` counter) beyond that -- the chunked
    prefill path.  Outputs at positions ``>= valid_len[b]`` come from frozen
    state and must be ignored by the caller.
    """
    from repro.models import quant_lstm as QL

    keys = _cell_state_keys(qlayers)
    x = emb.embed_tokens(params, tokens).astype(jnp.float32)
    new_cols: Dict[str, list] = {k: [] for k in keys}
    for i, (arrays, spec) in enumerate(qlayers):
        x_q = QL.quantize_input(x, spec.s_x, spec.zp_x)
        ys_q, new_layer = QL.quant_recurrent_layer(
            arrays, spec, x_q, tuple(states[k][i] for k in keys),
            backend=backend, valid_len=valid_len)
        x = QL.dequantize_output(ys_q, spec.s_h, spec.zp_h_out)
        for k, leaf in zip(keys, new_layer):
            new_cols[k].append(leaf)
    advanced = tokens.shape[1] if valid_len is None else valid_len
    out: Dict[str, Any] = dict(new_cols)
    out["len"] = states["len"] + advanced
    return x, out


def quant_forward(params, qlayers, cfg: ArchConfig, tokens, states,
                  backend: str = "xla", valid_len=None):
    """Integer LSTM stack over ``tokens``: (B, T) -> logits (B, T, V).

    See ``_quant_stack`` for the layer pipeline and the ``valid_len``
    (ragged chunked-prefill) semantics.
    """
    x, new_states = _quant_stack(params, qlayers, tokens, states, backend,
                                 valid_len)
    logits = emb.logits_head(params, x.astype(jnp.bfloat16))
    return logits, new_states


def quant_chunk_step(params, qlayers, cfg: ArchConfig, tokens, states,
                     valid_len, backend: str = "xla"):
    """Chunked-prefill step: ragged stack over a ``(B, K)`` block, LM head
    evaluated ONLY at each row's last valid position.

    The engine reads one next-token distribution per row, so running the
    vocab matmul over all K positions wastes (K-1)/K of the head compute --
    gather the ``(B, d_proj)`` last-valid hidden first, then project once.
    Rows with ``valid_len == 0`` gather position 0; their logits are
    garbage-by-construction and the caller ignores them (their state is
    frozen by the masked executor).  Returns ``((B, V) logits, new states)``.
    """
    x, new_states = _quant_stack(params, qlayers, tokens, states, backend,
                                 valid_len)
    idx = jnp.maximum(valid_len - 1, 0)
    last = jnp.take_along_axis(x, idx[:, None, None], axis=1)[:, 0]
    logits = emb.logits_head(params, last.astype(jnp.bfloat16))
    return logits, new_states


def quant_verify_step(params, qlayers, cfg: ArchConfig, tokens, states,
                      valid_len, draft_len, backend: str = "xla"):
    """Speculative verify step: masked chunk forward with an all-positions
    head, in-graph acceptance, and per-row rollback to the accepted length.

    ``tokens`` is a ``(B, W)`` block where row b's first ``valid_len[b]``
    positions are real inputs: the leading ``valid_len[b] - draft_len[b]``
    are **committed** tokens (teacher-forced prompt tokens, or the fed-back
    last generated token) and the trailing ``draft_len[b]`` are **draft
    candidates** proposed by a drafter.  The step

    1. runs the ragged masked executor over the whole block ONCE from
       ``states`` and evaluates the LM head at every position (unlike
       ``quant_chunk_step``'s last-valid-only head: here each position's
       argmax is a verdict on the next draft),
    2. computes each row's **accepted length** in-graph: committed positions
       are always consumed; draft position j is consumed iff every earlier
       draft was and the model's argmax at position j-1 equals the draft
       token at j (greedy acceptance -- the draft IS what greedy decode
       would have fed),
    3. re-advances ``states`` with the masked executor to exactly the
       accepted length -- a chunk advance with per-row rollback, bit-equal
       to teacher-forcing each row's accepted prefix alone, because it IS
       that program.  State contributions of rejected positions never
       reach the committed state.

    Returns ``(pred, accepted, new_states)``: ``pred`` ``(B, W)`` int32 is
    the per-position greedy argmax (position j is the model's next token
    after consuming inputs ``0..j``; garbage for ``j >= accepted[b]``),
    ``accepted`` ``(B,)`` int32 is the number of inputs consumed
    (``valid_len - draft_len <= accepted <= valid_len``; 0 for idle rows).
    The caller emits ``pred[b, j]`` for each consumed generation position --
    up to ``draft_len + 1`` tokens per row per step, every one bit-identical
    to 1-token greedy decode by construction.
    """
    x, _ = _quant_stack(params, qlayers, tokens, states, backend, valid_len)
    logits = emb.logits_head(params, x.astype(jnp.bfloat16))
    pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    base = valid_len - draft_len
    pos = jnp.arange(tokens.shape[1], dtype=jnp.int32)[None, :]
    # draft position j matches iff the model's prediction after position
    # j-1 equals the draft fed at j (pos 0 is never a draft: base >= 1 for
    # every row that feeds anything)
    match = jnp.concatenate(
        [jnp.ones((tokens.shape[0], 1), bool), pred[:, :-1] == tokens[:, 1:]],
        axis=1)
    ok = (pos < base[:, None]) | ((pos < valid_len[:, None]) & match)
    accepted = jnp.cumprod(ok.astype(jnp.int32), axis=1).sum(axis=1)
    _, new_states = _quant_stack(params, qlayers, tokens, states, backend,
                                 accepted)
    return pred, accepted, new_states


def quant_chunk_advance(params, qlayers, cfg: ArchConfig, tokens, states,
                        valid_len, backend: str = "xla"):
    """Chunked-prefill advance: ragged stack over ``(B, K)``, state only.

    For engine steps where NO slot finishes its prompt (and none is
    generating), the next-token distribution is never read -- skip the LM
    head entirely and return no logits, so consecutive prefill chunks can be
    dispatched back-to-back without a per-step device->host sync.  The state
    trajectory is identical to ``quant_chunk_step`` (the head reads state,
    never writes it).
    """
    _, new_states = _quant_stack(params, qlayers, tokens, states, backend,
                                 valid_len)
    return new_states


def quant_prefill(params, qlayers, cfg: ArchConfig, tokens, states,
                  backend: str = "xla"):
    """Teacher-forced integer prefill in ONE scanned pass over the prompt."""
    logits, states = quant_forward(params, qlayers, cfg, tokens, states,
                                   backend=backend)
    return logits[:, -1], states


def quant_decode_step(params, qlayers, cfg: ArchConfig, token, states,
                      backend: str = "xla"):
    logits, states = quant_forward(params, qlayers, cfg, token, states,
                                   backend=backend)
    return logits[:, -1], states
