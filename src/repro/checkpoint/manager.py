"""Checkpointing: atomic, keep-K, async, mesh-elastic restore.

Layout: <dir>/step_<N>/ containing
    tree.json       -- pytree structure: list of (path, dtype, shape)
    arrays.npz      -- full (unsharded) arrays keyed by flattened path
    meta.json       -- step, data-pipeline state, mesh shape at save time

Restore takes *target* shardings, so a checkpoint written on one mesh loads
onto any other (elastic scaling / recovery onto fewer or more pods): arrays
are saved unsharded and re-placed with jax.device_put against the new mesh.
On a real multi-host fleet saves would be per-process array shards (same
tree.json contract); single-host full-array saves keep this repo runnable.

Fault tolerance contract (used by runtime.fault.run_with_restarts):
  * writes go to ``tmp_step_<N>`` then os.replace -> crash-safe,
  * ``latest_step`` scans durable directories only,
  * keep_k garbage-collects old steps after a successful save.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = leaf
    return flat


class CheckpointManager:
    def __init__(self, directory: str, keep_k: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep_k = keep_k
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree: Any, extra_meta: Optional[Dict] = None,
             block: bool = False) -> None:
        def to_host(x):
            a = np.asarray(x)
            if a.dtype.kind == "V":  # bfloat16 has no numpy dtype: store f32
                a = np.asarray(jax.numpy.asarray(x).astype(jax.numpy.float32))
            return a

        host_tree = jax.tree_util.tree_map(to_host, tree)
        if self.async_save and not block:
            self.wait()
            self._thread = threading.Thread(
                target=self._save_sync, args=(step, host_tree, extra_meta))
            self._thread.start()
        else:
            self._save_sync(step, host_tree, extra_meta)

    def _save_sync(self, step: int, host_tree, extra_meta) -> None:
        tmp = os.path.join(self.dir, f"tmp_step_{step}")
        final = os.path.join(self.dir, f"step_{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat = _flatten(host_tree)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        treedef = jax.tree_util.tree_structure(host_tree)
        with open(os.path.join(tmp, "tree.json"), "w") as f:
            json.dump({"keys": sorted(flat), "treedef": str(treedef)}, f)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump({"step": step, **(extra_meta or {})}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()

    def wait(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()
        self._thread = None

    def _gc(self) -> None:
        steps = sorted(self.steps())
        for s in steps[: -self.keep_k] if self.keep_k else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    # -- restore --------------------------------------------------------------

    def steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return out

    def latest_step(self) -> Optional[int]:
        steps = self.steps()
        return max(steps) if steps else None

    def restore(self, step: int, like_tree: Any,
                shardings: Optional[Any] = None) -> Tuple[Any, Dict]:
        """Rebuild ``like_tree``-structured state; place per ``shardings``
        (a matching tree of NamedSharding, or None for default placement)."""
        path = os.path.join(self.dir, f"step_{step}")
        arrays = np.load(os.path.join(path, "arrays.npz"))
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        flat_like = _flatten(like_tree)
        flat_shard = _flatten(shardings) if shardings is not None else {}
        restored = {}
        for key, like in flat_like.items():
            if key not in arrays:
                raise KeyError(f"checkpoint missing leaf '{key}'")
            arr = arrays[key]
            if tuple(arr.shape) != tuple(like.shape):
                raise ValueError(
                    f"shape mismatch for {key}: ckpt {arr.shape} vs {like.shape}")
            sh = flat_shard.get(key)
            placed = (jax.device_put(arr, sh) if sh is not None
                      else jax.device_put(arr))
            if hasattr(like, "dtype") and placed.dtype != like.dtype:
                placed = placed.astype(like.dtype)  # bf16 round-trip via f32
            restored[key] = placed
        # rebuild the tree in original structure
        leaves_sorted = [restored[k] for k in sorted(flat_like)]
        paths = sorted(flat_like)
        # reconstruct by walking like_tree in flatten order
        flat_order, treedef = jax.tree_util.tree_flatten_with_path(like_tree)
        ordered = []
        for path_elems, _ in flat_order:
            key = "/".join(
                str(getattr(p, "key", getattr(p, "idx", p))) for p in path_elems)
            ordered.append(restored[key])
        return jax.tree_util.tree_unflatten(treedef, ordered), meta
