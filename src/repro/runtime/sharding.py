"""Logical-axis sharding rules (MaxText-style) + divisibility-safe resolution.

A *rule set* maps logical axis names to mesh axis names.  ``resolve`` turns a
logical spec tuple (one entry per tensor dim) into a PartitionSpec, dropping
any mesh axis whose size does not divide the dimension -- this keeps every
in_sharding legal (GSPMD requires divisibility for inputs) while degrading
gracefully for small models on big meshes (e.g. whisper-tiny's 6 heads).

Profiles:
  dense_small -- TP on heads/mlp/vocab; DP on batch; weights replicated.
  dense_fsdp  -- dense_small + weights' embed dim sharded over data (ZeRO-3).
  moe_fsdp    -- dense_fsdp + experts over model (EP) with expert-mlp
                 fallback TP when n_experts < model size.
  tiny        -- DP only (whisper-tiny, lstm-rnnt).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

LogicalRules = Tuple[Tuple[str, Tuple[str, ...]], ...]

# data-parallel mesh axes (pod folds into DP on the multi-pod mesh)
DP = ("pod", "data")

PROFILES: Dict[str, Dict[str, Tuple[str, ...]]] = {
    "tiny": {
        "batch": DP,
        "seq": (),
        "embed": (),
        "heads": (),
        "kv": (),
        "head_dim": (),
        "mlp": ("model",),
        "mlp2": (),
        "vocab": ("model",),
        "experts": (),
        "expert_mlp": (),
        "layers": (),
        "state": (),
    },
    "dense_small": {
        "batch": DP,
        "seq": (),
        "embed": (),
        "heads": ("model",),
        "kv": ("model",),
        "head_dim": ("model",),  # fallback when kv-heads % model != 0
        "mlp": ("model",),
        "mlp2": (),
        "vocab": ("model",),
        "experts": (),
        "expert_mlp": ("model",),
        "layers": (),
        "state": (),
    },
}
PROFILES["dense_fsdp"] = dict(PROFILES["dense_small"], embed=("data",))
PROFILES["moe_fsdp"] = dict(
    PROFILES["dense_fsdp"], experts=("model",), expert_mlp=("model",),
)


def rules_for(profile: str) -> Dict[str, Tuple[str, ...]]:
    return PROFILES[profile]


def shard_map_compat(f, mesh, in_specs, out_specs):
    """``shard_map`` across JAX API generations, replication checks off.

    Newer JAX exports ``jax.shard_map`` (replication check kwarg
    ``check_vma``); older releases only have
    ``jax.experimental.shard_map.shard_map`` (kwarg ``check_rep``).  Every
    shard_map body in this repo disables the check (int8-compressed psum
    and capacity-dispatch MoE both confuse it), so one shim covers them
    all and callers stop caring which JAX is installed.
    """
    try:
        from jax import shard_map as _sm
        return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_vma=False)
    except ImportError:
        from jax.experimental.shard_map import shard_map as _sm
        return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=False)


def resolve(
    logical: Optional[Tuple[Optional[str], ...]],
    shape: Sequence[int],
    rules: Dict[str, Tuple[str, ...]],
    mesh: Mesh,
) -> P:
    """Logical spec tuple -> PartitionSpec, enforcing divisibility."""
    if logical is None:
        return P()
    assert len(logical) == len(shape), (logical, shape)
    used = set()
    out = []
    for dim, name in zip(shape, logical):
        if name is None or name not in rules:
            out.append(None)
            continue
        axes = []
        prod = 1
        for ax in rules[name]:
            if ax not in mesh.shape or ax in used:
                continue
            if dim % (prod * mesh.shape[ax]) == 0:
                axes.append(ax)
                prod *= mesh.shape[ax]
        used.update(axes)
        if not axes:
            out.append(None)
        elif len(axes) == 1:
            out.append(axes[0])
        else:
            out.append(tuple(axes))
    return P(*out)


def tree_shardings(specs_tree, shapes_tree, rules, mesh):
    """Map parallel (logical-spec, shape) trees to NamedShardings."""

    def leaf(spec, arr):
        shape = arr.shape if hasattr(arr, "shape") else arr
        return NamedSharding(mesh, resolve(spec, shape, rules, mesh))

    return jax.tree_util.tree_map(
        leaf, specs_tree, shapes_tree,
        is_leaf=lambda s: s is None or (
            isinstance(s, tuple) and all(isinstance(x, (str, type(None))) for x in s)
        ),
    )


def make_constrain(rules, mesh):
    """Returns constrain(x, logical_tuple) applying with_sharding_constraint.

    Degrades to identity when no mesh is active (single-device smoke tests).
    """
    if mesh is None or np.prod(list(mesh.shape.values())) == 1:
        return lambda x, logical=None: x

    def constrain(x, logical=None):
        if logical is None:
            return x
        spec = resolve(tuple(logical), x.shape, rules, mesh)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return constrain


def batch_logical(batch_tree) -> Any:
    """Default logical specs for an input batch: shard dim0 over DP axes."""

    def leaf(x):
        nd = len(x.shape)
        return ("batch",) + (None,) * (nd - 1)

    return jax.tree_util.tree_map(leaf, batch_tree)


def engine_state_shardings(state_tree, rules, mesh) -> Any:
    """NamedShardings for a continuous-batching slot state.

    The slot dimension IS the batch dimension: every per-layer ``h``/``c``
    row (and the per-slot ``len`` counter) spreads over the data-parallel
    mesh axes, so a multi-device serving deployment scales slots across
    devices while each stream's integer math stays on one shard (keeping
    the bit-exactness contract intact -- no cross-row collectives exist in
    the decode step).  Degrades to fully-replicated specs when the slot
    count does not divide the DP axes (``resolve`` divisibility rule).
    """
    if rules is None:
        rules = rules_for("tiny")
    specs = state_logical(state_tree)
    return tree_shardings(specs, state_tree, rules, mesh)


def engine_block_sharding(shape: Sequence[int], rules, mesh) -> NamedSharding:
    """NamedSharding for a per-step engine input block: the slot dim leads.

    Covers the ``(S,)`` token/active vectors of the one-token step and the
    ``(S, K)`` token block + ``(S,)`` valid-length vector of the chunked
    prefill step.  Dim 0 is the slot axis and spreads over the data-parallel
    mesh axes -- the SAME placement ``engine_state_shardings`` gives the slot
    state, so the jitted step sees consistently-sharded operands and never
    needs a resharding collective on its inputs.  Falls back to replication
    when the slot count does not divide the DP axes (``resolve``).
    """
    if rules is None:
        rules = rules_for("tiny")
    logical = ("batch",) + (None,) * (len(shape) - 1)
    return NamedSharding(mesh, resolve(logical, shape, rules, mesh))


def pool_row_shardings(row_tree, rules, mesh) -> Any:
    """NamedShardings for a batch-1 state-pool row being swapped back in.

    A pool row is ``slice_state``'s output shape: every leaf keeps its
    leading batch axis (of size 1), so the same logical specs that place the
    full slot state (``engine_state_shardings``) apply verbatim -- and the
    ``resolve`` divisibility rule necessarily drops the DP axes on the
    size-1 batch dim, replicating the row.  Routing swap-ins through this
    helper keeps pool pages and slot tensors on one placement policy: the
    jitted resume write then scatters the row into the (possibly
    DP-sharded) slot axis without the engine ever hand-picking devices.
    """
    if rules is None:
        rules = rules_for("tiny")
    specs = state_logical(row_tree)
    return tree_shardings(specs, row_tree, rules, mesh)


def fleet_device_groups(n_shards: int, devices=None):
    """Partition the local devices into ``n_shards`` contiguous,
    equal-size, disjoint groups -- the fleet router's shard placement.

    Each per-shard engine gets its own device group (and mesh), so a shard
    death is a *device-group* event: the survivors' slot tensors live on
    other devices and are untouched.  Leftover devices (when the count does
    not divide) stay unused rather than unbalancing shards.  Returns
    ``None`` when there are fewer devices than shards -- the co-located CPU
    test mode, where every shard shares the default device and placement is
    a no-op (run under ``XLA_FLAGS=--xla_force_host_platform_device_count``
    to get real groups on CPU).
    """
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    if devices is None:
        devices = jax.devices()
    if len(devices) < n_shards:
        return None
    k = len(devices) // n_shards
    return [list(devices[i * k:(i + 1) * k]) for i in range(n_shards)]


def fleet_meshes(n_shards: int, devices=None):
    """One single-axis ``("data",)`` mesh per fleet shard over disjoint
    device groups (``fleet_device_groups``), or ``[None] * n_shards`` when
    there are not enough devices (mesh-less co-located engines).

    The ``data`` axis matches the DP axes the engine's slot-state shardings
    resolve against (``engine_state_shardings`` / ``engine_block_sharding``
    with the ``tiny`` profile), so each shard's slot axis spreads over its
    own devices and never touches a neighbour shard's.
    """
    groups = fleet_device_groups(n_shards, devices)
    if groups is None:
        return [None] * n_shards
    return [Mesh(np.asarray(g), ("data",)) for g in groups]


def state_logical(state_tree) -> Any:
    """Decode cache/state logical specs, keyed on (leaf name, rank).

    KV caches shard (batch, kv-heads); SSM/RG-LRU states shard (batch, inner
    dim).  Stacked-layer tensors have the layer dim first; per-layer lists
    (whisper, lstm) have batch first.
    """

    def walk(path, x):
        shape = x.shape
        nd = len(shape)
        name = ""
        for p in reversed(path):
            k = getattr(p, "key", None)
            if isinstance(k, str):
                name = k
                break
        if nd == 0:
            return None
        if name in ("k", "v"):
            if nd == 5:  # (L, B, S, KVH, D)
                return (None, "batch", None, "kv", "head_dim")
            if nd == 4:  # (B, S, KVH, D)  [whisper lists]
                return ("batch", None, "kv", "head_dim")
        if name in ("k_scale", "v_scale"):
            if nd == 4:  # (L, B, S, KVH)
                return (None, "batch", None, "kv")
            if nd == 3:
                return ("batch", None, "kv")
        if name == "h":
            if nd == 4:  # mamba (L, B, d_inner, N)
                return (None, "batch", "mlp", None)
            if nd == 3:  # rg-lru (L, B, d_rnn)
                return (None, "batch", "mlp")
            if nd == 2:  # lstm (B, d)
                return ("batch", "mlp")
        if name == "conv":
            if nd == 4:  # (L, B, K-1, D)
                return (None, "batch", None, "mlp")
            if nd == 3:
                return ("batch", None, "mlp")
        if name == "c" and nd == 2:  # lstm cell state
            return ("batch", "mlp")
        # fallback: stacked-layer tensors (L, B, ...) vs direct (B, ...)
        if nd >= 3:
            return (None, "batch") + (None,) * (nd - 2)
        return ("batch",) + (None,) * (nd - 1)

    flat, treedef = jax.tree_util.tree_flatten_with_path(state_tree)
    return jax.tree_util.tree_unflatten(
        treedef, [walk(p, l) for p, l in flat])
