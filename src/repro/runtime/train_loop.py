"""Train-step factory: pjit with logical-rule shardings, grad accumulation,
optional QAT and int8 error-feedback gradient compression.

Nothing here materializes parameters: shapes come from ``jax.eval_shape`` over
the model's init (the logical spec tree is captured during the same abstract
trace), so the factory works for the 1T-param dry-run configs on a CPU host.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.model_zoo import ModelBundle
from repro.optim import grad_compress
from repro.optim.optimizers import OptConfig, make_optimizer
from repro.runtime import sharding as shlib


@dataclasses.dataclass
class TrainArtifacts:
    step_fn: Callable  # (params, opt_state, batch) -> (params, opt, metrics)
    init_opt: Callable
    param_shardings: Any = None
    opt_shardings: Any = None
    batch_shardings: Any = None
    param_shapes: Any = None
    logical_specs: Any = None


def abstract_init(bundle: ModelBundle, key=None):
    """(param shapes, logical specs) without materializing any parameter."""
    key = key if key is not None else jax.random.PRNGKey(0)
    captured = {}

    def arrays_only(k):
        p, s = bundle.init(k)
        captured["specs"] = s
        return p

    shapes = jax.eval_shape(arrays_only, key)
    return shapes, captured["specs"]


def _is_logical_leaf(x):
    return x is None or (
        isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x)
    )


def opt_logical_specs(opt_cfg: OptConfig, params_logical, opt_shapes):
    """Optimizer-state logical specs derived from the parameter specs."""
    if opt_cfg.name == "adamw":
        return {
            "inner": {"mu": params_logical, "nu": params_logical, "step": None},
        }
    if opt_cfg.name == "adafactor":
        def factored(spec):
            if spec is None or not isinstance(spec, tuple):
                return {"v": None}
            if len(spec) >= 2:
                return {"vr": spec[:-1], "vc": spec[:-2] + spec[-1:]}
            return {"v": spec}

        v = jax.tree_util.tree_map(
            factored, params_logical, is_leaf=_is_logical_leaf)
        return {"inner": {"v": v, "step": None}}
    raise ValueError(opt_cfg.name)


def make_train_step(
    bundle: ModelBundle,
    mesh: Optional[Mesh],
    opt_cfg: OptConfig,
    *,
    microbatches: int = 1,
    grad_compress_int8: bool = False,
    qat: bool = False,
    batch_example: Optional[Dict] = None,
    donate: bool = True,
) -> TrainArtifacts:
    cfg = bundle.cfg
    rules = shlib.rules_for(cfg.shard_profile)
    constrain = shlib.make_constrain(rules, mesh)
    opt_init, opt_update = make_optimizer(opt_cfg)

    def loss_fn(params, batch):
        if qat and cfg.family == "lstm":
            from repro.models import lstm_lm
            return lstm_lm.loss_fn(params, cfg, batch, constrain, mesh, qat=True)
        return bundle.loss(params, batch, constrain, mesh)

    def compute_grads(params, batch):
        if microbatches == 1:
            return jax.value_and_grad(loss_fn)(params, batch)

        def mb_slice(b, i):
            return jax.tree_util.tree_map(
                lambda x: jax.lax.dynamic_slice_in_dim(
                    x, i * (x.shape[0] // microbatches),
                    x.shape[0] // microbatches, axis=0),
                b)

        def body(carry, i):
            loss_acc, grad_acc = carry
            l, g = jax.value_and_grad(loss_fn)(params, mb_slice(batch, i))
            grad_acc = jax.tree_util.tree_map(
                lambda a, b_: a + b_.astype(jnp.float32), grad_acc, g)
            return (loss_acc + l, grad_acc), None

        zero_g = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, grads), _ = jax.lax.scan(
            body, (jnp.float32(0.0), zero_g), jnp.arange(microbatches))
        inv = 1.0 / microbatches
        return loss * inv, jax.tree_util.tree_map(lambda g: g * inv, grads)

    def step_fn(params, opt_state, batch):
        loss, grads = compute_grads(params, batch)
        if grad_compress_int8:
            grads, new_resid = grad_compress.ef_compress_tree(
                grads, opt_state["ef_residual"])
        new_params, new_inner, metrics = opt_update(
            grads, opt_state["inner"], params)
        new_opt = {"inner": new_inner}
        if grad_compress_int8:
            new_opt["ef_residual"] = new_resid
        return new_params, new_opt, dict(metrics, loss=loss)

    def init_opt(params):
        st = {"inner": opt_init(params)}
        if grad_compress_int8:
            st["ef_residual"] = grad_compress.ef_init(params)
        return st

    donate_args = (0, 1) if donate else ()
    if mesh is None:
        return TrainArtifacts(
            jax.jit(step_fn, donate_argnums=donate_args), init_opt)

    param_shapes, logical = abstract_init(bundle)
    param_sh = shlib.tree_shardings(logical, param_shapes, rules, mesh)
    opt_shapes = jax.eval_shape(init_opt, param_shapes)
    opt_logical = {"inner": opt_logical_specs(opt_cfg, logical, opt_shapes)["inner"]}
    if grad_compress_int8:
        opt_logical["ef_residual"] = logical
    opt_sh = shlib.tree_shardings(opt_logical, opt_shapes, rules, mesh)

    batch_sh = None
    if batch_example is not None:
        batch_sh = shlib.tree_shardings(
            shlib.batch_logical(batch_example), batch_example, rules, mesh)

    jitted = jax.jit(
        step_fn,
        in_shardings=(param_sh, opt_sh, batch_sh),
        out_shardings=(param_sh, opt_sh, None),
        donate_argnums=donate_args,
    )
    return TrainArtifacts(jitted, init_opt, param_sh, opt_sh, batch_sh,
                          param_shapes, logical)


def make_serve_fns(bundle: ModelBundle, mesh: Optional[Mesh],
                   batch: int, max_len: int, quantized_cache: bool = False):
    """(prefill_fn, decode_fn, state_shardings, param_shardings)."""
    cfg = bundle.cfg
    rules = shlib.rules_for(cfg.shard_profile)
    constrain = shlib.make_constrain(rules, mesh)

    def prefill_fn(params, b):
        return bundle.prefill(params, b, constrain, mesh)

    def decode_fn(params, token, state):
        return bundle.decode(params, token, state, constrain, mesh)

    if mesh is None:
        return jax.jit(prefill_fn), jax.jit(decode_fn), None, None

    param_shapes, logical = abstract_init(bundle)
    param_sh = shlib.tree_shardings(logical, param_shapes, rules, mesh)
    state_shapes = jax.eval_shape(
        lambda: bundle.init_state(batch, max_len, quantized=quantized_cache))
    state_sh = shlib.tree_shardings(
        shlib.state_logical(state_shapes), state_shapes, rules, mesh)
    tok_sh = NamedSharding(
        mesh, shlib.resolve(("batch", None), (batch, 1), rules, mesh))
    prefill_jit = jax.jit(prefill_fn, in_shardings=(param_sh, None))
    decode_jit = jax.jit(
        decode_fn,
        in_shardings=(param_sh, tok_sh, state_sh),
        out_shardings=(None, state_sh),
        donate_argnums=(2,),
    )
    return prefill_jit, decode_jit, state_sh, param_sh
