"""Fault tolerance: restart-from-checkpoint driver, watchdog, straggler stats.

On a real fleet the coordinator restarts failed workers and every process
re-enters ``run_with_restarts``; here we exercise the same control flow in
one process (tests inject failures) so the recovery path is real code, not
a comment.  Elasticity: on restart the mesh may differ -- restore re-places
full arrays against the new shardings (see checkpoint.manager).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import numpy as np


class StepWatchdog:
    """Detects hung/straggling steps by wall-clock against a running EMA.

    * ``timeout_factor`` x EMA -> considered HUNG (caller should abort/retry;
      on TPU fleets this is where you'd re-schedule the slice).
    * ``straggler_factor`` x EMA -> logged as straggler (mitigation hook).
    """

    def __init__(self, timeout_factor: float = 10.0,
                 straggler_factor: float = 2.0, ema: float = 0.9):
        self.timeout_factor = timeout_factor
        self.straggler_factor = straggler_factor
        self.ema_coef = ema
        self.ema_s: Optional[float] = None
        self.stragglers = 0
        self.steps = 0

    def observe(self, seconds: float) -> str:
        self.steps += 1
        verdict = "ok"
        if self.ema_s is not None:
            if seconds > self.timeout_factor * self.ema_s:
                verdict = "hung"
            elif seconds > self.straggler_factor * self.ema_s:
                verdict = "straggler"
                self.stragglers += 1
        self.ema_s = (seconds if self.ema_s is None
                      else self.ema_coef * self.ema_s + (1 - self.ema_coef) * seconds)
        return verdict


@dataclasses.dataclass
class RestartStats:
    restarts: int = 0
    completed_steps: int = 0
    resumed_from: Optional[int] = None


def run_with_restarts(
    train_chunk: Callable[[int], int],
    *,
    ckpt_latest: Callable[[], Optional[int]],
    total_steps: int,
    max_restarts: int = 10,
) -> RestartStats:
    """Drive ``train_chunk(start_step) -> reached_step`` to completion,
    restarting from the latest durable checkpoint on any exception.

    ``train_chunk`` is expected to checkpoint periodically and may raise at
    any point (node failure, preemption); restart resumes from disk.
    """
    stats = RestartStats()
    start = ckpt_latest() or 0
    stats.resumed_from = start
    while start < total_steps:
        try:
            start = train_chunk(start)
            stats.completed_steps = start
        except Exception:
            stats.restarts += 1
            if stats.restarts > max_restarts:
                raise
            resumed = ckpt_latest() or 0
            start = resumed
    return stats
