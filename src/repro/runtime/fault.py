"""Fault tolerance: restart-from-checkpoint driver, watchdog, straggler stats.

On a real fleet the coordinator restarts failed workers and every process
re-enters ``run_with_restarts``; here we exercise the same control flow in
one process (tests inject failures) so the recovery path is real code, not
a comment.  Elasticity: on restart the mesh may differ -- restore re-places
full arrays against the new shardings (see checkpoint.manager).

Production consumers (PR 9): ``launch/engine.py`` wires a
:class:`StepWatchdog` into its serving loop (per-step wall time against a
running EMA, ``stragglers``/``hung`` surfaced in ``EngineStats``) and
``launch/fleet.py``'s router treats a shard whose step goes ``hung`` as a
fault-plane event (drain + re-admit elsewhere), so the watchdog verdict is
an input to recovery, not just a log line.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional, Tuple, Type

__all__ = ["StepWatchdog", "RestartStats", "run_with_restarts",
           "RESTARTABLE_EXCEPTIONS"]


class StepWatchdog:
    """Detects hung/straggling steps by wall-clock against a running EMA.

    * ``timeout_factor`` x EMA -> considered HUNG (caller should abort/retry;
      on TPU fleets this is where you'd re-schedule the slice).
    * ``straggler_factor`` x EMA -> logged as straggler (mitigation hook).

    ``stragglers`` / ``hung`` count the verdicts so far; ``last_verdict``
    is the most recent classification (what the fleet router polls after
    each shard step).  A hung step still updates the EMA -- a genuinely
    slower regime stops alarming once the EMA catches up.
    """

    def __init__(self, timeout_factor: float = 10.0,
                 straggler_factor: float = 2.0, ema: float = 0.9):
        if timeout_factor <= straggler_factor:
            raise ValueError(
                f"timeout_factor ({timeout_factor}) must exceed "
                f"straggler_factor ({straggler_factor})")
        if not 0.0 <= ema < 1.0:
            raise ValueError(f"ema must be in [0, 1), got {ema}")
        self.timeout_factor = timeout_factor
        self.straggler_factor = straggler_factor
        self.ema_coef = ema
        self.ema_s: Optional[float] = None
        self.stragglers = 0
        self.hung = 0
        self.steps = 0
        self.last_verdict = "ok"

    def observe(self, seconds: float) -> str:
        self.steps += 1
        verdict = "ok"
        if self.ema_s is not None:
            if seconds > self.timeout_factor * self.ema_s:
                verdict = "hung"
                self.hung += 1
            elif seconds > self.straggler_factor * self.ema_s:
                verdict = "straggler"
                self.stragglers += 1
        self.ema_s = (seconds if self.ema_s is None
                      else self.ema_coef * self.ema_s + (1 - self.ema_coef) * seconds)
        self.last_verdict = verdict
        return verdict


@dataclasses.dataclass
class RestartStats:
    restarts: int = 0
    completed_steps: int = 0
    resumed_from: Optional[int] = None
    backoff_s_total: float = 0.0  # wall spent backing off between restarts


# The default restart allowlist: infrastructure failures a restart can
# plausibly cure (lost node, preempted VM, flaky filesystem/network, a step
# that the watchdog timed out).  Programming errors -- TypeError, ValueError,
# KeyError, assertion failures -- propagate immediately: restarting them
# would deterministically re-fail and burn the restart budget for nothing.
RESTARTABLE_EXCEPTIONS: Tuple[Type[BaseException], ...] = (
    RuntimeError, OSError, TimeoutError, ConnectionError,
)


def run_with_restarts(
    train_chunk: Callable[[int], int],
    *,
    ckpt_latest: Callable[[], Optional[int]],
    total_steps: int,
    max_restarts: int = 10,
    restart_on: Tuple[Type[BaseException], ...] = RESTARTABLE_EXCEPTIONS,
    backoff_s: float = 0.05,
    backoff_cap_s: float = 5.0,
    sleep: Callable[[float], None] = time.sleep,
) -> RestartStats:
    """Drive ``train_chunk(start_step) -> reached_step`` to completion,
    restarting from the latest durable checkpoint on allowlisted exceptions.

    ``train_chunk`` is expected to checkpoint periodically and may raise at
    any point (node failure, preemption); restart resumes from disk.

    Two deliberate hardenings over the naive retry loop:

    * **Exception allowlist** (``restart_on``) -- only failures a restart
      can plausibly cure are retried; anything else (a ``ValueError`` from
      bad config, a ``KeyError`` from a renamed param) propagates
      immediately instead of being retried ``max_restarts`` times.
    * **Exponential backoff with a cap** -- restart ``n`` sleeps
      ``min(backoff_s * 2**(n-1), backoff_cap_s)`` first (injectable
      ``sleep`` for tests).  A persistent failure (checkpoint dir gone,
      device wedged) therefore costs bounded wall time instead of a hot
      busy-loop that hammers the checkpoint store ``max_restarts`` times
      in microseconds.
    """
    if max_restarts < 0:
        raise ValueError(f"max_restarts must be >= 0, got {max_restarts}")
    if backoff_s < 0 or backoff_cap_s < 0:
        raise ValueError(
            f"backoff_s/backoff_cap_s must be >= 0, got "
            f"{backoff_s}/{backoff_cap_s}")
    stats = RestartStats()
    start = ckpt_latest() or 0
    stats.resumed_from = start
    while start < total_steps:
        try:
            start = train_chunk(start)
            stats.completed_steps = start
        except restart_on:
            stats.restarts += 1
            if stats.restarts > max_restarts:
                raise
            pause = min(backoff_s * (2.0 ** (stats.restarts - 1)),
                        backoff_cap_s)
            if pause > 0:
                sleep(pause)
                stats.backoff_s_total += pause
            start = ckpt_latest() or 0
    return stats
