"""``QuantRecurrentCell``: the pluggable integer recurrent cell contract.

The paper's recipe (integer-only recurrence, 8-bit weights, mostly 8-bit
activations) is not LSTM-specific, and since PR 8 neither is this stack.  A
*cell* is described by a small static descriptor that the whole vertical
slice -- recipe packing, the hoisted two-stage executors, the persistent
Pallas sequence kernel, the LM wrapper, and the serving engine/state pool --
is written against:

  * **packed-weight spec** -- a quantized layer is always ``(arrays, spec)``
    where ``arrays`` holds ``W_cat``/``R_cat``/``fold_x_cat``/``fold_hb_cat``
    (N gate blocks column-concatenated, see ``core/recipe.py``) plus any
    cell-specific extras (peephole/LN/projection tensors), and ``spec`` is a
    frozen, hashable dataclass carrying every derived scale and fixed-point
    multiplier.  ``spec.cell`` names the cell; ``get_cell(spec)`` resolves
    its descriptor.
  * **quantized state** -- an ordered tuple of :class:`StateLeaf` entries
    declaring each carry tensor's pytree key, dtype, per-row width, and the
    integer value a freshly reset row is filled with.  **Leaf 0 is the
    cell's emitted per-step output** (the ``h`` every executor returns as
    ``ys[t]``) -- the sequence kernels rely on this.
  * **recurrent_step math** -- the pure-jnp one-timestep function lives in
    ``kernels/ref.py`` (``recurrent_step_jnp`` dispatches on ``spec.cell``)
    so one definition serves the ``xla`` scan executor and the Pallas
    sequence kernel identically; descriptors stay import-light and carry no
    traced code.
  * **gate count** -- ``gate_names(spec)`` orders the packed column blocks.

Registered cells: ``lstm`` (4 gates ``[i|f|z|o]``, CIFG drops ``i``; state
``(h int8, c int16)``) and ``gru`` (3 gates ``[r|u|n]``; state ``(h int8,)``
-- one packed GEMM and a single carry vector, cheaper than LSTM per step).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax.numpy as jnp

__all__ = [
    "StateLeaf", "QuantRecurrentCell", "LSTMCell", "GRUCell",
    "CELLS", "get_cell", "register_cell",
]


@dataclasses.dataclass(frozen=True)
class StateLeaf:
    """One carry tensor of a quantized recurrent state."""

    key: str  # pytree key in the stacked decode state ({"h": ..., ...})
    dtype: Any  # integer jnp dtype
    width: int  # per-row width (trailing dim)
    reset: int  # integer fill of a freshly reset row (e.g. the h zero point)


class QuantRecurrentCell:
    """Static descriptor of one integer recurrent cell topology.

    Subclasses define ``name``, ``gate_names``, ``d_out``, and
    ``state_leaves``; the concrete state helpers below are derived.  All
    methods take the layer's quantized ``spec`` (the frozen dataclass from
    ``core/recipe.py``) -- descriptors themselves are stateless singletons.
    """

    name: str = "?"
    # pytree keys of state_leaves, statically known (no spec needed) so the
    # float LM wrapper can build state dicts before quantization exists
    state_key_names: Tuple[str, ...] = ()

    def gate_names(self, spec) -> Tuple[str, ...]:
        """Packed gate-block order (column blocks of W_cat/R_cat)."""
        raise NotImplementedError

    def d_out(self, spec) -> int:
        """Per-step output width (== state leaf 0's width)."""
        raise NotImplementedError

    def state_leaves(self, spec) -> Tuple[StateLeaf, ...]:
        """Ordered carry declaration; leaf 0 is the emitted output."""
        raise NotImplementedError

    # -- derived state helpers (shared by every cell) -----------------------

    def state_keys(self, spec) -> Tuple[str, ...]:
        return tuple(leaf.key for leaf in self.state_leaves(spec))

    def init_state(self, spec, batch: int) -> Tuple[jnp.ndarray, ...]:
        """t=0 carry: every leaf filled with its declared reset value."""
        return tuple(
            jnp.full((batch, leaf.width), leaf.reset, leaf.dtype)
            for leaf in self.state_leaves(spec))

    def reset_rows(self, spec, state: Tuple[jnp.ndarray, ...], row):
        """Reset batch row(s) ``row`` of a stacked carry to t=0 (``row``
        may be a traced int32 scalar -- the engine jits this)."""
        return tuple(
            arr.at[row].set(jnp.asarray(leaf.reset, arr.dtype))
            for arr, leaf in zip(state, self.state_leaves(spec)))


class LSTMCell(QuantRecurrentCell):
    """Paper LSTM (eqs 1-7): 4 gates ``[i|f|z|o]`` (CIFG drops ``i``),
    int8 hidden ``h`` (at the output zero point) + int16 POT cell ``c``."""

    name = "lstm"
    state_key_names = ("h", "c")

    def gate_names(self, spec) -> Tuple[str, ...]:
        return spec.variant.gates

    def d_out(self, spec) -> int:
        return spec.cfg_d_proj if spec.use_projection else spec.cfg_d_hidden

    def state_leaves(self, spec) -> Tuple[StateLeaf, ...]:
        return (
            StateLeaf("h", jnp.int8, self.d_out(spec), spec.zp_h_out),
            StateLeaf("c", jnp.int16, spec.cfg_d_hidden, 0),
        )


class GRUCell(QuantRecurrentCell):
    """Integer GRU (cuDNN/v3 reset-after form so the packed GEMM holds):
    3 gates ``[r|u|n]``, single int8 hidden ``h`` carry."""

    name = "gru"
    state_key_names = ("h",)

    def gate_names(self, spec) -> Tuple[str, ...]:
        return spec.gate_names

    def d_out(self, spec) -> int:
        return spec.cfg_d_hidden

    def state_leaves(self, spec) -> Tuple[StateLeaf, ...]:
        return (StateLeaf("h", jnp.int8, spec.cfg_d_hidden, spec.zp_h_out),)


CELLS: Dict[str, QuantRecurrentCell] = {
    "lstm": LSTMCell(),
    "gru": GRUCell(),
}


def register_cell(cell: QuantRecurrentCell) -> None:
    """Extension hook: make a new cell resolvable by ``spec.cell`` name."""
    CELLS[cell.name] = cell


def get_cell(spec) -> QuantRecurrentCell:
    """Resolve a quantized layer spec's cell descriptor.

    Specs predating the cell abstraction (no ``cell`` attribute) resolve to
    LSTM; unknown names raise -- a plain raise, not ``assert``, so the check
    survives ``python -O``.
    """
    name = getattr(spec, "cell", "lstm")
    if name not in CELLS:
        raise ValueError(
            f"unknown recurrent cell {name!r}: registered cells are "
            f"{sorted(CELLS)}")
    return CELLS[name]
