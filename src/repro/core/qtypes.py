"""Quantization dtypes, specs and quantize/dequantize transforms.

Encodes the paper's quantization fundamentals (sec 3.1):

* linear affine quantization with optionally nudged zero points [Jacob et al.],
* symmetric (weights) vs asymmetric (activations) ranges,
* power-of-two (POT) scales and the Q_{m.n} format for the LSTM cell state
  (sec 3.1.2 / 3.2.2).

A ``QTensor`` is a pytree of integer values plus a static ``QuantSpec``; the
spec rides in the pytree's aux data so jitted functions specialize on it.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import fixedpoint as fp


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """Static description of a quantized tensor's arithmetic type."""

    bits: int  # 8, 16 or 32
    scale: float  # real value = scale * (q - zero_point)
    zero_point: int = 0
    symmetric: bool = True
    pot: bool = False  # scale is a power of two (Q_{m.n} interpretable)

    @property
    def dtype(self):
        return {8: jnp.int8, 16: jnp.int16, 32: jnp.int32}[self.bits]

    @property
    def qmin(self) -> int:
        return -(2 ** (self.bits - 1))

    @property
    def qmax(self) -> int:
        # symmetric quantization restricts to +/-(2^(n-1)-1) (paper: [-127,127])
        return 2 ** (self.bits - 1) - 1

    @property
    def q_format(self) -> Tuple[int, int]:
        """(m, n) of Q_{m.n} for POT scales: scale == 2**-n, m = bits-1-n."""
        if not self.pot:
            raise ValueError("Q_{m.n} format only defined for POT scales")
        n = -int(round(math.log2(self.scale)))
        return self.bits - 1 - n, n


@jax.tree_util.register_pytree_node_class
class QTensor:
    """Quantized tensor: integer values + static QuantSpec (pytree)."""

    __slots__ = ("values", "spec")

    def __init__(self, values, spec: QuantSpec):
        self.values = values
        self.spec = spec

    @property
    def shape(self):
        return self.values.shape

    def dequantize(self, dtype=jnp.float32):
        v = self.values.astype(dtype)
        if self.spec.zero_point:
            v = v - self.spec.zero_point
        return v * self.spec.scale

    def tree_flatten(self):
        return (self.values,), self.spec

    @classmethod
    def tree_unflatten(cls, spec, children):
        return cls(children[0], spec)

    def __repr__(self):
        return f"QTensor(shape={tuple(self.values.shape)}, spec={self.spec})"


# ---------------------------------------------------------------------------
# Scale computation (python/numpy side, offline).
# ---------------------------------------------------------------------------


def symmetric_scale(max_abs: float, bits: int) -> float:
    """Paper: s = max(|T|) / (2**(bits-1) - 1); e.g. max/127, max/32767."""
    qmax = 2 ** (bits - 1) - 1
    max_abs = float(max_abs)
    if max_abs == 0.0:
        max_abs = 1e-8
    return max_abs / qmax


def asymmetric_scale_zp(t_min: float, t_max: float, bits: int) -> Tuple[float, int]:
    """Paper: s = range / (2**bits - 1) with nudged zero point [Jacob et al.].

    Guarantees float 0.0 maps exactly to an integer zero point.
    """
    t_min = min(float(t_min), 0.0)
    t_max = max(float(t_max), 0.0)
    if t_max == t_min:
        t_max = t_min + 1e-8
    qmin, qmax = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
    scale = (t_max - t_min) / (qmax - qmin)
    zp_real = qmin - t_min / scale
    zero_point = int(round(zp_real))
    zero_point = max(qmin, min(qmax, zero_point))
    return scale, zero_point


def pot_scale_for(max_abs: float, bits: int = 16) -> float:
    """Extend |max| to the next power of two (paper sec 3.2.2, 'POT(max)').

    Returns scale = POT(max) / 2**(bits-1), a power of two, giving Q_{m.n}.
    """
    max_abs = float(max_abs)
    if max_abs <= 0:
        max_abs = 1.0
    pot = 2.0 ** math.ceil(math.log2(max_abs)) if max_abs > 0 else 1.0
    pot = max(pot, 2.0 ** -20)
    return pot / (2 ** (bits - 1))


# ---------------------------------------------------------------------------
# Quantize / dequantize (traceable; used by PTQ converters and fake-quant).
# ---------------------------------------------------------------------------


def quantize(x, spec: QuantSpec) -> QTensor:
    inv = 1.0 / spec.scale
    q = jnp.round(jnp.asarray(x, jnp.float32) * inv) + spec.zero_point
    lo = float(spec.qmin if not spec.symmetric else -spec.qmax)
    q = jnp.clip(q, lo, float(spec.qmax))
    return QTensor(q.astype(spec.dtype), spec)


def quantize_symmetric(x: np.ndarray, bits: int, pot: bool = False) -> QTensor:
    max_abs = float(np.max(np.abs(x))) if x.size else 0.0
    scale = pot_scale_for(max_abs, bits) if pot else symmetric_scale(max_abs, bits)
    spec = QuantSpec(bits=bits, scale=scale, zero_point=0, symmetric=True, pot=pot)
    return quantize(x, spec)


def quantize_asymmetric(x: np.ndarray, bits: int) -> QTensor:
    t_min = float(np.min(x)) if x.size else 0.0
    t_max = float(np.max(x)) if x.size else 0.0
    scale, zp = asymmetric_scale_zp(t_min, t_max, bits)
    spec = QuantSpec(bits=bits, scale=scale, zero_point=zp, symmetric=False)
    return quantize(x, spec)


def quantize_bias_i32(b: np.ndarray, scale: float) -> QTensor:
    """Bias quantized to int32 at a derived scale (paper sec 3.2.4)."""
    spec = QuantSpec(bits=32, scale=scale, zero_point=0, symmetric=True)
    q = np.clip(np.round(np.asarray(b, np.float64) / scale), -(2**31 - 1), 2**31 - 1)
    return QTensor(jnp.asarray(q, jnp.int32), spec)


def requantize_multiplier(s_in: float, s_out: float) -> Tuple[int, int]:
    """Effective rescale s_eff = s_in / s_out as (m0, shift) ints."""
    return fp.quantize_multiplier(s_in / s_out)
