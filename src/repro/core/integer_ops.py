"""Integer-only tensor ops: matmul, LayerNorm, RMSNorm, softmax.

These are the XLA-path implementations (pure jnp on integer dtypes) of the
paper's building blocks; the Pallas kernels in ``repro/kernels`` implement the
same contracts with explicit VMEM tiling and are validated against these.

Everything here obeys the paper's three principles (sec 3):
  * no floating-point arithmetic in the traced path,
  * no inner-loop branching (masks/selects only),
  * no lookup tables (barrel-shifted exponentials instead).

LayerNorm statistics are computed *exactly* (the paper's eq 13-16 semantics)
without any int64 tensor: n*Sum(q^2) - Sum(q)^2 is carried as uint32 limb
pairs and fed to the integer Newton-Raphson rsqrt.  See DESIGN.md "TPU
adaptation" for why this replaces TFLite's int64 accumulators.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import fixedpoint as fp


def matmul_i8_i32(x_q: jax.Array, w_q: jax.Array) -> jax.Array:
    """int8 x int8 -> int32 matmul (... k) @ (k, n).

    Uses the MXU's native int8 path on TPU via preferred_element_type.
    Safe accumulation depth 2**15 for int8 operands into int32 (sec 3.1.1).
    """
    assert x_q.dtype == jnp.int8 and w_q.dtype == jnp.int8
    return jax.lax.dot_general(
        x_q,
        w_q,
        (((x_q.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def matmul_i16_elementwise(a_q: jax.Array, b_q: jax.Array) -> jax.Array:
    """int16 (x) int16 -> int32 elementwise product (peephole, sec 3.2.3)."""
    return a_q.astype(jnp.int32) * b_q.astype(jnp.int32)


def fold_zero_point(w_q_i8: jax.Array, x_zero_point: int, bias_q: Optional[jax.Array]) -> jax.Array:
    """Deployment optimization (sec 6): fold the zero-point correction into
    the bias so the runtime kernel treats both operands as symmetric.

    An asymmetric activation represents ``x = s * (x_q - zp)``, so the real
    product needs ``W(x_q - zp) + b == W x_q - colsum(W) * zp + b``: the
    correction enters with a MINUS sign.  This is the convention the runtime
    uses -- ``core/recipe.py`` precomputes exactly ``-colsum(W) * zp (+ b)``
    into the ``fold_x`` / ``fold_hb`` / ``fold_*_cat`` arrays, and the
    executors add the folded vector to the raw ``x_q @ W`` accumulator.
    """
    col_sum = jnp.sum(w_q_i8.astype(jnp.int32), axis=0)
    folded = -col_sum * jnp.int32(x_zero_point)
    if bias_q is not None:
        folded = folded + bias_q.astype(jnp.int32)
    return folded


# ---------------------------------------------------------------------------
# Exact integer statistics via uint32 limbs
# ---------------------------------------------------------------------------


def _row_stats_limbs(q: jax.Array) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Return (sum_q, sum_q2 as u64 limbs) reduced over the last axis.

    Exact for row length n <= 2**14 and |q| <= 2**15 (int16 inputs widened).
    """
    n = q.shape[-1]
    assert n <= (1 << 14), f"integer norm supports rows up to 16384, got {n}"
    q32 = q.astype(jnp.int32)
    sum_q = jnp.sum(q32, axis=-1)  # |.| <= n * 2**15 <= 2**29
    q2 = (q32 * q32).astype(jnp.uint32)  # <= 2**30, exact
    hi16 = q2 >> 16  # <= 2**14
    lo16 = q2 & jnp.uint32(0xFFFF)
    sum_hi = jnp.sum(hi16.astype(jnp.int32), axis=-1).astype(jnp.uint32)  # <= 2**28
    sum_lo = jnp.sum(lo16.astype(jnp.int32), axis=-1).astype(jnp.uint32)  # <= 2**30
    # sum_q2 = sum_hi * 2**16 + sum_lo as u64 limbs
    hi = sum_hi >> 16
    lo = sum_hi << 16
    hi2, lo2 = fp.u64_add(hi, lo, jnp.zeros_like(sum_lo), sum_lo)
    return sum_q, (hi2, lo2)


def integer_layernorm(
    q: jax.Array,
    ln_w_q: jax.Array,
    ln_b_q: jax.Array,
    out_m0,
    out_shift,
    out_qmax: int = 32767,
) -> jax.Array:
    """Paper sec 3.2.6: integer-only LayerNorm.

    * ``q``: int16 gate accumulator values (scale cancels in normalization).
    * normalized value x' is represented with the paper's s' = 2**-10 factor:
      q' = round(1024 * (q - mean)/sigma) == round(1024*(n*q - Sum q) * rsqrt(V))
      with V = n*Sum q^2 - (Sum q)^2 carried exactly in u64 limbs.
    * output: round((q' * L_q + b_q) * out_multiplier), int16.
      out_multiplier folds 2**-10 * s_L / s_out (computed offline).
    """
    n = q.shape[-1]
    sum_q, (v_hi, v_lo) = _row_stats_limbs(q)
    # V = n * Sum q^2 - (Sum q)^2   (>= 0 by Cauchy-Schwarz)
    nhi, nlo = fp.u64_mul_small(v_hi, v_lo, n)
    abs_sum = jnp.abs(sum_q).astype(jnp.uint32)
    s_hi, s_lo = fp.u64_from_mul_u32(abs_sum, abs_sum)
    v_hi2, v_lo2 = fp.u64_sub(nhi, nlo, s_hi, s_lo)
    # q' = mbqm(n*q - Sum q, 1024 * rsqrt(V))
    m0, shift = fp.integer_rsqrt_multiplier(v_hi2, v_lo2, extra_pow2=10)
    dev = q.astype(jnp.int32) * jnp.int32(n) - sum_q[..., None]
    qprime = fp.multiply_by_quantized_multiplier(dev, m0[..., None], shift[..., None])
    degenerate = jnp.logical_and(v_hi2 == 0, v_lo2 == 0)[..., None]
    qprime = jnp.where(degenerate, jnp.int32(0), qprime)
    qprime = jnp.clip(qprime, -32768, 32767)
    # y = q' * L + b  (int16*int16 + int32), then rescale to the output scale
    acc = qprime * ln_w_q.astype(jnp.int32)
    acc = fp.saturating_add_i32(acc, ln_b_q.astype(jnp.int32))
    out = fp.multiply_by_quantized_multiplier(acc, out_m0, out_shift)
    return jnp.clip(out, -out_qmax - 1, out_qmax).astype(jnp.int16)


def integer_rmsnorm(
    q: jax.Array,
    w_q: jax.Array,
    out_m0,
    out_shift,
    eps_guard: bool = True,
) -> jax.Array:
    """RMSNorm generalization of the paper's integer LayerNorm (beyond-paper).

    q / rms(q) = q * sqrt(n) * rsqrt(Sum q^2); the sqrt(n) and the s'=2**-10
    factor fold into the rsqrt multiplier, and 2**-10 * s_w / s_out folds into
    (out_m0, out_shift) exactly as in integer_layernorm.
    """
    n = q.shape[-1]
    _, (v_hi, v_lo) = _row_stats_limbs(q)
    m0, shift = fp.integer_rsqrt_multiplier(v_hi, v_lo, extra_pow2=10)
    # fold sqrt(n) (static) into the multiplier mantissa
    sn_m0, sn_shift = fp.quantize_multiplier(math.sqrt(n))
    m0 = fp.saturating_rounding_doubling_high_mul(m0, jnp.int32(sn_m0))
    shift = shift + jnp.int32(sn_shift)
    qprime = fp.multiply_by_quantized_multiplier(
        q.astype(jnp.int32), m0[..., None], shift[..., None]
    )
    if eps_guard:
        degenerate = jnp.logical_and(v_hi == 0, v_lo == 0)[..., None]
        qprime = jnp.where(degenerate, jnp.int32(0), qprime)
    qprime = jnp.clip(qprime, -32768, 32767)
    acc = qprime * w_q.astype(jnp.int32)
    out = fp.multiply_by_quantized_multiplier(acc, out_m0, out_shift)
    return jnp.clip(out, -32768, 32767).astype(jnp.int16)


def integer_softmax(
    logits_q: jax.Array,
    in_m0: int,
    in_shift: int,
    axis: int = -1,
    mask: Optional[jax.Array] = None,
) -> jax.Array:
    """int16/int32 logits -> int16 Q0.15 probabilities (beyond-paper).

    TFLite-style 16-bit softmax built from the paper's building blocks:
    max-subtraction in integers, barrel-shifted exp to Q0.31, integer
    Newton reciprocal of the sum.  (in_m0, in_shift) rescales the logits'
    scale to Q5.26 so that exp_on_negative_values can consume them.
    """
    assert axis == -1
    x = logits_q.astype(jnp.int32)
    if mask is not None:
        neg = jnp.int32(fp.INT32_MIN // 2)
        x = jnp.where(mask, x, neg)
    x_max = jnp.max(x, axis=-1, keepdims=True)
    diff = x - x_max  # <= 0
    scaled = fp.multiply_by_quantized_multiplier(diff, in_m0, in_shift)
    scaled = jnp.maximum(scaled, jnp.int32(-(1 << 31) + 1))
    e = fp.exp_on_negative_values(scaled, 5)  # Q0.31
    if mask is not None:
        e = jnp.where(mask, e, jnp.int32(0))
    n = logits_q.shape[-1]
    k = max(int(math.ceil(math.log2(max(n, 2)))), 1)
    e_s = e >> k
    denom = jnp.sum(e_s, axis=-1)  # < 2**31
    denom = jnp.maximum(denom, 1)
    rm0, rshift = fp.integer_recip_multiplier(denom, extra_pow2=15)
    p = fp.multiply_by_quantized_multiplier(e_s, rm0[..., None], rshift[..., None])
    return jnp.clip(p, 0, 32767).astype(jnp.int16)
