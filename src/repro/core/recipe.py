"""The paper's quantization recipe (Table 2): float LSTM -> integer LSTM.

Given calibrated ``Stats`` and float parameters, produce (a) an arrays pytree
of integer tensors and (b) a static ``QLSTMSpec`` holding every derived scale
and precomputed fixed-point multiplier.  All real-valued scale arithmetic
happens HERE, offline; the integer executor in ``repro.models.quant_lstm``
touches integers only.

Recipe summary (Table 2):
  x, h, m      int8  asymmetric  range/255 (nudged zero point)
  W, R, W_proj int8  symmetric   max/127
  P, L         int16 symmetric   max/32767
  b (no LN)    int32 at s_R*s_h     |  b (LN) int32 at 2**-10 * s_L
  b_proj       int32 at s_Wproj*s_m
  c            int16 symmetric POT(max)/32768  => Q_{m.15-m}
  gates (noLN) int16 Q3.12 (2**-12)  |  gates (LN) int16 max|g|/32767
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from . import fixedpoint as fp
from . import qtypes as qt
from .calibrate import Stats
from repro.models.lstm import LSTMConfig, LSTMVariant

MulPair = Tuple[int, int]  # (m0, shift) from fp.quantize_multiplier


@dataclasses.dataclass(frozen=True)
class GateSpec:
    eff_x: MulPair  # s_W*s_x / s_gate
    eff_h: MulPair  # s_R*s_h / s_gate
    eff_c: Optional[MulPair]  # s_P*s_c / s_gate (peephole)
    ln_out: Optional[MulPair]  # 2**-10 * s_L / 2**-12 (LN only)


@dataclasses.dataclass(frozen=True)
class QLSTMSpec:
    """Static (hashable) integer-execution plan for one LSTM layer."""

    cfg_d_input: int
    cfg_d_hidden: int
    cfg_d_proj: int
    use_layernorm: bool
    use_projection: bool
    use_peephole: bool
    use_cifg: bool
    zp_x: int
    zp_h: int
    zp_m: int
    zp_h_out: int
    cell_int_bits: int  # m of Q_{m.15-m}
    gates: Tuple[Tuple[str, GateSpec], ...]
    eff_m: MulPair  # 2**-30 / s_m  (gate-to-hidden, sec 3.2.7)
    eff_proj: Optional[MulPair]  # s_Wproj*s_m / s_h
    s_x: float
    s_h: float
    s_m: float
    s_c: float

    @property
    def variant(self) -> LSTMVariant:
        return LSTMVariant(
            self.use_layernorm,
            self.use_projection,
            self.use_peephole,
            self.use_cifg,
        )

    def gate_spec(self, g: str) -> GateSpec:
        return dict(self.gates)[g]

    def gate_block(self, g: str) -> slice:
        """Column block of gate ``g`` inside the packed [i|f|z|o] arrays."""
        k = self.variant.gates.index(g)
        return slice(k * self.cfg_d_hidden, (k + 1) * self.cfg_d_hidden)


def _np(x) -> np.ndarray:
    return np.asarray(x, np.float64)


def quantize_lstm_layer(
    params: Dict[str, Any],
    cfg: LSTMConfig,
    stats: Stats,
    prefix: str = "",
) -> Tuple[Dict[str, Any], QLSTMSpec]:
    """Apply Table 2 to one layer.  Returns (integer arrays, static spec)."""
    v = cfg.variant

    def rng(name):
        return stats.range(prefix + name)

    def max_abs(name):
        return stats.max_abs(prefix + name)

    # --- activations (asymmetric int8) and cell (POT int16) ----------------
    s_x, zp_x = qt.asymmetric_scale_zp(*rng("x"), 8)
    s_h, zp_h = qt.asymmetric_scale_zp(*rng("h"), 8)
    s_m, zp_m = qt.asymmetric_scale_zp(*rng("m"), 8)
    if v.use_projection:
        s_hout, zp_hout = qt.asymmetric_scale_zp(*rng("h_out"), 8)
    else:
        s_hout, zp_hout = s_m, zp_m
    s_c = qt.pot_scale_for(max_abs("c"), 16)
    m_c = 15 - int(round(-np.log2(s_c)))  # integer bits of Q_{m.15-m}
    m_c = max(m_c, 0)

    arrays: Dict[str, Any] = {}
    per_gate: Dict[str, Dict[str, np.ndarray]] = {
        "W": {}, "R": {}, "fold_x": {}, "fold_hb": {}
    }
    gate_specs = []

    for g in v.gates:
        W = _np(params["W"][g])
        R = _np(params["R"][g])
        b = _np(params["b"][g])
        s_W = qt.symmetric_scale(np.abs(W).max(), 8)
        s_R = qt.symmetric_scale(np.abs(R).max(), 8)
        Wq = np.clip(np.round(W / s_W), -127, 127).astype(np.int8)
        Rq = np.clip(np.round(R / s_R), -127, 127).astype(np.int8)
        per_gate["W"][g] = Wq
        per_gate["R"][g] = Rq

        # gate output scale: Q3.12 without LN, measured/32767 with LN
        if v.use_layernorm:
            s_gate = qt.symmetric_scale(max_abs(f"g_{g}"), 16)
        else:
            s_gate = 2.0**-12

        # zero-point folding (sec 6): W(x - zp) == Wx - colsum(W)*zp
        # (the sign convention of integer_ops.fold_zero_point)
        fold_x = -Wq.astype(np.int64).sum(axis=0) * zp_x
        per_gate["fold_x"][g] = np.clip(
            fold_x, -(2**31 - 1), 2**31 - 1
        ).astype(np.int32)
        fold_h = -Rq.astype(np.int64).sum(axis=0) * zp_h
        if not v.use_layernorm:
            # bias carried at s_R*s_h into the recurrent accumulator (3.2.4)
            bq = np.round(b / (s_R * s_h))
            fold_h = fold_h + bq
        per_gate["fold_hb"][g] = np.clip(
            fold_h, -(2**31 - 1), 2**31 - 1
        ).astype(np.int32)

        eff_c = None
        if v.use_peephole and g != "z":
            P = _np(params["P"][g])
            s_P = qt.symmetric_scale(np.abs(P).max(), 16)
            Pq = np.clip(np.round(P / s_P), -32767, 32767).astype(np.int16)
            arrays.setdefault("P", {})[g] = jnp.asarray(Pq)
            eff_c = fp.quantize_multiplier(s_P * s_c / s_gate)

        ln_out = None
        if v.use_layernorm:
            L = _np(params["L"][g])
            s_L = qt.symmetric_scale(np.abs(L).max(), 16)
            Lq = np.clip(np.round(L / s_L), -32767, 32767).astype(np.int16)
            arrays.setdefault("L", {})[g] = jnp.asarray(Lq)
            # LN bias at 2**-10 * s_L (Table 2)
            lbq = np.clip(
                np.round(b / (2.0**-10 * s_L)), -(2**31 - 1), 2**31 - 1
            )
            arrays.setdefault("Lb", {})[g] = jnp.asarray(lbq, jnp.int32)
            ln_out = fp.quantize_multiplier(2.0**-10 * s_L / 2.0**-12)

        gate_specs.append(
            (
                g,
                GateSpec(
                    eff_x=fp.quantize_multiplier(s_W * s_x / s_gate),
                    eff_h=fp.quantize_multiplier(s_R * s_h / s_gate),
                    eff_c=eff_c,
                    ln_out=ln_out,
                ),
            )
        )

    # --- packed [i|f|z|o] blocks (fused executor, fig 10-12) ---------------
    # The gate weights are stored ONLY column-concatenated, so one
    # (B, d_in) x (d_in, G*H) int8 MXU matmul produces every gate
    # accumulator at once; slicing column block g (``spec.gate_block``) is
    # bit-identical to the per-gate matmul, so the reference executor reads
    # the same buffers and the model stays at its Table-1 size.  Gate order
    # follows ``v.gates`` (CIFG drops the "i" block).
    arrays["W_cat"] = jnp.asarray(
        np.concatenate([per_gate["W"][g] for g in v.gates], axis=1)
    )
    arrays["R_cat"] = jnp.asarray(
        np.concatenate([per_gate["R"][g] for g in v.gates], axis=1)
    )
    arrays["fold_x_cat"] = jnp.asarray(
        np.concatenate([per_gate["fold_x"][g] for g in v.gates])
    )
    arrays["fold_hb_cat"] = jnp.asarray(
        np.concatenate([per_gate["fold_hb"][g] for g in v.gates])
    )

    eff_proj = None
    if v.use_projection:
        Wp = _np(params["W_proj"])
        bp = _np(params["b_proj"])
        s_wp = qt.symmetric_scale(np.abs(Wp).max(), 8)
        Wpq = np.clip(np.round(Wp / s_wp), -127, 127).astype(np.int8)
        arrays["W_proj"] = jnp.asarray(Wpq)
        fold_p = -Wpq.astype(np.int64).sum(axis=0) * zp_m + np.round(
            bp / (s_wp * s_m)
        )
        arrays["fold_proj"] = jnp.asarray(
            np.clip(fold_p, -(2**31 - 1), 2**31 - 1), jnp.int32
        )
        eff_proj = fp.quantize_multiplier(s_wp * s_m / s_hout)

    spec = QLSTMSpec(
        cfg_d_input=cfg.d_input,
        cfg_d_hidden=cfg.d_hidden,
        cfg_d_proj=cfg.d_proj,
        use_layernorm=v.use_layernorm,
        use_projection=v.use_projection,
        use_peephole=v.use_peephole,
        use_cifg=v.use_cifg,
        zp_x=zp_x,
        zp_h=zp_h,
        zp_m=zp_m,
        zp_h_out=zp_hout,
        cell_int_bits=m_c,
        gates=tuple(gate_specs),
        eff_m=fp.quantize_multiplier(2.0**-30 / s_m),
        eff_proj=eff_proj,
        s_x=s_x,
        s_h=s_hout,
        s_m=s_m,
        s_c=s_c,
    )
    return arrays, spec


def recipe_table(spec: QLSTMSpec) -> Dict[str, str]:
    """Human-readable Table-2 row dump for one quantized layer (benchmarks)."""
    rows = {
        "x": f"int8 asym s={spec.s_x:.3e} zp={spec.zp_x}",
        "h": f"int8 asym s={spec.s_h:.3e} zp={spec.zp_h}",
        "m": f"int8 asym s={spec.s_m:.3e} zp={spec.zp_m}",
        "c": f"int16 POT s={spec.s_c:.3e} (Q{spec.cell_int_bits}."
        f"{15 - spec.cell_int_bits})",
    }
    for g, gs in spec.gates:
        rows[f"gate_{g}"] = (
            f"eff_x={gs.eff_x} eff_h={gs.eff_h} eff_c={gs.eff_c} "
            f"ln_out={gs.ln_out}"
        )
    if spec.eff_proj:
        rows["proj"] = f"eff={spec.eff_proj}"
    return rows
