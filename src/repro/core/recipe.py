"""The paper's quantization recipe (Table 2): float cell -> integer cell.

Given calibrated ``Stats`` and float parameters, produce (a) an arrays pytree
of integer tensors and (b) a static spec (``QLSTMSpec`` / ``QGRUSpec``)
holding every derived scale and precomputed fixed-point multiplier.  All
real-valued scale arithmetic happens HERE, offline; the integer executors in
``repro.models.quant_lstm`` and ``repro.kernels`` touch integers only.

The recipe is cell-agnostic (``core/cell.py``): each quantizer packs its
cell's N gate blocks column-concatenated via ``_pack_gate_blocks`` so the
recurrent stage is always one ``(B, d_out) x (d_out, G*H)`` int8 GEMM, and
records per-gate fixed-point multipliers in the same ``GateSpec`` shape.

Recipe summary (Table 2), LSTM row names; GRU reuses x/h/W/R/b/gate rows:
  x, h, m      int8  asymmetric  range/255 (nudged zero point)
  W, R, W_proj int8  symmetric   max/127
  P, L         int16 symmetric   max/32767
  b (no LN)    int32 at s_R*s_h     |  b (LN) int32 at 2**-10 * s_L
  b_proj       int32 at s_Wproj*s_m
  c            int16 symmetric POT(max)/32768  => Q_{m.15-m}
  gates (noLN) int16 Q3.12 (2**-12)  |  gates (LN) int16 max|g|/32767
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from . import fixedpoint as fp
from . import qtypes as qt
from .calibrate import Stats
from repro.models.gru import GRUConfig, GRUVariant
from repro.models.lstm import LSTMConfig, LSTMVariant

MulPair = Tuple[int, int]  # (m0, shift) from fp.quantize_multiplier


@dataclasses.dataclass(frozen=True)
class GateSpec:
    eff_x: MulPair  # s_W*s_x / s_gate
    eff_h: MulPair  # s_R*s_h / s_gate
    eff_c: Optional[MulPair]  # s_P*s_c / s_gate (peephole)
    ln_out: Optional[MulPair]  # 2**-10 * s_L / 2**-12 (LN only)


@dataclasses.dataclass(frozen=True)
class QLSTMSpec:
    """Static (hashable) integer-execution plan for one LSTM layer."""

    cfg_d_input: int
    cfg_d_hidden: int
    cfg_d_proj: int
    use_layernorm: bool
    use_projection: bool
    use_peephole: bool
    use_cifg: bool
    zp_x: int
    zp_h: int
    zp_m: int
    zp_h_out: int
    cell_int_bits: int  # m of Q_{m.15-m}
    gates: Tuple[Tuple[str, GateSpec], ...]
    eff_m: MulPair  # 2**-30 / s_m  (gate-to-hidden, sec 3.2.7)
    eff_proj: Optional[MulPair]  # s_Wproj*s_m / s_h
    s_x: float
    s_h: float
    s_m: float
    s_c: float

    @property
    def cell(self) -> str:
        return "lstm"

    @property
    def variant(self) -> LSTMVariant:
        return LSTMVariant(
            self.use_layernorm,
            self.use_projection,
            self.use_peephole,
            self.use_cifg,
        )

    @property
    def gate_names(self) -> Tuple[str, ...]:
        return self.variant.gates

    @property
    def d_out(self) -> int:
        return self.cfg_d_proj if self.use_projection else self.cfg_d_hidden

    def gate_spec(self, g: str) -> GateSpec:
        return dict(self.gates)[g]

    def gate_block(self, g: str) -> slice:
        """Column block of gate ``g`` inside the packed [i|f|z|o] arrays."""
        k = self.variant.gates.index(g)
        return slice(k * self.cfg_d_hidden, (k + 1) * self.cfg_d_hidden)


@dataclasses.dataclass(frozen=True)
class QGRUSpec:
    """Static (hashable) integer-execution plan for one GRU layer.

    The GRU feeds its int8 hidden straight back (no projection stage), so
    the recipe uses ONE hidden format -- the union of the recurrent-input
    tap ``h`` and the output tap ``h_out`` -- and the carry update is exact:
    ``u (.) h`` stays in h units (``eff_carry`` = 2**-15, no rescale error).
    """

    cfg_d_input: int
    cfg_d_hidden: int
    use_layernorm: bool
    zp_x: int
    zp_h: int
    zp_h_out: int  # == zp_h (single hidden format); kept for API symmetry
    gates: Tuple[Tuple[str, GateSpec], ...]  # ("r"|"u"|"n", GateSpec)
    eff_carry: MulPair  # 2**-15       : u (.) (h - zp_h)  -> h units
    eff_n: MulPair  # 2**-30 / s_h : (1 - u) (.) n_act -> h units
    s_x: float
    s_h: float

    @property
    def cell(self) -> str:
        return "gru"

    @property
    def variant(self) -> GRUVariant:
        return GRUVariant(self.use_layernorm)

    @property
    def gate_names(self) -> Tuple[str, ...]:
        return tuple(g for g, _ in self.gates)

    @property
    def d_out(self) -> int:
        return self.cfg_d_hidden

    def gate_spec(self, g: str) -> GateSpec:
        return dict(self.gates)[g]

    def gate_block(self, g: str) -> slice:
        """Column block of gate ``g`` inside the packed [r|u|n] arrays."""
        k = self.gate_names.index(g)
        return slice(k * self.cfg_d_hidden, (k + 1) * self.cfg_d_hidden)


def _np(x) -> np.ndarray:
    return np.asarray(x, np.float64)


def _i32(x) -> np.ndarray:
    return np.clip(x, -(2**31 - 1), 2**31 - 1).astype(np.int32)


def _pack_gate_blocks(
    arrays: Dict[str, Any],
    per_gate: Dict[str, Dict[str, np.ndarray]],
    gate_order: Tuple[str, ...],
) -> None:
    """Column-concatenate N per-gate blocks into the fused executor layout.

    The gate weights are stored ONLY concatenated, so one
    (B, d_in) x (d_in, G*H) int8 MXU matmul produces every gate accumulator
    at once; slicing column block g (``spec.gate_block``) is bit-identical
    to the per-gate matmul, so reference executors read the same buffers and
    the model stays at its Table-1 size.  ``gate_order`` is the cell's gate
    tuple (LSTM [i|f|z|o] minus CIFG's "i"; GRU [r|u|n]).
    """
    arrays["W_cat"] = jnp.asarray(
        np.concatenate([per_gate["W"][g] for g in gate_order], axis=1)
    )
    arrays["R_cat"] = jnp.asarray(
        np.concatenate([per_gate["R"][g] for g in gate_order], axis=1)
    )
    arrays["fold_x_cat"] = jnp.asarray(
        np.concatenate([per_gate["fold_x"][g] for g in gate_order])
    )
    arrays["fold_hb_cat"] = jnp.asarray(
        np.concatenate([per_gate["fold_hb"][g] for g in gate_order])
    )


def quantize_lstm_layer(
    params: Dict[str, Any],
    cfg: LSTMConfig,
    stats: Stats,
    prefix: str = "",
) -> Tuple[Dict[str, Any], QLSTMSpec]:
    """Apply Table 2 to one layer.  Returns (integer arrays, static spec)."""
    v = cfg.variant

    def rng(name):
        return stats.range(prefix + name)

    def max_abs(name):
        return stats.max_abs(prefix + name)

    # --- activations (asymmetric int8) and cell (POT int16) ----------------
    s_x, zp_x = qt.asymmetric_scale_zp(*rng("x"), 8)
    # ONE hidden format for the recurrence: the h the gates consume IS last
    # step's emitted output, so its int8 coding must be the coding the
    # output was written in.  Deriving them from their own taps ("h" vs
    # "h_out"/"m") yields two near-equal scales with DIFFERENT nudged zero
    # points, and the systematic zp offset compounds over the scan (worst
    # on the *-Proj-PH-CIFG variants).  Union the input and output tap
    # ranges instead: both formats come out identical and the feedback is
    # exact by construction.
    lo_in, hi_in = rng("h")
    lo_out, hi_out = rng("h_out" if v.use_projection else "m")
    s_h, zp_h = qt.asymmetric_scale_zp(min(lo_in, lo_out),
                                       max(hi_in, hi_out), 8)
    if v.use_projection:
        s_m, zp_m = qt.asymmetric_scale_zp(*rng("m"), 8)
    else:
        # no projection: m IS the emitted h, so it shares the union format
        s_m, zp_m = s_h, zp_h
    s_hout, zp_hout = s_h, zp_h
    s_c = qt.pot_scale_for(max_abs("c"), 16)
    m_c = 15 - int(round(-np.log2(s_c)))  # integer bits of Q_{m.15-m}
    m_c = max(m_c, 0)

    arrays: Dict[str, Any] = {}
    per_gate: Dict[str, Dict[str, np.ndarray]] = {
        "W": {}, "R": {}, "fold_x": {}, "fold_hb": {}
    }
    gate_specs = []

    for g in v.gates:
        W = _np(params["W"][g])
        R = _np(params["R"][g])
        b = _np(params["b"][g])
        s_W = qt.symmetric_scale(np.abs(W).max(), 8)
        s_R = qt.symmetric_scale(np.abs(R).max(), 8)
        Wq = np.clip(np.round(W / s_W), -127, 127).astype(np.int8)
        Rq = np.clip(np.round(R / s_R), -127, 127).astype(np.int8)
        per_gate["W"][g] = Wq
        per_gate["R"][g] = Rq

        # gate output scale: Q3.12 without LN, measured/32767 with LN
        if v.use_layernorm:
            s_gate = qt.symmetric_scale(max_abs(f"g_{g}"), 16)
        else:
            s_gate = 2.0**-12

        # zero-point folding (sec 6): W(x - zp) == Wx - colsum(W)*zp
        # (the sign convention of integer_ops.fold_zero_point)
        fold_x = -Wq.astype(np.int64).sum(axis=0) * zp_x
        per_gate["fold_x"][g] = np.clip(
            fold_x, -(2**31 - 1), 2**31 - 1
        ).astype(np.int32)
        fold_h = -Rq.astype(np.int64).sum(axis=0) * zp_h
        if not v.use_layernorm:
            # bias carried at s_R*s_h into the recurrent accumulator (3.2.4)
            bq = np.round(b / (s_R * s_h))
            fold_h = fold_h + bq
        per_gate["fold_hb"][g] = np.clip(
            fold_h, -(2**31 - 1), 2**31 - 1
        ).astype(np.int32)

        eff_c = None
        if v.use_peephole and g != "z":
            P = _np(params["P"][g])
            s_P = qt.symmetric_scale(np.abs(P).max(), 16)
            Pq = np.clip(np.round(P / s_P), -32767, 32767).astype(np.int16)
            arrays.setdefault("P", {})[g] = jnp.asarray(Pq)
            eff_c = fp.quantize_multiplier(s_P * s_c / s_gate)

        ln_out = None
        if v.use_layernorm:
            L = _np(params["L"][g])
            s_L = qt.symmetric_scale(np.abs(L).max(), 16)
            Lq = np.clip(np.round(L / s_L), -32767, 32767).astype(np.int16)
            arrays.setdefault("L", {})[g] = jnp.asarray(Lq)
            # LN bias at 2**-10 * s_L (Table 2)
            lbq = np.clip(
                np.round(b / (2.0**-10 * s_L)), -(2**31 - 1), 2**31 - 1
            )
            arrays.setdefault("Lb", {})[g] = jnp.asarray(lbq, jnp.int32)
            ln_out = fp.quantize_multiplier(2.0**-10 * s_L / 2.0**-12)

        gate_specs.append(
            (
                g,
                GateSpec(
                    eff_x=fp.quantize_multiplier(s_W * s_x / s_gate),
                    eff_h=fp.quantize_multiplier(s_R * s_h / s_gate),
                    eff_c=eff_c,
                    ln_out=ln_out,
                ),
            )
        )

    _pack_gate_blocks(arrays, per_gate, v.gates)

    eff_proj = None
    if v.use_projection:
        Wp = _np(params["W_proj"])
        bp = _np(params["b_proj"])
        s_wp = qt.symmetric_scale(np.abs(Wp).max(), 8)
        Wpq = np.clip(np.round(Wp / s_wp), -127, 127).astype(np.int8)
        arrays["W_proj"] = jnp.asarray(Wpq)
        fold_p = -Wpq.astype(np.int64).sum(axis=0) * zp_m + np.round(
            bp / (s_wp * s_m)
        )
        arrays["fold_proj"] = jnp.asarray(
            np.clip(fold_p, -(2**31 - 1), 2**31 - 1), jnp.int32
        )
        eff_proj = fp.quantize_multiplier(s_wp * s_m / s_hout)

    spec = QLSTMSpec(
        cfg_d_input=cfg.d_input,
        cfg_d_hidden=cfg.d_hidden,
        cfg_d_proj=cfg.d_proj,
        use_layernorm=v.use_layernorm,
        use_projection=v.use_projection,
        use_peephole=v.use_peephole,
        use_cifg=v.use_cifg,
        zp_x=zp_x,
        zp_h=zp_h,
        zp_m=zp_m,
        zp_h_out=zp_hout,
        cell_int_bits=m_c,
        gates=tuple(gate_specs),
        eff_m=fp.quantize_multiplier(2.0**-30 / s_m),
        eff_proj=eff_proj,
        s_x=s_x,
        s_h=s_hout,
        s_m=s_m,
        s_c=s_c,
    )
    return arrays, spec


def quantize_gru_layer(
    params: Dict[str, Any],
    cfg: GRUConfig,
    stats: Stats,
    prefix: str = "",
) -> Tuple[Dict[str, Any], QGRUSpec]:
    """Apply Table 2 to one GRU layer.  Returns (integer arrays, static spec).

    Same recipe rows as the LSTM (int8 asym activations, int8 sym weights,
    Q3.12 gates without LN / measured 16-bit gates with LN, biases folded
    into the recurrent accumulator), specialized to the reset-after GRU:

      r, u  : sigmoid_q15(rescale(acc_x) + rescale(acc_h))      [LN'd first]
      n     : tanh_q15(rescale(acc_x_n) + rdp(r * rescale(acc_h_n), 15))
      h'    : sat8(mbqm(u*(h - zp_h), 2**-15)
                   + mbqm((2**15 - u)*n, 2**-30/s_h) + zp_h)

    The hidden format is the UNION of the ``h`` and ``h_out`` tap ranges so
    the fed-back int8 code and the recurrent folding share one (s, zp) --
    the carry term ``u (.) h`` then needs no real-valued rescale at all.
    """
    v = cfg.variant

    def rng(name):
        return stats.range(prefix + name)

    def max_abs(name):
        return stats.max_abs(prefix + name)

    # --- activations: one hidden format for input AND output taps ----------
    s_x, zp_x = qt.asymmetric_scale_zp(*rng("x"), 8)
    lo_in, hi_in = rng("h")
    lo_out, hi_out = rng("h_out")
    s_h, zp_h = qt.asymmetric_scale_zp(min(lo_in, lo_out), max(hi_in, hi_out), 8)

    arrays: Dict[str, Any] = {}
    per_gate: Dict[str, Dict[str, np.ndarray]] = {
        "W": {}, "R": {}, "fold_x": {}, "fold_hb": {}
    }
    gate_specs = []

    for g in v.gates:
        W = _np(params["W"][g])
        R = _np(params["R"][g])
        b = _np(params["b"][g])
        s_W = qt.symmetric_scale(np.abs(W).max(), 8)
        s_R = qt.symmetric_scale(np.abs(R).max(), 8)
        Wq = np.clip(np.round(W / s_W), -127, 127).astype(np.int8)
        Rq = np.clip(np.round(R / s_R), -127, 127).astype(np.int8)
        per_gate["W"][g] = Wq
        per_gate["R"][g] = Rq

        # gate output scale: Q3.12 without LN, measured/32767 with LN
        if v.use_layernorm:
            s_gate = qt.symmetric_scale(max_abs(f"g_{g}"), 16)
        else:
            s_gate = 2.0**-12

        # zero-point folding (sec 6): W(x - zp) == Wx - colsum(W)*zp
        per_gate["fold_x"][g] = _i32(
            -Wq.astype(np.int64).sum(axis=0) * zp_x)
        fold_h = -Rq.astype(np.int64).sum(axis=0) * zp_h
        if not v.use_layernorm:
            # bias carried at s_R*s_h into the recurrent accumulator; for
            # gate "n" this sits INSIDE the reset product (reset-after form)
            fold_h = fold_h + np.round(b / (s_R * s_h))
        per_gate["fold_hb"][g] = _i32(fold_h)

        ln_out = None
        if v.use_layernorm:
            L = _np(params["L"][g])
            s_L = qt.symmetric_scale(np.abs(L).max(), 16)
            Lq = np.clip(np.round(L / s_L), -32767, 32767).astype(np.int16)
            arrays.setdefault("L", {})[g] = jnp.asarray(Lq)
            # LN bias at 2**-10 * s_L (Table 2)
            lbq = _i32(np.round(b / (2.0**-10 * s_L)))
            arrays.setdefault("Lb", {})[g] = jnp.asarray(lbq, jnp.int32)
            ln_out = fp.quantize_multiplier(2.0**-10 * s_L / 2.0**-12)

        gate_specs.append(
            (
                g,
                GateSpec(
                    eff_x=fp.quantize_multiplier(s_W * s_x / s_gate),
                    eff_h=fp.quantize_multiplier(s_R * s_h / s_gate),
                    eff_c=None,
                    ln_out=ln_out,
                ),
            )
        )

    _pack_gate_blocks(arrays, per_gate, v.gates)

    spec = QGRUSpec(
        cfg_d_input=cfg.d_input,
        cfg_d_hidden=cfg.d_hidden,
        use_layernorm=v.use_layernorm,
        zp_x=zp_x,
        zp_h=zp_h,
        zp_h_out=zp_h,
        gates=tuple(gate_specs),
        eff_carry=fp.quantize_multiplier(2.0**-15),
        eff_n=fp.quantize_multiplier(2.0**-30 / s_h),
        s_x=s_x,
        s_h=s_h,
    )
    return arrays, spec


def recipe_table(spec) -> Dict[str, str]:
    """Human-readable Table-2 row dump for one quantized layer (benchmarks)."""
    rows = {
        "x": f"int8 asym s={spec.s_x:.3e} zp={spec.zp_x}",
        "h": f"int8 asym s={spec.s_h:.3e} zp={spec.zp_h}",
    }
    if spec.cell == "lstm":
        rows["m"] = f"int8 asym s={spec.s_m:.3e} zp={spec.zp_m}"
        rows["c"] = (
            f"int16 POT s={spec.s_c:.3e} (Q{spec.cell_int_bits}."
            f"{15 - spec.cell_int_bits})"
        )
    for g, gs in spec.gates:
        rows[f"gate_{g}"] = (
            f"eff_x={gs.eff_x} eff_h={gs.eff_h} eff_c={gs.eff_c} "
            f"ln_out={gs.ln_out}"
        )
    if getattr(spec, "eff_proj", None):
        rows["proj"] = f"eff={spec.eff_proj}"
    return rows
