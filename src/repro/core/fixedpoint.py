"""Integer-only fixed-point arithmetic (gemmlowp semantics) in JAX.

This module is the numerical heart of the paper "On the quantization of
recurrent neural networks" (Li & Alvarez, 2021): every op here is expressible
with 32-bit integer ALU instructions (add/sub/mul/shift/compare/select) so the
same code runs on CPUs, DSPs, integer neural accelerators, and -- via Pallas --
on TPU VPU lanes.  No floating point is used anywhere in the traced paths.

Notation: ``Q_{m.n}`` is a signed fixed-point format with ``m`` integer bits
and ``n`` fractional bits (m + n + 1 == bit width).  A raw int32 ``r`` in
``Q_{m.(31-m)}`` represents the real value ``r * 2**(m-31)``.

Key primitives (bit-exact ports of gemmlowp/fixedpoint.h and the TFLite
quantized-LSTM kernel semantics):

* ``saturating_rounding_doubling_high_mul`` (SRDHM) -- the fixed-point multiply.
* ``rounding_divide_by_pot`` -- rounding arithmetic right shift.
* ``multiply_by_quantized_multiplier`` -- rescale by a statically-derived
  (mantissa, exponent) pair; the only place real-valued scales enter the
  integer graph, and they enter as *static* integers computed offline.
* ``exp_on_negative_values`` / ``tanh_fp`` / ``sigmoid_fp`` -- integer
  transcendentals via barrel-shifted exponentials and Newton-Raphson division.
* ``integer_rsqrt_multiplier`` / ``integer_recip_multiplier`` -- integer
  Newton-Raphson 1/sqrt(V) and 1/x used by integer LayerNorm/RMSNorm/softmax.

TPU adaptation (see DESIGN.md): TFLite's reference kernels accumulate LayerNorm
statistics in int64; TPUs have no 64-bit integer datapath, so everywhere a u64
is required we carry (hi, lo) uint32 limb pairs instead.  The math stays exact.

The pure-numpy oracle lives in ``repro/kernels/ref.py`` and
``tests/test_fixedpoint.py`` cross-checks against python big-int arithmetic.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

INT32_MAX = 2147483647
INT32_MIN = -2147483648
INT16_MAX = 32767
INT16_MIN = -32768

# ---------------------------------------------------------------------------
# u64-as-two-uint32-limbs helpers.
# ---------------------------------------------------------------------------


def _u32(x):
    return jnp.asarray(x).astype(jnp.uint32)


def _i32(x):
    return jnp.asarray(x).astype(jnp.int32)


def u64_from_mul_u32(a, b) -> Tuple[jax.Array, jax.Array]:
    """Full 64-bit product of two uint32 values as (hi, lo) uint32 limbs."""
    a = _u32(a)
    b = _u32(b)
    mask = jnp.uint32(0xFFFF)
    a_hi, a_lo = a >> 16, a & mask
    b_hi, b_lo = b >> 16, b & mask
    ll = a_lo * b_lo  # < 2**32, exact in uint32
    lh = a_lo * b_hi  # < 2**32
    hl = a_hi * b_lo  # < 2**32
    hh = a_hi * b_hi  # < 2**32
    mid = lh + hl  # may wrap once: carry weight 2**(32+16)
    carry_mid = (mid < lh).astype(jnp.uint32)
    lo = ll + ((mid & mask) << 16)
    carry_lo = (lo < ll).astype(jnp.uint32)
    hi = hh + (mid >> 16) + (carry_mid << 16) + carry_lo
    return hi, lo


def u64_add(h1, l1, h2, l2) -> Tuple[jax.Array, jax.Array]:
    lo = _u32(l1) + _u32(l2)
    carry = (lo < _u32(l1)).astype(jnp.uint32)
    return _u32(h1) + _u32(h2) + carry, lo


def u64_sub(h1, l1, h2, l2) -> Tuple[jax.Array, jax.Array]:
    lo = _u32(l1) - _u32(l2)
    borrow = (_u32(l1) < _u32(l2)).astype(jnp.uint32)
    return _u32(h1) - _u32(h2) - borrow, lo


def u64_shift_right(hi, lo, n: int) -> Tuple[jax.Array, jax.Array]:
    """Logical right shift of a u64 limb pair by a static 0 <= n < 32."""
    if n == 0:
        return _u32(hi), _u32(lo)
    hi = _u32(hi)
    lo = _u32(lo)
    return hi >> n, (lo >> n) | (hi << (32 - n))


def u64_mul_small(hi, lo, k: int) -> Tuple[jax.Array, jax.Array]:
    """(hi, lo) * k for a static 0 <= k < 2**16; exact provided no overflow."""
    hi = _u32(hi)
    lo = _u32(lo)
    ku = jnp.uint32(k)
    h1, l1 = u64_from_mul_u32(lo, ku)
    return h1 + hi * ku, l1


def clz32(x) -> jax.Array:
    """Leading zeros of a uint32 (returns 32 for x == 0); vectorized."""
    x = _u32(x)
    n = jnp.zeros(jnp.shape(x), jnp.int32)
    cur = x
    for shift in (16, 8, 4, 2, 1):
        hi = cur >> shift
        take = hi != jnp.uint32(0)
        cur = jnp.where(take, hi, cur)
        n = n + jnp.where(take, jnp.int32(shift), jnp.int32(0))
    # n == floor(log2(x)) for x != 0.
    return jnp.where(x == jnp.uint32(0), jnp.int32(32), jnp.int32(31) - n)


def u64_leading_zeros(hi, lo) -> jax.Array:
    return jnp.where(_u32(hi) == 0, 32 + clz32(lo), clz32(hi))


# ---------------------------------------------------------------------------
# gemmlowp core ops
# ---------------------------------------------------------------------------


def saturating_rounding_doubling_high_mul(a, b) -> jax.Array:
    """Bit-exact gemmlowp SRDHM: trunc((2*a*b + nudge) / 2**31), saturated.

    Both operands are int32; viewing them as Q0.31 the result is the rounded
    Q0.31 product.  Implemented with 32-bit limb arithmetic only (no int64).
    """
    a = _i32(a)
    b = _i32(b)
    overflow = jnp.logical_and(a == INT32_MIN, b == INT32_MIN)
    neg = (a < 0) ^ (b < 0)
    # |a| as uint32 (INT32_MIN's magnitude 2**31 is representable in uint32).
    abs_a = jnp.where(a < 0, jnp.uint32(0) - _u32(a), _u32(a))
    abs_b = jnp.where(b < 0, jnp.uint32(0) - _u32(b), _u32(b))
    hi, lo = u64_from_mul_u32(abs_a, abs_b)  # |a*b| <= 2**62
    # gemmlowp: (2ab + nudge) / 2**31 with C truncating division and
    # nudge = ab >= 0 ? 2**30 : 1 - 2**30.  On the magnitude this becomes
    # mag = (2|ab| + n) >> 31 with n = 2**30 (pos) or 2**30 - 1 (neg).
    nudge_lo = jnp.where(neg, jnp.uint32((1 << 30) - 1), jnp.uint32(1 << 30))
    hi, lo = u64_add(hi, lo, jnp.zeros_like(hi), nudge_lo)
    mag = (lo >> 31) | (hi << 1)  # (hi:lo) >> 31, low 32 bits
    result = jnp.where(neg, jnp.int32(0) - _i32(mag), _i32(mag))
    return jnp.where(overflow, jnp.int32(INT32_MAX), result)


def rounding_divide_by_pot(x, exponent) -> jax.Array:
    """gemmlowp RoundingDivideByPOT: rounding arithmetic shift right."""
    x = _i32(x)
    if isinstance(exponent, int):
        if exponent == 0:
            return x
        assert 0 < exponent < 32, exponent
        mask = jnp.int32((1 << exponent) - 1)
        remainder = x & mask
        threshold = (mask >> 1) + jnp.where(x < 0, jnp.int32(1), jnp.int32(0))
        return (x >> exponent) + (remainder > threshold).astype(jnp.int32)
    exponent = _i32(exponent)
    mask = ((jnp.int32(1) << exponent) - 1).astype(jnp.int32)
    remainder = x & mask
    threshold = (mask >> 1) + jnp.where(x < 0, jnp.int32(1), jnp.int32(0))
    shifted = jnp.where(exponent > 0, x >> jnp.maximum(exponent, 0), x)
    inc = jnp.logical_and(exponent > 0, remainder > threshold)
    return shifted + inc.astype(jnp.int32)


def saturating_left_shift(x, n) -> jax.Array:
    """x << n with int32 saturation (n: static int or traced int32 >= 0)."""
    x = _i32(x)
    if isinstance(n, int):
        if n == 0:
            return x
        assert 0 < n < 32
    shifted = x << n
    bad = (shifted >> n) != x
    sat = jnp.where(x >= 0, jnp.int32(INT32_MAX), jnp.int32(INT32_MIN))
    return jnp.where(bad, sat, shifted)


def saturating_add_i32(a, b) -> jax.Array:
    a = _i32(a)
    b = _i32(b)
    s = a + b  # wraps
    overflow_pos = jnp.logical_and(jnp.logical_and(a > 0, b > 0), s < 0)
    overflow_neg = jnp.logical_and(jnp.logical_and(a < 0, b < 0), s >= 0)
    s = jnp.where(overflow_pos, jnp.int32(INT32_MAX), s)
    return jnp.where(overflow_neg, jnp.int32(INT32_MIN), s)


def saturate_i16(x) -> jax.Array:
    return jnp.clip(_i32(x), INT16_MIN, INT16_MAX).astype(jnp.int16)


def saturate_i8(x) -> jax.Array:
    return jnp.clip(_i32(x), -128, 127).astype(jnp.int8)


def rounding_half_sum(a, b) -> jax.Array:
    """Exact (a + b + 1) >> 1 without 64-bit arithmetic (gemmlowp)."""
    a = _i32(a)
    b = _i32(b)
    return (a >> 1) + (b >> 1) + (((a & 1) + (b & 1) + 1) >> 1)


# ---------------------------------------------------------------------------
# Static (python-side) multiplier computation -- runs offline at calibration
# time, mirroring TFLite's QuantizeMultiplier.
# ---------------------------------------------------------------------------


def quantize_multiplier(real_multiplier: float) -> Tuple[int, int]:
    """Decompose real == m0/2**31 * 2**shift with m0 in [2**30, 2**31)."""
    if real_multiplier == 0.0:
        return 0, 0
    if real_multiplier < 0:
        raise ValueError("multipliers must be non-negative")
    mant, exp = math.frexp(real_multiplier)  # mant in [0.5, 1)
    m0 = int(round(mant * (1 << 31)))
    if m0 == (1 << 31):
        m0 //= 2
        exp += 1
    if exp > 31:
        raise ValueError(f"multiplier {real_multiplier} too large")
    if exp < -31:
        return 0, 0  # underflows to zero
    return m0, exp


def multiply_by_quantized_multiplier(x, m0, shift) -> jax.Array:
    """TFLite MultiplyByQuantizedMultiplier: rescale int32 by m0/2**31 * 2**shift.

    ``m0``/``shift`` may be python ints (static) or int32 arrays (per-channel).
    """
    x = _i32(x)
    if isinstance(shift, int):
        left = max(shift, 0)
        right = max(-shift, 0)
        y = saturating_rounding_doubling_high_mul(
            saturating_left_shift(x, left) if left else x, jnp.int32(m0)
        )
        return rounding_divide_by_pot(y, right)
    shift = _i32(shift)
    m0 = _i32(m0)
    left = jnp.maximum(shift, 0)
    right = jnp.maximum(-shift, 0)
    y = saturating_rounding_doubling_high_mul(saturating_left_shift(x, left), m0)
    return rounding_divide_by_pot(y, right)


# ---------------------------------------------------------------------------
# Integer transcendentals (gemmlowp fixedpoint.h ports)
# ---------------------------------------------------------------------------

_EXP_CONSTANT_TERM = 1895147668  # exp(-1/8) in Q0.31
_EXP_ONE_THIRD = 715827883  # 1/3 in Q0.31
# (exponent, exp(-2**exponent) in Q0.31)
_EXP_BARREL = (
    (-2, 1672461947),
    (-1, 1302514674),
    (0, 790015084),
    (1, 290630308),
    (2, 39332535),
    (3, 720401),
    (4, 242),
)
_ONE_Q31 = INT32_MAX  # gemmlowp's F0::One()
_K48_OVER_17 = 1515870810  # 48/17 in Q2.29
_K_NEG32_OVER_17 = -1010580540  # -32/17 in Q2.29
_INV_SQRT2_Q31 = 1518500250  # 2**-0.5 in Q0.31


def exp_on_interval_between_negative_one_quarter_and_0_excl(a) -> jax.Array:
    """exp(a) for a in (-1/4, 0]; a and result are Q0.31 (gemmlowp Taylor)."""
    a = _i32(a)
    srdhm = saturating_rounding_doubling_high_mul
    x = a + jnp.int32(1 << 28)  # t = a + 1/8, |t| <= 1/8
    x2 = srdhm(x, x)
    x3 = srdhm(x2, x)
    x4 = srdhm(x2, x2)
    x4_over_4 = rounding_divide_by_pot(x4, 2)
    # t^2/2 + t^3/6 + t^4/24 == (((t^4/4 + t^3) / 3) + t^2) / 2
    tmp = rounding_divide_by_pot(
        srdhm(x4_over_4 + x3, jnp.int32(_EXP_ONE_THIRD)) + x2, 1
    )
    ct = jnp.int32(_EXP_CONSTANT_TERM)
    return ct + srdhm(ct, x + tmp)


def exp_on_negative_values(a, integer_bits: int) -> jax.Array:
    """exp(a) for a <= 0 in Q_{m}.{31-m} (m = integer_bits); result Q0.31."""
    assert 0 <= integer_bits <= 29
    a = _i32(a)
    frac_bits = 31 - integer_bits
    one_quarter = jnp.int32(1 << (frac_bits - 2))
    mask = one_quarter - 1
    a_mod = (a & mask) - one_quarter  # in (-1/4, 0] of the input format
    result = exp_on_interval_between_negative_one_quarter_and_0_excl(
        a_mod << integer_bits  # exact rescale to Q0.31
    )
    remainder = a_mod - a  # >= 0: the "quarters" part of |a|
    srdhm = saturating_rounding_doubling_high_mul
    for exponent, multiplier in _EXP_BARREL:
        if integer_bits > exponent:
            shift_amount = frac_bits + exponent
            if 0 <= shift_amount < 31:
                bit = jnp.int32(1 << shift_amount)
                result = jnp.where(
                    (remainder & bit) != 0,
                    srdhm(result, jnp.int32(multiplier)),
                    result,
                )
    if integer_bits > 5:
        clamp_bound = jnp.int32(-(1 << (frac_bits + 5)))
        result = jnp.where(a < clamp_bound, jnp.int32(0), result)
    return jnp.where(a == 0, jnp.int32(_ONE_Q31), result)


def one_over_one_plus_x(a) -> jax.Array:
    """1/(1+a) for a in [0, 1] given as Q0.31; result in Q2.29.

    gemmlowp one_over_one_plus_x_for_x_in_0_1: 3 Newton-Raphson iterations for
    1/d around d = (1+a)/2 in [0.5, 1], seeded with 48/17 - 32/17*d.
    """
    a = _i32(a)
    srdhm = saturating_rounding_doubling_high_mul
    half_denominator = rounding_half_sum(a, jnp.int32(_ONE_Q31))
    x = jnp.int32(_K48_OVER_17) + srdhm(half_denominator, jnp.int32(_K_NEG32_OVER_17))
    one_q2_29 = jnp.int32(1 << 29)
    for _ in range(3):
        hdx = srdhm(half_denominator, x)  # Q0.31*Q2.29 -> Q2.29 of d*x
        one_minus_hdx = one_q2_29 - hdx
        x = x + saturating_left_shift(srdhm(x, one_minus_hdx), 2)
    # x ~= 1/d = 2/(1+a) in Q2.29; return 1/(1+a) = x/2 (exact shift).
    return x >> 1


def tanh_fp(a, integer_bits: int) -> jax.Array:
    """tanh of Q_{m}.{31-m} int32 -> Q0.31 int32 (gemmlowp)."""
    a = _i32(a)
    srdhm = saturating_rounding_doubling_high_mul
    neg = a < 0
    abs_a = jnp.where(neg, jnp.where(a == INT32_MIN, jnp.int32(INT32_MAX), -a), a)
    # t = exp(-2|a|).  Doubling a Q_{m} value == reinterpreting its raw bits
    # in Q_{m+1}: exact, saturation-free (gemmlowp does the equivalent).
    t = exp_on_negative_values(-abs_a, integer_bits + 1)
    one_minus_t = jnp.int32(_ONE_Q31) - t
    inv = one_over_one_plus_x(t)  # Q2.29 of 1/(1+t), in [0.5, 1]
    result = saturating_left_shift(srdhm(one_minus_t, inv), 2)  # back to Q0.31
    return jnp.where(neg, -result, result)


def sigmoid_fp(a, integer_bits: int) -> jax.Array:
    """logistic of Q_{m}.{31-m} int32 -> Q0.31 int32 (gemmlowp)."""
    a = _i32(a)
    srdhm = saturating_rounding_doubling_high_mul
    neg = a < 0
    abs_neg = jnp.where(neg, a, -a)  # -|a| <= 0
    t = exp_on_negative_values(abs_neg, integer_bits)
    # sigmoid(-|a|) = t / (1 + t)
    sig_neg = saturating_left_shift(srdhm(t, one_over_one_plus_x(t)), 2)
    result = jnp.where(neg, sig_neg, jnp.int32(_ONE_Q31) - sig_neg)
    return jnp.where(a == 0, jnp.int32(1 << 30), result)


# --- int16 wrappers: the LSTM-facing API (paper sec 3.2.1, TFLite semantics).


def tanh_q15(x, input_integer_bits: int = 3) -> jax.Array:
    """tanh: int16 Q_{m.15-m} in -> int16 Q0.15 out."""
    x32 = jnp.asarray(x).astype(jnp.int32) << 16  # Q_{m.15-m} -> Q_{m.31-m}
    y = tanh_fp(x32, input_integer_bits)
    return saturate_i16(rounding_divide_by_pot(y, 16))


def sigmoid_q15(x, input_integer_bits: int = 3) -> jax.Array:
    """sigmoid: int16 Q_{m.15-m} in -> int16 Q0.15 out."""
    x32 = jnp.asarray(x).astype(jnp.int32) << 16
    y = sigmoid_fp(x32, input_integer_bits)
    return saturate_i16(rounding_divide_by_pot(y, 16))


# ---------------------------------------------------------------------------
# Integer reciprocal square root / reciprocal (for LayerNorm, RMSNorm, softmax)
# ---------------------------------------------------------------------------


def integer_rsqrt_normalized(m_q31) -> jax.Array:
    """rsqrt of a mantissa in [0.5, 1) given as Q0.31; result Q2.29.

    Newton-Raphson: y <- y * (3 - m*y^2) / 2, four iterations from a linear
    seed; result in (1, sqrt(2)].
    """
    m = _i32(m_q31)
    srdhm = saturating_rounding_doubling_high_mul
    # seed: y0 ~= 1.7880 - 0.8047*m (linear fit; worst-case rel err ~3%)
    k_a = jnp.int32(int(round(1.7880 * (1 << 29))))  # Q2.29
    k_b = jnp.int32(int(round(0.8047 * (1 << 29))))  # Q2.29 coefficient
    # srdhm(Q0.31 m, Q2.29 k_b) = m*0.8047 * 2**29 -> Q2.29.
    y = k_a - srdhm(m, k_b)
    three_q27 = jnp.int32(3 << 27)
    for _ in range(4):
        y2 = srdhm(y, y)  # value y^2 * 2**27
        my2 = srdhm(m, y2)  # value m*y^2 * 2**27
        diff = three_q27 - my2  # (3 - m*y^2) * 2**27
        # y*(diff)/2: srdhm -> y*diff * 2**(29+27-31) = *2**25; want *2**28.
        y = saturating_left_shift(srdhm(y, diff), 3)
    return y


def integer_rsqrt_multiplier(hi, lo, extra_pow2: int = 0) -> Tuple[jax.Array, jax.Array]:
    """(m0, shift) int32 arrays with rsqrt(V)*2**extra_pow2 == m0/2**31 * 2**shift.

    V = hi*2**32 + lo (uint32 limbs, V > 0).  Feed the result to
    ``multiply_by_quantized_multiplier`` for per-row integer normalization.
    """
    hi = _u32(hi)
    lo = _u32(lo)
    lz = u64_leading_zeros(hi, lo)  # int32 in [0, 64]
    e = jnp.int32(64) - lz  # V = m * 2**e, m in [0.5, 1)
    # Extract the top 32 bits of V << lz (MSB lands at bit 63).
    lzc = jnp.clip(lz, 0, 63)
    lz_lt32 = lzc < 32
    sh = jnp.where(lz_lt32, lzc, lzc - 32).astype(jnp.uint32)
    lo_part = jnp.where(
        sh > 0, lo >> (jnp.uint32(32) - jnp.maximum(sh, 1)), jnp.uint32(0)
    )
    top_lt = (hi << sh) | lo_part
    top_ge = lo << sh
    top = jnp.where(lz_lt32, top_lt, top_ge)  # in [2**31, 2**32)
    m_q31 = _i32(top >> 1)  # Q0.31 mantissa in [0.5, 1)
    y = integer_rsqrt_normalized(m_q31)  # Q2.29 in (1, sqrt(2)]
    # rsqrt(V) = rsqrt(m) * 2**(-e/2).  For odd e use an extra 1/sqrt(2):
    # 2**(-e/2) = 2**(-(e-1)/2) * 2**(-1/2); half_e = floor(e/2) either way.
    e_is_odd = (e & 1) != 0
    y = jnp.where(
        e_is_odd,
        saturating_rounding_doubling_high_mul(y, jnp.int32(_INV_SQRT2_Q31)),
        y,
    )
    half_e = e >> 1
    # value(y) = y_raw * 2**-29 = (y_raw / 2**31) * 2**2
    m0 = y
    shift = jnp.int32(2 + extra_pow2) - half_e
    return m0, shift.astype(jnp.int32)


def integer_recip_multiplier(x_i32, extra_pow2: int = 0) -> Tuple[jax.Array, jax.Array]:
    """(m0, shift) with (1/x)*2**extra_pow2 ~= m0/2**31 * 2**shift; x > 0 int32."""
    x = _i32(x_i32)
    lz = clz32(x)
    e = jnp.int32(32) - lz  # x = m * 2**e, m in [0.5, 1)
    m_q31 = x << jnp.maximum(lz - 1, 0)  # exact: MSB to bit 30
    # 1/m = 2/(1 + a) with a = 2m - 1 in [0, 1)
    a = (m_q31 - jnp.int32(1 << 30)) << 1
    inv = one_over_one_plus_x(a)  # Q2.29 of 1/(2m) in (0.5, 1]
    # 1/x = 2 * (1/(2m)) * 2**-e ; value(inv) = inv/2**31 * 2**2
    m0 = inv
    shift = jnp.int32(2 + 1 + extra_pow2) - e
    return m0, shift.astype(jnp.int32)
