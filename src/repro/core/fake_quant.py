"""Quantization-aware training: straight-through fake quantization (sec 4).

The paper's QAT graph rewrite (fig 16) requires the input and recurrent
matmul components to be *un-concatenated* so each carries its own fake-quant
scale; our LSTM keeps W and R separate by construction, so QAT is just a
matter of wrapping tensors in ``fake_quant`` at the recipe's tap points.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def _ste(x: jax.Array, xq: jax.Array) -> jax.Array:
    """Straight-through estimator: forward xq, backward identity."""
    return x + jax.lax.stop_gradient(xq - x)


def fake_quant_symmetric(
    x: jax.Array,
    bits: int = 8,
    per_channel_axis: Optional[int] = None,
    pot: bool = False,
) -> jax.Array:
    """Symmetric fake quant with dynamically observed max-abs (QAT style)."""
    qmax = float(2 ** (bits - 1) - 1)
    if per_channel_axis is None:
        max_abs = jnp.max(jnp.abs(x))
    else:
        axes = tuple(i for i in range(x.ndim) if i != per_channel_axis % x.ndim)
        max_abs = jnp.max(jnp.abs(x), axis=axes, keepdims=True)
    max_abs = jnp.maximum(max_abs, 1e-8)
    if pot:
        max_abs = 2.0 ** jnp.ceil(jnp.log2(max_abs))
        scale = max_abs / (qmax + 1.0)
    else:
        scale = max_abs / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax)
    return _ste(x, q * scale)


def fake_quant_asymmetric(x: jax.Array, bits: int = 8) -> jax.Array:
    """Asymmetric fake quant with nudged zero point (paper sec 3.2.4)."""
    qmin = float(-(2 ** (bits - 1)))
    qmax = float(2 ** (bits - 1) - 1)
    t_min = jnp.minimum(jnp.min(x), 0.0)
    t_max = jnp.maximum(jnp.max(x), 0.0)
    scale = jnp.maximum((t_max - t_min) / (qmax - qmin), 1e-8)
    zp = jnp.clip(jnp.round(qmin - t_min / scale), qmin, qmax)  # nudged
    q = jnp.clip(jnp.round(x / scale) + zp, qmin, qmax)
    return _ste(x, (q - zp) * scale)


def fake_quant_q(x: jax.Array, fractional_bits: int, bits: int = 16) -> jax.Array:
    """Fake quant onto a fixed Q_{m.n} grid (e.g. Q3.12 gate inputs)."""
    scale = 2.0 ** (-fractional_bits)
    qmax = float(2 ** (bits - 1) - 1)
    q = jnp.clip(jnp.round(x / scale), -qmax - 1, qmax)
    return _ste(x, q * scale)
