"""Statistics collection for quantization (paper sec 4).

Two supported modes:

* **Post-training** (the paper's headline result): run float inference on a
  small representative dataset (the paper shows 100 utterances suffice) and
  record per-tensor min/max.  Models expose a ``taps`` side-channel: when a
  ``TapCollector`` is passed through the forward pass, every quantization-
  relevant intermediate registers itself under a stable name.

* **QAT**: the same taps drive ``fake_quant`` during training so the scales
  are learned under simulated quantization noise; the training graph keeps
  input and recurrent components un-concatenated so they carry separate
  scales (paper fig 16).
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class TapCollector:
    """Records min/max of named intermediates during a traced forward pass.

    The same object can be reused across jit invocations; ``snapshot`` returns
    the (device) stats of the latest call and ``merge`` folds them into a
    running numpy aggregate.
    """

    def __init__(self):
        self.taps: Dict[str, Tuple[jax.Array, jax.Array]] = {}

    def tap(self, name: str, x: jax.Array) -> jax.Array:
        lo = jnp.min(x).astype(jnp.float32)
        hi = jnp.max(x).astype(jnp.float32)
        if name in self.taps:
            plo, phi = self.taps[name]
            lo = jnp.minimum(lo, plo)
            hi = jnp.maximum(hi, phi)
        self.taps[name] = (lo, hi)
        return x

    def snapshot(self) -> Dict[str, Tuple[jax.Array, jax.Array]]:
        return dict(self.taps)


class Stats:
    """Running numpy min/max aggregate keyed by tap name."""

    def __init__(self):
        self.ranges: Dict[str, Tuple[float, float]] = {}

    def merge(self, taps: Dict[str, Tuple[jax.Array, jax.Array]]) -> None:
        for name, (lo, hi) in taps.items():
            lo = float(lo)
            hi = float(hi)
            if name in self.ranges:
                plo, phi = self.ranges[name]
                lo, hi = min(lo, plo), max(hi, phi)
            self.ranges[name] = (lo, hi)

    def range(self, name: str) -> Tuple[float, float]:
        if name not in self.ranges:
            raise KeyError(
                f"no calibration stats for tap '{name}'; have {sorted(self.ranges)}"
            )
        return self.ranges[name]

    def max_abs(self, name: str) -> float:
        lo, hi = self.range(name)
        return max(abs(lo), abs(hi))

    def to_dict(self) -> Dict[str, Tuple[float, float]]:
        return dict(self.ranges)

    @classmethod
    def from_dict(cls, d: Dict[str, Tuple[float, float]]) -> "Stats":
        s = cls()
        s.ranges = {k: (float(v[0]), float(v[1])) for k, v in d.items()}
        return s


def calibrate(
    apply_fn: Callable,
    params,
    batches,
    num_batches: Optional[int] = None,
) -> Stats:
    """Run ``apply_fn(params, batch, collector)`` over a calibration set.

    ``apply_fn`` must route the collector's ``tap`` through the model.  The
    paper's finding: a fixed ~100-sample set is enough for negligible loss.
    """
    stats = Stats()

    @jax.jit
    def _one(params, batch):
        collector = TapCollector()
        apply_fn(params, batch, collector)
        return collector.snapshot()

    for i, batch in enumerate(batches):
        if num_batches is not None and i >= num_batches:
            break
        stats.merge(jax.device_get(_one(params, batch)))
    return stats
