"""int8 gradient compression with error feedback (distributed-optimization).

The paper's int8 recipe applied to the data-parallel gradient exchange:
quantize (g + residual) symmetrically to int8 with a globally-agreed scale,
all-reduce in the integer domain (4x fewer wire bytes than f32, 2x vs bf16),
dequantize, and keep the quantization error as residual for the next step
(error feedback preserves convergence; tested in tests/test_optim.py).

``compressed_psum`` is the on-wire form for shard_map data parallelism;
``ef_compress_tree`` is the optimizer-level transform for pjit training where
XLA owns the all-reduce (it simulates the same wire quantization).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


def compressed_psum(g: jax.Array, axis_name: str, residual: Optional[jax.Array] = None):
    """int8 all-reduce of a float gradient over ``axis_name`` (shard_map body).

    Returns (mean gradient, new residual).  Exactness: the int32 sum of
    per-device int8 values is exact; the only loss is the int8 rounding,
    which the residual re-injects next step.
    """
    gf = g.astype(jnp.float32)
    if residual is not None:
        gf = gf + residual
    # agree on a shared scale (one scalar psum; negligible wire cost)
    local_max = jnp.max(jnp.abs(gf))
    global_max = jax.lax.pmax(local_max, axis_name)
    scale = jnp.maximum(global_max, 1e-20) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127)
    new_residual = gf - q * scale
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)  # wire: int8 payload
    n = jax.lax.psum(jnp.ones((), jnp.int32), axis_name)
    mean = total.astype(jnp.float32) * scale / n.astype(jnp.float32)
    return mean.astype(g.dtype), new_residual


def ef_init(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def ef_compress_tree(grads, residuals) -> Tuple[Any, Any]:
    """Optimizer-level error-feedback int8 transform (pjit path)."""

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-20) / 127.0
        q = jnp.clip(jnp.round(gf / scale), -127, 127)
        deq = q * scale
        return deq.astype(g.dtype), gf - deq

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_r = tdef.flatten_up_to(residuals)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (tdef.unflatten([o[0] for o in out]),
            tdef.unflatten([o[1] for o in out]))
