"""Optimizers: AdamW (f32 moments) and Adafactor (factored second moment for
the 100B+ configs), with global-norm clipping and warmup-cosine schedules.

Written against plain pytrees (no optax dependency in this offline image).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"  # adamw | adafactor | sgd
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    min_lr_ratio: float = 0.1


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    floor = cfg.min_lr_ratio
    return cfg.lr * warm * (floor + (1 - floor) * cos)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree_util.tree_leaves(tree))
    )


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads
    ), norm


# --- AdamW -------------------------------------------------------------------


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree_util.tree_map(zeros, params),
        "nu": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(cfg: OptConfig, grads, state, params):
    step = state["step"] + 1
    lr = schedule(cfg, step)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    b1, b2 = cfg.b1, cfg.b2

    def upd(g, mu, nu, p):
        g = g.astype(jnp.float32)
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * jnp.square(g)
        mu_hat = mu / (1 - b1 ** step.astype(jnp.float32))
        nu_hat = nu / (1 - b2 ** step.astype(jnp.float32))
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_mu = tdef.flatten_up_to(state["mu"])
    flat_nu = tdef.flatten_up_to(state["nu"])
    out = [upd(g, m, n, p) for g, m, n, p in zip(flat_g, flat_mu, flat_nu, flat_p)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_state = {
        "mu": tdef.unflatten([o[1] for o in out]),
        "nu": tdef.unflatten([o[2] for o in out]),
        "step": step,
    }
    return new_p, new_state, {"lr": lr, "grad_norm": gnorm}


# --- Adafactor (factored second moments; memory ~ O(n+m) per matrix) ---------


def adafactor_init(params):
    def init(p):
        if p.ndim >= 2:
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {
        "v": jax.tree_util.tree_map(init, params,
                                    is_leaf=lambda x: hasattr(x, "ndim")),
        "step": jnp.zeros((), jnp.int32),
    }


def adafactor_update(cfg: OptConfig, grads, state, params):
    step = state["step"] + 1
    lr = schedule(cfg, step)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    decay = 1.0 - (step.astype(jnp.float32) + 1.0) ** -0.8

    def upd(g, v, p):
        g = g.astype(jnp.float32)
        g2 = jnp.square(g) + 1e-30
        if p.ndim >= 2:
            vr = decay * v["vr"] + (1 - decay) * jnp.mean(g2, axis=-1)
            vc = decay * v["vc"] + (1 - decay) * jnp.mean(g2, axis=-2)
            denom = jnp.sqrt(
                vr[..., None] * vc[..., None, :]
                / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), 1e-30)[..., None]
            )
            delta = g / jnp.maximum(denom, 1e-30)
            new_v = {"vr": vr, "vc": vc}
        else:
            nv = decay * v["v"] + (1 - decay) * g2
            delta = g / (jnp.sqrt(nv) + 1e-30)
            new_v = {"v": nv}
        # update clipping (Adafactor's d=1.0 RMS rule)
        rms = jnp.sqrt(jnp.mean(jnp.square(delta)) + 1e-30)
        delta = delta / jnp.maximum(1.0, rms)
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), new_v

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(g, v, p) for g, v, p in zip(flat_g, flat_v, flat_p)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_state = {"v": tdef.unflatten([o[1] for o in out]), "step": step}
    return new_p, new_state, {"lr": lr, "grad_norm": gnorm}


def make_optimizer(cfg: OptConfig):
    if cfg.name == "adamw":
        return adamw_init, lambda g, s, p: adamw_update(cfg, g, s, p)
    if cfg.name == "adafactor":
        return adafactor_init, lambda g, s, p: adafactor_update(cfg, g, s, p)
    raise ValueError(cfg.name)
