"""Serve a transformer with batched requests: float vs int8 side by side.

Simple continuous-batching loop: requests arrive with different prompt
lengths, get slotted into a fixed-size batch, decode steps run for the whole
batch, finished slots are refilled.

    PYTHONPATH=src python examples/serve_quantized.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import SMOKE_CONFIGS
from repro.models import model_zoo, quant_transformer

IDENT = lambda x, logical=None: x
MAX_LEN = 96
BATCH = 4


def serve(bundle, params, requests, gen_tokens=12):
    """requests: list of 1-D prompt arrays; returns list of generations."""
    decode = jax.jit(lambda p, t, s: bundle.decode(p, t, s, IDENT))
    state = bundle.init_state(BATCH, MAX_LEN)
    queue = list(enumerate(requests))
    active = [None] * BATCH  # (req_id, remaining_prompt, generated)
    results = {}
    tok = jnp.zeros((BATCH, 1), jnp.int32)
    steps = 0
    while queue or any(a is not None for a in active):
        # admit new requests into free slots (simplified: restart batch state
        # when the whole batch turns over; production would use paged caches)
        for slot in range(BATCH):
            if active[slot] is None and queue:
                rid, prompt = queue.pop(0)
                active[slot] = [rid, list(prompt), []]
        next_tok = np.asarray(tok)
        for slot, st in enumerate(active):
            if st is None:
                continue
            if st[1]:  # still feeding the prompt
                next_tok[slot, 0] = st[1].pop(0)
        logits, state = decode(params, jnp.asarray(next_tok), state)
        steps += 1
        sampled = np.asarray(jnp.argmax(logits, -1))
        for slot, st in enumerate(active):
            if st is None:
                continue
            if not st[1]:  # prompt consumed: collect generation
                st[2].append(int(sampled[slot]))
                next_tok[slot, 0] = sampled[slot]
                if len(st[2]) >= gen_tokens:
                    results[st[0]] = st[2]
                    active[slot] = None
        tok = jnp.asarray(next_tok)
    return [results[i] for i in range(len(requests))], steps


def main():
    cfg = SMOKE_CONFIGS["qwen3-4b"]
    bundle = model_zoo.build(cfg)
    params, _ = bundle.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    requests = [rng.integers(0, cfg.vocab_size, rng.integers(4, 12))
                for _ in range(6)]

    t0 = time.time()
    gen_f, steps = serve(bundle, params, requests)
    t_float = time.time() - t0

    qb = quant_transformer.quantize_bundle(bundle)
    qparams, _ = qb.init(jax.random.PRNGKey(0))
    t0 = time.time()
    gen_q, _ = serve(qb, qparams, requests)
    t_int8 = time.time() - t0

    agree = np.mean([
        np.mean(np.asarray(a[:6]) == np.asarray(b[:6]))
        for a, b in zip(gen_f, gen_q)])
    print(f"served {len(requests)} requests in {steps} decode steps")
    print(f"float: {t_float:.2f}s   int8 (weights+KV cache): {t_int8:.2f}s")
    print(f"greedy-token agreement float vs int8: {agree:.0%}")
    for i, (a, b) in enumerate(zip(gen_f[:3], gen_q[:3])):
        print(f"  req{i}: float={a[:8]} int8={b[:8]}")


if __name__ == "__main__":
    main()
