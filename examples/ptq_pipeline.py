"""The paper's full statistics-collection story (sec 4): post-training
quantization vs quantization-aware training, side by side.

Trains a small LSTM regressor, then quantizes it three ways:
  * PTQ with a LARGE calibration set,
  * PTQ with a ~100-sample calibration set (the paper's headline finding:
    this is enough),
  * QAT (fake-quant fine-tuning with separate input/recurrent scales,
    fig 16) followed by the same integer conversion.

    PYTHONPATH=src python examples/ptq_pipeline.py
"""
import jax
import jax.numpy as jnp

from repro.core import recipe
from repro.core.calibrate import Stats, TapCollector
from repro.models import lstm, quant_lstm

variant = lstm.LSTMVariant(use_layernorm=True)
cfg = lstm.LSTMConfig(16, 48, 0, variant)
key = jax.random.PRNGKey(0)
params = lstm.init_lstm_params(key, cfg)

xs = jax.random.normal(jax.random.PRNGKey(1), (256, 12, 16))
target = jnp.cumsum(xs, axis=1)[..., :16] * 0.2  # running-sum task


def task_loss(p, qat=False):
    ys, _ = lstm.lstm_layer(p, cfg, xs, qat=qat)
    return jnp.mean(jnp.square(ys[..., :16] - target))


grad_fn = jax.jit(jax.value_and_grad(lambda p: task_loss(p)))
for i in range(120):
    l, g = grad_fn(params)
    params = jax.tree_util.tree_map(lambda a, b: a - 0.05 * b, params, g)
print(f"float task loss: {float(task_loss(params)):.5f}")


def integer_loss(p, calib_samples):
    col = TapCollector()
    lstm.lstm_layer(p, cfg, xs[:calib_samples], collector=col)
    stats = Stats()
    stats.merge(jax.device_get(col.snapshot()))
    arrays, spec = recipe.quantize_lstm_layer(p, cfg, stats)
    xs_q = quant_lstm.quantize_input(xs, spec.s_x, spec.zp_x)
    ys_q, _ = quant_lstm.quant_lstm_layer(arrays, spec, xs_q)
    ys = quant_lstm.dequantize_output(ys_q, spec.s_h, spec.zp_h_out)
    return float(jnp.mean(jnp.square(ys[..., :16] - target)))


print(f"PTQ (256-sample calibration): {integer_loss(params, 256):.5f}")
print(f"PTQ (8-sample calibration):   {integer_loss(params, 8):.5f}"
      "   <- the paper's '100 utterances suffice' finding")

# QAT fine-tune: simulate quantization noise in training (fig 16 graph)
qat_params = params
qat_grad = jax.jit(jax.value_and_grad(lambda p: task_loss(p, qat=True)))
for i in range(40):
    l, g = qat_grad(qat_params)
    qat_params = jax.tree_util.tree_map(lambda a, b: a - 0.02 * b,
                                        qat_params, g)
print(f"QAT then integer conversion:  {integer_loss(qat_params, 64):.5f}")
