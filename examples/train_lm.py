"""End-to-end driver: train a ~100M-param transformer LM with the full
production loop (sharded step, async checkpoints, watchdog, restart).

Default invocation runs a scaled-down 30-second demo; pass --full for the
real ~100M/300-step run (CPU-hours on this host; minutes on one TPU chip):

    PYTHONPATH=src python examples/train_lm.py [--full]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ArchConfig
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import model_zoo
from repro.optim.optimizers import OptConfig
from repro.runtime.fault import StepWatchdog
from repro.runtime.train_loop import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    if args.full:  # ~100M params
        cfg = ArchConfig(name="lm-100m", family="dense", n_layers=10,
                         d_model=640, n_heads=10, n_kv_heads=10, head_dim=64,
                         d_ff=2560, vocab_size=32768, shard_profile="tiny")
        steps, batch, seq = 300, 16, 256
    else:
        cfg = ArchConfig(name="lm-demo", family="dense", n_layers=4,
                         d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
                         d_ff=512, vocab_size=2048, shard_profile="tiny",
                         remat="none")
        steps, batch, seq = 60, 8, 64

    bundle = model_zoo.build(cfg)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                                  global_batch=batch))
    art = make_train_step(bundle, None, OptConfig(
        lr=1e-2, warmup_steps=10, total_steps=steps))
    params, _ = bundle.init(jax.random.PRNGKey(0))
    n = model_zoo.count_params(params)
    print(f"model: {cfg.name} ({n/1e6:.1f}M params), {steps} steps")
    opt = art.init_opt(params)
    ckpt = CheckpointManager(args.ckpt_dir, keep_k=2)
    start = ckpt.latest_step() or 0
    if start:
        (params, opt), _ = ckpt.restore(start, (params, opt))
        print(f"resumed from step {start}")
    wd = StepWatchdog()
    for step, raw in data.iterate(start):
        if step >= steps:
            break
        batch_d = {k: jnp.asarray(v) for k, v in raw.items()}
        t0 = time.time()
        params, opt, m = art.step_fn(params, opt, batch_d)
        verdict = wd.observe(time.time() - t0)
        if step % 10 == 0:
            print(f"step {step:4d}  loss {float(m['loss']):.4f}  "
                  f"lr {float(m['lr']):.2e}  [{verdict}]")
        if (step + 1) % 50 == 0:
            ckpt.save(step + 1, (params, opt))
    ckpt.wait()
    print(f"done; stragglers {wd.stragglers}/{wd.steps}; "
          f"checkpoints at {args.ckpt_dir}")


if __name__ == "__main__":
    main()
