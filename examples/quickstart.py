"""Quickstart: quantize an LSTM to integer-only execution in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import recipe
from repro.core.calibrate import Stats, TapCollector
from repro.models import lstm, quant_lstm

# 1. a float LSTM with the paper's full feature set
variant = lstm.LSTMVariant(use_layernorm=True, use_projection=True,
                           use_peephole=True)
cfg = lstm.LSTMConfig(d_input=64, d_hidden=128, d_proj=64, variant=variant)
params = lstm.init_lstm_params(jax.random.PRNGKey(0), cfg)

# 2. calibrate ranges on a small representative set (post-training, sec 4)
xs = jax.random.normal(jax.random.PRNGKey(1), (8, 20, 64))
collector = TapCollector()
ys_float, _ = lstm.lstm_layer(params, cfg, xs, collector=collector)
stats = Stats()
stats.merge(jax.device_get(collector.snapshot()))

# 3. apply the paper's Table-2 recipe -> integer arrays + static plan
arrays, spec = recipe.quantize_lstm_layer(params, cfg, stats)
print("recipe:", *recipe.recipe_table(spec).items(), sep="\n  ")

# 4. run entirely in integers (int8 matmuls, int16 gemmlowp transcendentals)
xs_q = quant_lstm.quantize_input(xs, spec.s_x, spec.zp_x)
ys_q, _ = quant_lstm.quant_lstm_layer(arrays, spec, xs_q)
ys_int = quant_lstm.dequantize_output(ys_q, spec.s_h, spec.zp_h_out)

err = float(jnp.abs(ys_int - ys_float).max())
rel = err / float(jnp.abs(ys_float).max())
print(f"\ninteger vs float: max abs err {err:.4f} (rel {rel:.2%})")
assert rel < 0.05
print("OK -- integer-only LSTM matches the float reference.")
