# Developer entry points.  `make check` is the CI gate: it COLLECTS the whole
# suite first (so import/collection regressions fail loudly and early), then
# runs the `fast` marker subset with Pallas interpret=True on CPU, bounded by
# a timeout.
PY ?= python
PYTEST = PYTHONPATH=src $(PY) -m pytest

.PHONY: check test collect bench

collect:
	$(PYTEST) -q --collect-only >/dev/null

check: collect
	timeout 1800 env PYTHONPATH=src REPRO_KERNEL_BACKEND=xla \
		$(PY) -m pytest -q -m fast

test:
	$(PYTEST) -q

bench:
	PYTHONPATH=src $(PY) benchmarks/speed.py
