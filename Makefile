# Developer entry points.  `make check` is the CI gate: it COLLECTS the whole
# suite first (so import/collection regressions fail loudly and early), then
# runs the `fast` marker subset with Pallas interpret=True on CPU, bounded by
# a timeout.  BACKEND selects the kernel backend the fast subset runs under
# (CI runs a {xla, pallas_interpret} matrix).
PY ?= python
PYTEST = PYTHONPATH=src $(PY) -m pytest
BACKEND ?= xla
# engine-smoke knobs: prefill chunk size and the serve-CLI backend name
# (serve.py takes "interpret" for the pallas_interpret kernel backend)
CHUNK ?= 1
SERVE_BACKEND ?= xla
# speculative-decode knobs: draft budget (SPEC=0 runs the greedy baseline
# leg, which skips the accept gate), gate bars (TTFT_BAR lets CI relax the
# chunked-prefill TTFT gate for noisy 2-core runners)
SPEC ?= 4
SPEC_GATE ?= 1.3
TTFT_BAR ?= 2.0
# scheduler knobs: slot-scheduling policy for the oversubscribed leg of the
# preemption benchmark + the engine smoke, admission headroom ratio, and the
# tokens/s gate of oversubscribed-vs-reject (relaxed in CI smoke: the win is
# structural -- occupancy -- but 2-core runners are noisy)
POLICY ?= srf
OVERSUB ?= 3.0
PREEMPT_GATE ?= 1.2
# fleet knobs: shard count, open-loop request count, and the goodput
# retention gate of the faulted leg (tokens per fleet STEP, so the gate is
# deterministic for a given workload + injector seed -- CI-safe)
SHARDS ?= 2
FLEET_REQUESTS ?= 24
FLEET_GATE ?= 0.7
# fault + gate flags of the fleet smoke leg; CI's 1-shard no-fault leg
# overrides this with an empty string (killing the only shard would just
# measure dead air, and the retention gate needs a faulted leg to compare)
FLEET_FAULT ?= --kill-frac 0.5 --kill-restart 24 --check-retention $(FLEET_GATE)

.PHONY: check test collect bench prefill-bench prefill-bench-smoke \
	engine-smoke scheduler-smoke engine-bench engine-ttft-bench \
	spec-bench spec-bench-smoke preempt-bench preempt-bench-smoke \
	zoo-smoke zoo-bench zoo-bench-smoke fleet-smoke fleet-bench \
	fleet-bench-smoke

collect:
	$(PYTEST) -q --collect-only >/dev/null

check: collect
	timeout 3600 env PYTHONPATH=src REPRO_KERNEL_BACKEND=$(BACKEND) \
		$(PY) -m pytest -q -m fast

test:
	$(PYTEST) -q

bench:
	PYTHONPATH=src $(PY) benchmarks/speed.py

# hoisted-GEMM vs per-step-scan prefill throughput with the >=1.5x hard
# gate at the acceptance shape (B=8, T=64); writes BENCH_prefill.json
prefill-bench:
	PYTHONPATH=src $(PY) benchmarks/prefill_throughput.py \
		--check-speedup 1.5

# CI smoke: same gate machinery at a small (B, T) / relaxed bar so 2-core
# runners finish fast; proves the gate path end-to-end on every push
prefill-bench-smoke:
	timeout 600 env PYTHONPATH=src $(PY) benchmarks/prefill_throughput.py \
		--batch 4 --seq 32 --iters 5 \
		--check-speedup 1.2 --out BENCH_prefill_smoke.json

# end-to-end continuous-batching serve in under a minute (post-compile):
# mixed prompt/gen lengths through 8 slots on the smoke LSTM LM.
# `make engine-smoke CHUNK=4` exercises chunked prefill; SERVE_BACKEND
# selects the kernel backend (xla | pallas | interpret).
engine-smoke:
	timeout 300 env PYTHONPATH=src $(PY) -m repro.launch.serve \
		--arch lstm-rnnt --smoke --quant int8-lstm --engine \
		--slots 8 --requests 12 --prompt-len 8 --gen 8 \
		--chunk $(CHUNK) --backend $(SERVE_BACKEND)

# scheduler smoke: the same serve CLI under a preempting policy with
# oversubscription (more live streams than slots, time-multiplexed through
# the host-side state pool); POLICY selects fifo|priority|srf|rr
scheduler-smoke:
	timeout 300 env PYTHONPATH=src $(PY) -m repro.launch.serve \
		--arch lstm-rnnt --smoke --quant int8-lstm --engine \
		--slots 4 --requests 12 --prompt-len 8 --gen 8 \
		--policy $(POLICY) --oversubscribe $(OVERSUB) \
		--backend $(SERVE_BACKEND)

# engine vs sequential serving with the >=2x acceptance gate enforced
engine-bench:
	PYTHONPATH=src $(PY) benchmarks/engine_throughput.py \
		--slots 8 --requests 24 --chunk $(CHUNK) --check-speedup 2.0

# chunked prefill on a prompt-heavy trace: mean TTFT must drop >= TTFT_BAR
# (default 2x; CI passes a relaxed bar -- wall-clock TTFT on shared 2-core
# runners is noisy, and the deterministic step-count 2x gate lives in
# tests/test_engine.py)
engine-ttft-bench:
	timeout 1200 env PYTHONPATH=src $(PY) benchmarks/engine_throughput.py \
		--slots 8 --requests 12 --prompt-heavy --chunk 4 \
		--check-ttft-speedup $(TTFT_BAR)

# speculative decoding vs greedy on a repetitive-text trace: bit-exact per
# stream AND >= SPEC_GATE accepted tokens per verify slot-step (the gate is
# step-count based, so it is deterministic and CI-safe); writes
# BENCH_spec.json
spec-bench:
	PYTHONPATH=src $(PY) benchmarks/spec_decode.py \
		--speculate $(SPEC) \
		$(if $(filter-out 0,$(SPEC)),--check-accept $(SPEC_GATE))

# CI smoke: same machinery with a matrix-selectable backend and draft
# budget; SPEC=0 runs the greedy baseline leg (bit-exactness vs
# decode_single still enforced, accept gate skipped -- it needs drafts)
spec-bench-smoke:
	timeout 1500 env PYTHONPATH=src $(PY) benchmarks/spec_decode.py \
		--backend $(SERVE_BACKEND) --speculate $(SPEC) \
		$(if $(filter-out 0,$(SPEC)),--check-accept $(SPEC_GATE)) \
		--out BENCH_spec_smoke.json

# preempt/resume swap cost + bursty-trace goodput: oversubscribed POLICY
# scheduling vs the FIFO-with-rejection baseline, bit-exactness enforced on
# every served stream, tokens/s gate >= PREEMPT_GATE; writes
# BENCH_preempt.json
preempt-bench:
	PYTHONPATH=src $(PY) benchmarks/preempt_resume.py \
		--slots 4 --bursts 4 --policy $(POLICY) \
		--oversubscribe $(OVERSUB) \
		--check-speedup $(PREEMPT_GATE) --out BENCH_preempt.json

# CI smoke: same gate machinery, smaller trace + relaxed bar so 2-core
# runners finish fast; proves the gate path end-to-end on every push
preempt-bench-smoke:
	timeout 1500 env PYTHONPATH=src $(PY) benchmarks/preempt_resume.py \
		--slots 4 --bursts 3 --period 16 \
		--backend $(SERVE_BACKEND) --policy $(POLICY) \
		--oversubscribe $(OVERSUB) \
		--check-speedup $(PREEMPT_GATE) --out BENCH_preempt_smoke.json

# fleet smoke: the serve CLI through the admission router with a seeded
# mid-flight shard kill -- recovery (state migration + prefix replay) runs
# on every invocation, not just in tests
fleet-smoke:
	timeout 600 env PYTHONPATH=src $(PY) -m repro.launch.serve \
		--arch lstm-rnnt --smoke --quant int8-lstm --engine \
		--shards $(SHARDS) --slots 2 --requests 12 \
		--prompt-len 8 --gen 8 --backend $(SERVE_BACKEND) \
		--fault-spec '{"kills": [{"shard": 0, "at_frac": 0.5, "restart_after": 24}]}'

# open-loop SLO benchmark: Poisson arrivals / heavy-tailed lengths through
# the fleet, no-fault leg vs 1-shard-killed-at-50%-progress leg,
# bit-exactness on every completed stream (kills, migrations, and replays
# included) and the goodput-retention gate >= FLEET_GATE; writes
# BENCH_fleet.json
fleet-bench:
	PYTHONPATH=src $(PY) benchmarks/fleet_load.py \
		--shards $(SHARDS) --slots 2 --requests $(FLEET_REQUESTS) \
		--kill-frac 0.5 --kill-restart 24 \
		--check-retention $(FLEET_GATE) --out BENCH_fleet.json

# CI smoke: same gate machinery, bounded wall time; proves the retention
# gate end-to-end on every push (goodput is tokens per fleet step --
# deterministic, so the relaxed-runner caveat of the wall-clock gates does
# not apply here)
fleet-bench-smoke:
	timeout 1800 env PYTHONPATH=src $(PY) benchmarks/fleet_load.py \
		--shards $(SHARDS) --slots 2 --requests $(FLEET_REQUESTS) \
		--backend $(SERVE_BACKEND) $(FLEET_FAULT) \
		--out BENCH_fleet_smoke.json

# GRU leg of the cell zoo (PR 8): serve the gru-rnnt smoke stack through
# the unchanged continuous-batching engine, then replay the checked-in GRU
# goldens (layer variants + LM decode + engine decode under {fifo, srf} x
# oversubscription) -- any integer drift fails the leg
zoo-smoke:
	timeout 300 env PYTHONPATH=src $(PY) -m repro.launch.serve \
		--arch gru-rnnt --smoke --quant int8-gru --engine \
		--slots 4 --requests 8 --prompt-len 8 --gen 8 \
		--backend $(SERVE_BACKEND)
	timeout 1800 env PYTHONPATH=src $(PY) -m pytest -q \
		tests/test_golden_gru.py

# GRU vs LSTM sequence throughput at matched hidden size with the GRU >=
# LSTM hard gate; writes BENCH_zoo.json
zoo-bench:
	PYTHONPATH=src $(PY) benchmarks/zoo_throughput.py --min-ratio 1.0

# CI smoke: same gate machinery at a small shape / relaxed bar (2-core
# runners are noisy; the real >= 1.0x gate is `make zoo-bench`)
zoo-bench-smoke:
	timeout 900 env PYTHONPATH=src $(PY) benchmarks/zoo_throughput.py \
		--batch 4 --seq 32 --iters 5 --min-ratio 0.9 \
		--out BENCH_zoo_smoke.json
