"""Continuous-batching engine: the core invariant is BIT-exactness.

Integer decode is deterministic and every decode-batch row is computed
independently, so a stream served inside a busy engine batch must produce
exactly the tokens it produces when decoded alone -- regardless of slot
index, co-tenants, slot count, or admission order.  These tests assert that
invariant deterministically (>= 8 concurrent mixed-length streams, the PR
acceptance gate) and -- when hypothesis is installed -- over randomized
workloads and admission orders.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import SMOKE_CONFIGS
from repro.launch import engine as E
from repro.models import lstm_lm, model_zoo

pytestmark = pytest.mark.fast


@pytest.fixture(scope="module")
def qlm():
    """Quantized smoke LSTM LM shared by every test in this module (the
    engine/reference jit caches key on qlayers identity)."""
    cfg = SMOKE_CONFIGS["lstm-rnnt"]
    bundle = model_zoo.build(cfg)
    params, _ = bundle.init(jax.random.PRNGKey(0))
    calib = jax.random.randint(jax.random.PRNGKey(2), (4, 8), 0,
                               cfg.vocab_size)
    qlayers = lstm_lm.quantize_stack(params, cfg, calib)
    return params, qlayers, cfg


@pytest.fixture(scope="module")
def qfwd(qlm):
    """One jitted quant_forward shared by the state-helper tests (jax.jit
    retraces per input shape, so a single callable covers them all)."""
    params, qlayers, cfg = qlm
    return jax.jit(lambda p, t, s: lstm_lm.quant_forward(
        p, qlayers, cfg, t, s))


def _reference(params, qlayers, cfg, requests):
    return {r.rid: E.decode_single(params, qlayers, cfg, r.prompt,
                                   r.max_new_tokens) for r in requests}


def test_engine_8_concurrent_streams_bitexact(qlm):
    """Acceptance gate: >= 8 concurrent streams with mixed prompt/gen
    lengths, every stream bit-identical to decoding it alone."""
    params, qlayers, cfg = qlm
    rng = np.random.default_rng(7)
    # mixed lengths drawn from a small set so the batch-1 reference only
    # compiles a handful of distinct prefill shapes
    requests = [
        E.Request(rid=i,
                  prompt=rng.integers(0, cfg.vocab_size, size=(p,)),
                  max_new_tokens=g)
        for i, (p, g) in enumerate(
            [(2, 9), (3, 7), (5, 5), (2, 8), (3, 6), (5, 4),
             (2, 2), (3, 1), (5, 3), (2, 5)])
    ]
    eng = E.ContinuousBatchingEngine(params, qlayers, cfg, n_slots=8)
    eng.submit_all(requests)
    results, stats = eng.run()

    assert stats.max_active >= 8, "workload never filled all 8 slots"
    assert len(results) == len(requests)
    ref = _reference(params, qlayers, cfg, requests)
    for r in requests:
        assert results[r.rid].tokens == ref[r.rid], f"stream {r.rid} drifted"
        assert len(results[r.rid].tokens) == r.max_new_tokens


def test_admission_order_irrelevant(qlm):
    """The same workload FIFO and shuffled must emit identical per-stream
    tokens (continuous batching is invisible to each stream; slot-count
    invariance is covered by the 8-slot-vs-single-stream tests)."""
    params, qlayers, cfg = qlm
    requests = E.synthetic_trace(6, cfg.vocab_size, seed=11,
                                 prompt_lens=(2, 4, 5), gen_lens=(3, 6))
    outcomes = []
    for order in (list(range(6)), [4, 2, 0, 5, 1, 3]):
        eng = E.ContinuousBatchingEngine(params, qlayers, cfg, n_slots=3)
        eng.submit_all([requests[i] for i in order])
        results, _ = eng.run()
        outcomes.append({rid: res.tokens for rid, res in results.items()})
    assert outcomes[0] == outcomes[1]


def test_eviction_reuses_slots_midflight(qlm):
    """More requests than slots: finished streams must be evicted and their
    slots re-admit pending requests (total steps well under sequential)."""
    params, qlayers, cfg = qlm
    requests = E.synthetic_trace(9, cfg.vocab_size, seed=3,
                                 prompt_lens=(2, 3), gen_lens=(2, 4))
    eng = E.ContinuousBatchingEngine(params, qlayers, cfg, n_slots=3)
    eng.submit_all(requests)
    results, stats = eng.run()
    assert len(results) == 9
    sequential_steps = sum(r.prompt.size + r.max_new_tokens - 1
                           for r in requests)
    assert stats.steps < sequential_steps
    assert 0.0 < stats.occupancy <= 1.0
    # admission stamps must show slot reuse over time
    assert max(r.admitted_step for r in results.values()) > 0


def test_stack_slice_state_roundtrip(qlm, qfwd):
    """slice_state/stack_state: slicing a mid-decode batch row gives the
    bitwise state of that stream, and stacking slices reassembles the
    batch."""
    params, qlayers, cfg = qlm
    toks = jnp.asarray(
        np.random.default_rng(5).integers(0, cfg.vocab_size, size=(4, 6)),
        jnp.int32)
    state = lstm_lm.init_quant_decode_state(qlayers, 4, per_slot_len=True)
    _, state = qfwd(params, toks, state)

    singles = []
    for r in range(4):
        s1 = lstm_lm.init_quant_decode_state(qlayers, 1, per_slot_len=True)
        _, s1 = qfwd(params, toks[r:r + 1], s1)
        singles.append(s1)
        got = lstm_lm.slice_state(state, r)
        for k in ("h", "c"):
            for a, b in zip(got[k], s1[k]):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    restacked = lstm_lm.stack_state(singles)
    for k in ("h", "c"):
        for a, b in zip(restacked[k], state[k]):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(restacked["len"]),
                                  np.asarray(state["len"]))


def test_reset_quant_slot_restores_initial_rows(qlm, qfwd):
    """Admission reset: the reset row equals a freshly-initialized state row
    while other rows are untouched."""
    params, qlayers, cfg = qlm
    state = lstm_lm.init_quant_decode_state(qlayers, 3, per_slot_len=True)
    fresh = jax.tree_util.tree_map(lambda x: np.asarray(x).copy(), state)
    toks = jnp.asarray([[1], [2], [3]], jnp.int32)
    _, state = qfwd(params, toks, state)
    dirty = jax.tree_util.tree_map(lambda x: np.asarray(x).copy(), state)
    state = lstm_lm.reset_quant_slot(qlayers, state, jnp.int32(1))
    for k in ("h", "c"):
        for got, f, d in zip(state[k], fresh[k], dirty[k]):
            got = np.asarray(got)
            np.testing.assert_array_equal(got[1], f[1])
            np.testing.assert_array_equal(got[0], d[0])
            np.testing.assert_array_equal(got[2], d[2])
    assert int(state["len"][1]) == 0 and int(state["len"][0]) == 1


def test_trace_roundtrip(tmp_path, qlm):
    """JSON trace loading: explicit prompts and prompt_len synthesis."""
    import json

    params, qlayers, cfg = qlm
    path = tmp_path / "trace.json"
    path.write_text(json.dumps([
        {"prompt": [3, 1, 4], "gen": 2, "id": 42},
        {"prompt_len": 5, "gen": 3},
    ]))
    reqs = E.load_trace(str(path), cfg.vocab_size, seed=0)
    assert reqs[0].rid == 42 and reqs[0].prompt.tolist() == [3, 1, 4]
    assert reqs[1].prompt.size == 5 and reqs[1].max_new_tokens == 3
    # n_slots=3 reuses the step trace compiled by the tests above
    eng = E.ContinuousBatchingEngine(params, qlayers, cfg, n_slots=3)
    eng.submit_all(reqs)
    results, _ = eng.run()
    assert results[42].tokens == E.decode_single(
        params, qlayers, cfg, reqs[0].prompt, 2)


def test_engine_with_mesh_sharding_hook(qlm):
    """The batch-axis sharding hook (single-device mesh) must not change a
    single emitted token -- including the chunked-prefill program, whose
    (S, K) token block and (S,) valid vector go through
    ``engine_block_sharding``."""
    from jax.sharding import Mesh

    from repro.runtime import sharding as shlib

    params, qlayers, cfg = qlm
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    rules = shlib.rules_for(cfg.shard_profile)
    requests = E.synthetic_trace(4, cfg.vocab_size, seed=2,
                                 prompt_lens=(2, 4), gen_lens=(3,))
    plain = E.ContinuousBatchingEngine(params, qlayers, cfg, n_slots=2,
                                       chunk=2)
    plain.submit_all(requests)
    sharded = E.ContinuousBatchingEngine(params, qlayers, cfg, n_slots=2,
                                         chunk=2, mesh=mesh, rules=rules)
    sharded.submit_all(list(requests))
    rp, _ = plain.run()
    rs, _ = sharded.run()
    assert {k: v.tokens for k, v in rp.items()} == \
        {k: v.tokens for k, v in rs.items()}


# ---------------------------------------------------------------------------
# Chunked prefill: bit-exactness, TTFT metrics, truncation bookkeeping
# ---------------------------------------------------------------------------


def _run_engine(qlm, requests, *, chunk, n_slots=3, max_steps=None):
    params, qlayers, cfg = qlm
    eng = E.ContinuousBatchingEngine(params, qlayers, cfg, n_slots=n_slots,
                                     chunk=chunk)
    # fresh Request objects: engines mutate nothing, but keep inputs isolated
    eng.submit_all([E.Request(rid=r.rid, prompt=r.prompt,
                              max_new_tokens=r.max_new_tokens)
                    for r in requests])
    return eng.run(max_steps=max_steps)


def test_chunked_prefill_bitexact(qlm):
    """Chunk sizes 2 and 4 must emit bit-identical tokens to chunk=1 and to
    decoding each stream alone -- prompts shorter than, equal to, and longer
    than (and not divisible by) the chunk, plus a mid-generation co-tenant,
    all advance correctly in shared steps."""
    params, qlayers, cfg = qlm
    rng = np.random.default_rng(13)
    requests = [
        E.Request(rid=i,
                  prompt=rng.integers(0, cfg.vocab_size, size=(p,)),
                  max_new_tokens=g)
        for i, (p, g) in enumerate(
            [(1, 3), (3, 2), (4, 2), (5, 4), (9, 2), (2, 3)])
    ]
    outs = {}
    for k in (1, 2, 4):
        results, stats = _run_engine(qlm, requests, chunk=k)
        assert stats.chunk == k
        outs[k] = {rid: r.tokens for rid, r in results.items()}
    assert outs[1] == outs[2] == outs[4]
    ref = _reference(params, qlayers, cfg, requests)
    for r in requests:
        assert outs[4][r.rid] == ref[r.rid], f"stream {r.rid} drifted"


def test_chunked_prefill_cuts_ttft_on_prompt_heavy(qlm):
    """Long prompts (>= 16 tokens): chunk=4 must finish prefill in ~P/4
    steps, so total steps and mean TTFT-in-steps drop >= 2x vs chunk=1
    (deterministic -- step counts don't depend on wall clock)."""
    params, qlayers, cfg = qlm
    rng = np.random.default_rng(5)
    requests = [
        E.Request(rid=i,
                  prompt=rng.integers(0, cfg.vocab_size, size=(p,)),
                  max_new_tokens=2)
        for i, p in enumerate([16, 17, 16])
    ]
    _, s1 = _run_engine(qlm, requests, chunk=1)
    _, s4 = _run_engine(qlm, requests, chunk=4)
    assert s4.steps < s1.steps
    assert s1.mean_ttft_steps >= 2 * s4.mean_ttft_steps
    # K=1: TTFT in steps for an immediately-admitted stream is exactly its
    # prompt length (one teacher-forced token per step, first generated
    # token on the step that consumes the last prompt token)
    assert s1.mean_ttft_steps == np.mean([16, 17, 16])


def test_ttft_and_stream_rate_metrics(qlm):
    """Request-level latency bookkeeping: an immediately-admitted stream's
    ttft_steps equals its prompt length at chunk=1, wall-clock fields are
    populated and positive, and stats aggregate them."""
    params, qlayers, cfg = qlm
    rng = np.random.default_rng(9)
    requests = [
        E.Request(rid=i,
                  prompt=rng.integers(0, cfg.vocab_size, size=(p,)),
                  max_new_tokens=3)
        for i, p in enumerate([2, 4, 5])
    ]
    results, stats = _run_engine(qlm, requests, chunk=1)
    for r in requests:
        res = results[r.rid]
        assert res.ttft_steps == r.prompt.size  # admitted at step 0
        assert res.ttft_s is not None and res.ttft_s > 0
        assert res.tokens_per_s is not None and res.tokens_per_s > 0
    assert stats.mean_ttft_steps == np.mean([2, 4, 5])
    assert stats.mean_ttft_s > 0
    assert stats.mean_stream_tokens_per_s > 0


def test_truncation_finished_step_matches_last_ran_step(qlm):
    """max_steps regression: a truncated stream's finished_step must be the
    step that actually ran last (stats.steps - 1), the same stamp a stream
    evicted on that step would get -- not one past it."""
    params, qlayers, cfg = qlm
    rng = np.random.default_rng(3)
    requests = [
        E.Request(rid=i,
                  prompt=rng.integers(0, cfg.vocab_size, size=(2,)),
                  max_new_tokens=8)
        for i in range(3)
    ]
    results, stats = _run_engine(qlm, requests, chunk=1, max_steps=4)
    assert stats.steps == 4
    assert results, "nothing truncated -- workload too short for the test"
    for res in results.values():
        assert res.truncated
        assert res.finished_step == stats.steps - 1
        # partial output: prompt of 2 consumed in 2 steps, tokens on steps
        # 1..3 -> 3 generated of the 8 budgeted
        assert len(res.tokens) == 3
        assert res.ttft_steps == 2


def test_request_and_engine_validation_raises(qlm):
    """Invariants must raise ValueError (not assert, which python -O
    strips): empty prompts, non-positive budgets, bad slot/chunk counts."""
    params, qlayers, cfg = qlm
    with pytest.raises(ValueError, match="empty prompt"):
        E.Request(rid=0, prompt=np.zeros((0,), np.int32), max_new_tokens=1)
    with pytest.raises(ValueError, match="max_new_tokens"):
        E.Request(rid=0, prompt=np.array([1]), max_new_tokens=0)
    with pytest.raises(ValueError, match="n_slots"):
        E.ContinuousBatchingEngine(params, qlayers, cfg, n_slots=0)
    with pytest.raises(ValueError, match="chunk"):
        E.ContinuousBatchingEngine(params, qlayers, cfg, n_slots=1, chunk=0)


def test_load_trace_validates_entries(tmp_path, qlm):
    """Malformed trace entries fail loudly with the entry index, instead of
    KeyError/empty-prompt crashes deep inside the engine."""
    import json

    _, _, cfg = qlm

    def write(payload):
        p = tmp_path / "bad.json"
        p.write_text(json.dumps(payload))
        return str(p)

    cases = [
        ({"not": "a list"}, "expected a JSON list"),
        (["nope"], "entry 0"),
        ([{"prompt_len": 4}], "missing 'gen'"),
        ([{"prompt_len": 4, "gen": 0}], "'gen' must be >= 1"),
        ([{"prompt": [], "gen": 2}], "'prompt' is empty"),
        ([{"prompt_len": 0, "gen": 2}], "'prompt_len' must be >= 1"),
        ([{"gen": 2}], "needs 'prompt' or 'prompt_len'"),
        ([{"prompt_len": 2, "gen": 1}, {"gen": 1}], "entry 1"),
    ]
    for payload, match in cases:
        with pytest.raises(ValueError, match=match):
            E.load_trace(write(payload), cfg.vocab_size)


# ---------------------------------------------------------------------------
# Property test: random workloads + admission orders (hypothesis optional)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:  # the rest of the module must still run without it
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    _WORKLOAD = st.lists(
        st.tuples(st.integers(1, 4), st.integers(1, 4)),  # (prompt_len, gen)
        min_size=1, max_size=6,
    )

    @settings(max_examples=6, deadline=None)
    @given(workload=_WORKLOAD, seed=st.integers(0, 2**16),
           order_seed=st.integers(0, 2**16))
    def test_property_engine_equals_single_stream(qlm, workload, seed,
                                                  order_seed):
        """For random prompt lengths, gen budgets and admission orders,
        every stream's engine tokens are bit-identical to decoding it alone
        (slots fixed at 3 so the jitted step is compiled once per
        module)."""
        params, qlayers, cfg = qlm
        rng = np.random.default_rng(seed)
        requests = [
            E.Request(rid=i,
                      prompt=rng.integers(0, cfg.vocab_size, size=(p,)),
                      max_new_tokens=g)
            for i, (p, g) in enumerate(workload)
        ]
        order = np.random.default_rng(order_seed).permutation(len(requests))
        eng = E.ContinuousBatchingEngine(params, qlayers, cfg, n_slots=3)
        eng.submit_all([requests[i] for i in order])
        results, _ = eng.run()
        for r in requests:
            ref = E.decode_single(params, qlayers, cfg, r.prompt,
                                  r.max_new_tokens)
            assert results[r.rid].tokens == ref, f"stream {r.rid} drifted"

    @settings(max_examples=5, deadline=None)
    @given(workload=_WORKLOAD, chunk=st.integers(1, 8),
           seed=st.integers(0, 2**16), order_seed=st.integers(0, 2**16))
    def test_property_chunked_prefill_bitexact(qlm, workload, chunk, seed,
                                               order_seed):
        """For random chunk sizes K in {1..8}, workloads and admission
        orders, the chunked engine's per-stream tokens are bit-identical to
        the K=1 engine AND to decoding each stream alone (slots fixed at 3
        so chunk programs compile once per distinct K)."""
        params, qlayers, cfg = qlm
        rng = np.random.default_rng(seed)
        requests = [
            E.Request(rid=i,
                      prompt=rng.integers(0, cfg.vocab_size, size=(p,)),
                      max_new_tokens=g)
            for i, (p, g) in enumerate(workload)
        ]
        order = np.random.default_rng(order_seed).permutation(len(requests))
        outs = {}
        for k in sorted({1, chunk}):
            eng = E.ContinuousBatchingEngine(params, qlayers, cfg,
                                             n_slots=3, chunk=k)
            eng.submit_all([requests[i] for i in order])
            results, _ = eng.run()
            outs[k] = {rid: res.tokens for rid, res in results.items()}
        assert outs[1] == outs[chunk]
        for r in requests:
            ref = E.decode_single(params, qlayers, cfg, r.prompt,
                                  r.max_new_tokens)
            assert outs[chunk][r.rid] == ref, f"stream {r.rid} drifted"
