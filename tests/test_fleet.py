"""Fleet tier: admission routing, fault injection, shard-kill recovery.

THE acceptance property (ISSUE 9): with a seeded ``FaultInjector`` killing
1 of 2 shards mid-flight, every stream -- including the ones re-admitted to
the survivor with migrated state or a replayed prefix -- completes
bit-identical to ``decode_single`` of its original request.  That is the
paper's integer-state compactness cashing in as recovery correctness: the
state is a few hundred host bytes, slices/stacks losslessly, and integer
math re-rounds nothing on the way back in.

Multi-device placement (disjoint per-shard meshes under
``--xla_force_host_platform_device_count``) is exercised in a subprocess
(not marked fast): XLA_FLAGS must be set before jax initializes.
"""
import jax
import numpy as np
import pytest

from repro.configs.registry import SMOKE_CONFIGS
from repro.launch import engine as E
from repro.launch import fleet as F
from repro.models import lstm_lm, model_zoo
from repro.runtime import sharding as shlib
from repro.runtime.fault import StepWatchdog

pytestmark = pytest.mark.fast


@pytest.fixture(scope="module")
def qlm():
    cfg = SMOKE_CONFIGS["lstm-rnnt"]
    bundle = model_zoo.build(cfg)
    params, _ = bundle.init(jax.random.PRNGKey(0))
    calib = jax.random.randint(jax.random.PRNGKey(2), (4, 8), 0,
                               cfg.vocab_size)
    qlayers = lstm_lm.quantize_stack(params, cfg, calib)
    return params, qlayers, cfg


def _requests(cfg, spec, *, arrivals=None):
    rng = np.random.default_rng(7)
    out = []
    for i, (p, g) in enumerate(spec):
        out.append(E.Request(
            rid=i, prompt=rng.integers(0, cfg.vocab_size, size=(p,)),
            max_new_tokens=g,
            arrival=float(arrivals[i]) if arrivals else 0.0))
    return out


def _reference(qlm, requests):
    params, qlayers, cfg = qlm
    return {r.rid: E.decode_single(params, qlayers, cfg, r.prompt,
                                   r.max_new_tokens) for r in requests}


# ---------------------------------------------------------------------------
# FaultInjector determinism (no model needed)
# ---------------------------------------------------------------------------


def test_killspec_validates_trigger():
    with pytest.raises(ValueError):
        F.KillSpec(shard=0)  # neither trigger
    with pytest.raises(ValueError):
        F.KillSpec(shard=0, at_step=3, at_frac=0.5)  # both
    with pytest.raises(ValueError):
        F.KillSpec(shard=0, at_frac=1.5)


def test_kills_fire_exactly_once():
    inj = F.FaultInjector(kills=[dict(shard=0, at_step=5),
                                 dict(shard=1, at_frac=0.5)])
    assert inj.kills_due(4, 0.0) == []
    due = inj.kills_due(5, 0.0)
    assert [k.shard for k in due] == [0]
    assert inj.kills_due(6, 0.4) == []  # step kill consumed, frac not due
    assert [k.shard for k in inj.kills_due(7, 0.6)] == [1]
    assert inj.kills_due(8, 1.0) == []


def test_admission_failures_deterministic():
    inj = F.FaultInjector(seed=3, admission_fails={4: 2},
                          admission_fail_rate=0.3)
    # explicit schedule: first 2 attempts of rid 4 fail, then the rate draw
    assert inj.admission_fails_for(4, 0) and inj.admission_fails_for(4, 1)
    # rate-based draws are a pure function of (seed, rid, attempt)
    twin = F.FaultInjector(seed=3, admission_fail_rate=0.3)
    for rid in range(20):
        for attempt in range(3):
            assert (inj.admission_fails_for(rid + 100, attempt)
                    == twin.admission_fails_for(rid + 100, attempt))
    other = F.FaultInjector(seed=4, admission_fail_rate=0.3)
    draws = [(rid, a) for rid in range(40) for a in range(3)]
    assert any(twin.admission_fails_for(r, a) != other.admission_fails_for(r, a)
               for r, a in draws), "different seeds never diverged"


def test_from_spec_rejects_unknown_keys():
    with pytest.raises(ValueError):
        F.FaultInjector.from_spec({"kils": []})
    inj = F.FaultInjector.from_spec(
        {"seed": 1, "kills": [{"shard": 0, "at_frac": 0.5}],
         "admission_fails": {"7": 2}})
    assert inj.kills[0].at_frac == 0.5
    assert inj.admission_fails == {7: 2}


def test_hook_only_for_targeted_shards():
    inj = F.FaultInjector(hangs=[dict(shard=1, at_step=2, sleep_s=0.0)])
    assert inj.hook_for(0) is None
    assert inj.hook_for(1) is not None


# ---------------------------------------------------------------------------
# Router placement helpers
# ---------------------------------------------------------------------------


def test_fleet_device_groups_partition():
    devs = list(range(8))  # the helper only len()s and slices
    groups = shlib.fleet_device_groups(3, devices=devs)
    assert groups == [[0, 1], [2, 3], [4, 5]]  # disjoint, equal, leftovers
    assert shlib.fleet_device_groups(9, devices=devs) is None
    with pytest.raises(ValueError):
        shlib.fleet_device_groups(0, devices=devs)


def test_fleet_meshes_degrade_without_devices():
    meshes = shlib.fleet_meshes(4)  # single test device -> co-located mode
    if len(jax.devices()) < 4:
        assert meshes == [None] * 4


# ---------------------------------------------------------------------------
# Engine-level satellites: watchdog surfacing, export/adopt, duplicate rids
# ---------------------------------------------------------------------------


def test_engine_watchdog_flags_injected_hang(qlm):
    params, qlayers, cfg = qlm
    reqs = _requests(cfg, [(2, 8), (3, 8)])
    # warm the compiled programs so the watchdog EMA seeds on a real step
    warm = E.ContinuousBatchingEngine(params, qlayers, cfg, n_slots=2)
    warm.submit(E.Request(rid=0, prompt=np.zeros(2, np.int32),
                          max_new_tokens=2))
    warm.run()

    hung_at = []

    def hook(step):
        if step == 3:
            hung_at.append(step)
            import time
            time.sleep(0.3)

    wd = StepWatchdog()
    eng = E.ContinuousBatchingEngine(params, qlayers, cfg, n_slots=2,
                                     watchdog=wd, step_hook=hook)
    eng.submit_all(reqs)
    results, stats = eng.run()
    assert hung_at == [3]
    assert stats.hung >= 1  # the injected sleep read as a hung device
    assert wd.hung >= 1 and wd.last_verdict in ("ok", "straggler", "hung")
    ref = _reference(qlm, reqs)
    for r in reqs:  # a hang slows the step; it must not corrupt it
        assert results[r.rid].tokens == ref[r.rid]


def test_export_adopt_roundtrip_bitexact(qlm):
    """Drain a half-done engine and adopt its streams into a fresh one:
    the continuation must be bit-exact (the migration primitive the fleet
    router builds recovery on)."""
    params, qlayers, cfg = qlm
    reqs = _requests(cfg, [(2, 9), (3, 7), (5, 5)])
    ref = _reference(qlm, reqs)
    src = E.ContinuousBatchingEngine(params, qlayers, cfg, n_slots=2,
                                     oversubscribe=2.0, policy="srf")
    src.submit_all(reqs)
    partial, _ = src.run(max_steps=6, keep_live=True)
    exported = src.export_streams(device_alive=True)
    assert src.live == 0 and src.pending == 0
    dst = E.ContinuousBatchingEngine(params, qlayers, cfg, n_slots=2)
    done = dict(partial)
    for ms in exported:
        if ms.pending:
            dst.submit(ms.request)
        else:
            dst.adopt_stream(ms.request, state_row=ms.state_row,
                             fed=ms.fed, generated=ms.generated,
                             drafter=ms.drafter)
    results, _ = dst.run()
    done.update(results)
    for r in reqs:
        assert done[r.rid].tokens == ref[r.rid], f"stream {r.rid} drifted"


def test_adopt_rejects_bad_input(qlm):
    params, qlayers, cfg = qlm
    eng = E.ContinuousBatchingEngine(params, qlayers, cfg, n_slots=2)
    req = E.Request(rid=1, prompt=np.zeros(3, np.int32), max_new_tokens=4)
    with pytest.raises(ValueError, match="state row"):
        eng.adopt_stream(req, state_row=None, fed=2)
    row = jax.device_get(lstm_lm.slice_state(
        lstm_lm.init_quant_decode_state(qlayers, 2, per_slot_len=True), 0))
    with pytest.raises(ValueError, match="nothing to adopt"):
        eng.adopt_stream(req, state_row=row, fed=3, generated=[1, 2, 3, 4])
    with pytest.raises(ValueError, match="inconsistent"):
        eng.adopt_stream(req, state_row=row, fed=9, generated=[1])


def test_duplicate_rid_rejected_everywhere(qlm):
    params, qlayers, cfg = qlm
    req = E.Request(rid=5, prompt=np.zeros(2, np.int32), max_new_tokens=2)
    dup = E.Request(rid=5, prompt=np.ones(3, np.int32), max_new_tokens=3)
    eng = E.ContinuousBatchingEngine(params, qlayers, cfg, n_slots=2)
    eng.submit(req)
    with pytest.raises(ValueError, match="duplicate"):
        eng.submit(dup)
    row = jax.device_get(lstm_lm.slice_state(
        lstm_lm.init_quant_decode_state(qlayers, 2, per_slot_len=True), 0))
    with pytest.raises(ValueError, match="duplicate"):
        eng.adopt_stream(dup, state_row=row, fed=1)
    router = F.FleetRouter(params, qlayers, cfg, n_shards=1,
                           slots_per_shard=2)
    router.submit(E.Request(rid=5, prompt=np.zeros(2, np.int32),
                            max_new_tokens=2))
    with pytest.raises(ValueError, match="duplicate"):
        router.submit(dup)
    with pytest.raises(ValueError, match=">= 0"):
        router.submit(E.Request(rid=-3, prompt=np.zeros(2, np.int32),
                                max_new_tokens=2))


# ---------------------------------------------------------------------------
# Router: the acceptance property + fault-plane behaviors
# ---------------------------------------------------------------------------


def test_shard_kill_recovery_bitexact(qlm):
    """ACCEPTANCE: seeded injector hard-kills 1 of 2 shards mid-flight
    while it is oversubscribed; pooled streams migrate with state,
    residents replay their prefix, and EVERY stream completes bit-identical
    to decode_single.

    Workload shape matters: srf only parks a resident in the pool when a
    SHORTER stream arrives later and preempts it, so the first four (long)
    requests land two per shard at step 0 and a short request arrives at
    step 2 on each shard (least-loaded ties break to the lower index) --
    by the step-5 kill, shard 0 deterministically holds both residents
    (device rows die -> replay) and a preempted pooled stream (host pages
    survive -> migrate)."""
    params, qlayers, cfg = qlm
    reqs = _requests(cfg, [(3, 12), (3, 12), (3, 12), (3, 12),
                           (2, 3), (2, 3)],
                     arrivals=[0, 0, 0, 0, 2, 2])
    ref = _reference(qlm, reqs)
    inj = F.FaultInjector(seed=0, kills=[dict(shard=0, at_step=5)])
    router = F.FleetRouter(params, qlayers, cfg, n_shards=2,
                           slots_per_shard=2, oversubscribe=2.0,
                           policy="srf", injector=inj)
    router.warmup()
    router.submit_all(reqs)
    results, stats = router.run()
    assert stats.kills == 1
    assert stats.completed == len(reqs)
    # both recovery paths exercised: the killed shard was oversubscribed
    # (pooled pages survive the device -> migrate) and had residents
    # (device rows died -> replay)
    assert stats.migrated_streams >= 1, "no pooled stream migrated"
    assert stats.replayed_streams >= 1, "no resident stream replayed"
    for r in reqs:
        fr = results[r.rid]
        assert not fr.truncated and not fr.rejected
        assert fr.tokens == ref[r.rid], f"stream {r.rid} drifted"
        assert len(fr.tokens) == r.max_new_tokens


def test_graceful_drain_migrates_everything(qlm):
    params, qlayers, cfg = qlm
    reqs = _requests(cfg, [(2, 9), (3, 7), (5, 6), (2, 8)])
    ref = _reference(qlm, reqs)
    inj = F.FaultInjector(kills=[dict(shard=0, at_step=5, graceful=True)])
    router = F.FleetRouter(params, qlayers, cfg, n_shards=2,
                           slots_per_shard=2, injector=inj)
    router.warmup()
    router.submit_all(reqs)
    results, stats = router.run()
    assert stats.replayed_streams == 0  # graceful: nothing re-ingests
    assert stats.migrated_streams >= 1
    for r in reqs:
        assert results[r.rid].tokens == ref[r.rid]


def test_kill_with_restart_rejoins_fleet(qlm):
    params, qlayers, cfg = qlm
    reqs = _requests(cfg, [(2, 9), (3, 9), (2, 8), (3, 8), (2, 7), (3, 7)],
                     arrivals=[0, 0, 0, 8, 10, 12])
    ref = _reference(qlm, reqs)
    inj = F.FaultInjector(kills=[dict(shard=0, at_step=4,
                                      restart_after=4)])
    router = F.FleetRouter(params, qlayers, cfg, n_shards=2,
                           slots_per_shard=2, injector=inj)
    router.warmup()
    router.submit_all(reqs)
    results, stats = router.run()
    assert stats.kills == 1 and stats.restarts == 1
    assert stats.shards[0].restarts == 1 and stats.shards[0].alive
    # the restarted shard took real work afterwards
    assert stats.shards[0].generated_tokens > 0
    for r in reqs:
        assert results[r.rid].tokens == ref[r.rid]


def test_hang_verdict_drains_shard(qlm):
    """An injected step hang trips the shard watchdog; on_hang='kill'
    turns the verdict into a graceful drain and the streams finish on the
    survivor, bit-exactly."""
    params, qlayers, cfg = qlm
    reqs = _requests(cfg, [(2, 9), (3, 7), (5, 6), (2, 8)])
    ref = _reference(qlm, reqs)
    inj = F.FaultInjector(hangs=[dict(shard=0, at_step=4, sleep_s=0.3)])
    router = F.FleetRouter(params, qlayers, cfg, n_shards=2,
                           slots_per_shard=2, injector=inj,
                           on_hang="kill")
    router.warmup()  # EMA must seed from post-compile steps
    router.submit_all(reqs)
    results, stats = router.run()
    assert stats.hang_events >= 1
    assert stats.kills >= 1
    assert not stats.shards[0].alive
    for r in reqs:
        assert results[r.rid].tokens == ref[r.rid]


def test_admission_retry_backoff_and_exhaustion(qlm):
    params, qlayers, cfg = qlm
    reqs = _requests(cfg, [(2, 5), (3, 5), (2, 4)])
    ref = _reference(qlm, reqs)
    inj = F.FaultInjector(admission_fails={0: 2, 1: 99})
    router = F.FleetRouter(params, qlayers, cfg, n_shards=1,
                           slots_per_shard=2, injector=inj,
                           max_admit_attempts=3, backoff_steps=1,
                           backoff_cap_steps=4)
    router.submit_all(reqs)
    results, stats = router.run()
    # rid 0: attempts 0,1 fail transiently, attempt 2 lands
    assert results[0].admit_attempts == 3
    assert results[0].tokens == ref[0]
    # rid 1: budget exhausted -> rejected, no tokens
    assert results[1].rejected and results[1].tokens == []
    assert results[2].tokens == ref[2]
    assert stats.admit_retries >= 2
    assert stats.rejected == 1


def test_saturated_fleet_degrades_to_fifo_reject(qlm):
    params, qlayers, cfg = qlm
    reqs = _requests(cfg, [(2, 8), (2, 8), (2, 8), (2, 8)])
    router = F.FleetRouter(params, qlayers, cfg, n_shards=1,
                           slots_per_shard=1, max_queue=1)
    router.submit_all(reqs)
    results, stats = router.run()
    assert stats.rejected >= 1  # overflow bounced, fifo-reject style
    assert stats.completed >= 1
    served = [r for r in results.values() if not r.rejected]
    ref = _reference(qlm, reqs)
    for fr in served:
        assert fr.tokens == ref[fr.rid]


def test_whole_fleet_death_surfaces_lost_streams(qlm):
    params, qlayers, cfg = qlm
    reqs = _requests(cfg, [(2, 8), (3, 8)])
    inj = F.FaultInjector(kills=[dict(shard=0, at_step=4)])
    router = F.FleetRouter(params, qlayers, cfg, n_shards=1,
                           slots_per_shard=2, injector=inj)
    router.submit_all(reqs)
    results, stats = router.run()
    assert stats.lost == len(reqs)  # no survivor, no restart scheduled
    for r in reqs:
        assert results[r.rid].truncated  # surfaced, not silently dropped
