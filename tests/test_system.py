"""End-to-end behaviour: train -> calibrate -> quantize -> integer serve.

This is the paper's pipeline (sec 4-5) run on a small model: post-training
quantization from a small calibration set must track the float model, and
training must demonstrably learn on the synthetic task.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import SMOKE_CONFIGS
from repro.core import recipe as R
from repro.core.calibrate import Stats, TapCollector
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import lstm as L
from repro.models import model_zoo
from repro.models import quant_lstm as QL
from repro.optim.optimizers import OptConfig
from repro.runtime.train_loop import make_train_step

IDENT = lambda x, logical=None: x


def _train(name, steps=40, lr=3e-3, data_vocab=None):
    cfg = SMOKE_CONFIGS[name]
    bundle = model_zoo.build(cfg)
    data = SyntheticLM(DataConfig(vocab_size=data_vocab or cfg.vocab_size,
                                  seq_len=32, global_batch=8, noise=0.0))
    art = make_train_step(bundle, None, OptConfig(
        lr=lr, warmup_steps=5, total_steps=steps + 20))
    params, _ = bundle.init(jax.random.PRNGKey(0))
    opt = art.init_opt(params)
    losses = []
    for step, batch in data.iterate():
        if step >= steps:
            break
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt, m = art.step_fn(params, opt, batch)
        losses.append(float(m["loss"]))
    return losses


def test_training_reduces_loss_lstm():
    # the tiny smoke LSTM (proj width 20) needs an easier rule: vocab 16
    losses = _train("lstm-rnnt", steps=120, lr=1e-2, data_vocab=16)
    assert losses[-1] < 0.7 * losses[0], (losses[0], losses[-1])


def test_training_reduces_loss_transformer():
    losses = _train("qwen1.5-0.5b", steps=120, lr=1e-2)
    assert losses[-1] < 0.8 * losses[0], (losses[0], losses[-1])


def test_ptq_pipeline_end_to_end():
    """Train float LSTM -> PTQ with a small calibration set -> the integer
    model's task loss matches float within a small margin (paper Table 1)."""
    variant = L.LSTMVariant(use_layernorm=True, use_projection=True)
    cfg = L.LSTMConfig(16, 32, 16, variant)
    params = L.init_lstm_params(jax.random.PRNGKey(0), cfg)

    xs = jax.random.normal(jax.random.PRNGKey(1), (64, 10, 16))
    target = jnp.roll(xs, 1, axis=-1) * 0.5

    def loss_fn(p, x, t):
        ys, _ = L.lstm_layer(p, cfg, x)
        return jnp.mean(jnp.square(ys[..., :16] - t))

    lr = 0.05
    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    for _ in range(60):
        l, g = grad_fn(params, xs, target)
        params = jax.tree_util.tree_map(lambda p, gg: p - lr * gg, params, g)
    float_loss = float(loss_fn(params, xs, target))

    # PTQ on a small calibration subset (paper: 100 utterances suffice)
    col = TapCollector()
    L.lstm_layer(params, cfg, xs[:8], collector=col)
    stats = Stats()
    stats.merge(jax.device_get(col.snapshot()))
    arrays, spec = R.quantize_lstm_layer(params, cfg, stats)
    xs_q = QL.quantize_input(xs, spec.s_x, spec.zp_x)
    ys_q, _ = QL.quant_lstm_layer(arrays, spec, xs_q)
    ys_i = QL.dequantize_output(ys_q, spec.s_h, spec.zp_h_out)
    int_loss = float(jnp.mean(jnp.square(ys_i[..., :16] - target)))
    assert int_loss < float_loss * 1.25 + 2e-3, (float_loss, int_loss)


def test_model_size_reduction():
    """Paper Table 1: the integer model is ~4x smaller than float."""
    variant = L.LSTMVariant(use_layernorm=True, use_projection=True)
    cfg = L.LSTMConfig(64, 128, 64, variant)
    params = L.init_lstm_params(jax.random.PRNGKey(0), cfg)
    col = TapCollector()
    xs = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 64))
    L.lstm_layer(params, cfg, xs, collector=col)
    stats = Stats()
    stats.merge(jax.device_get(col.snapshot()))
    arrays, spec = R.quantize_lstm_layer(params, cfg, stats)

    def nbytes(tree):
        return sum(np.asarray(x).nbytes
                   for x in jax.tree_util.tree_leaves(tree))

    assert nbytes(arrays) < 0.3 * nbytes(params)


def test_recipe_table_dump():
    from repro.core.recipe import recipe_table
    variant = L.LSTMVariant(True, True, True, False)
    cfg = L.LSTMConfig(8, 16, 8, variant)
    params = L.init_lstm_params(jax.random.PRNGKey(0), cfg)
    col = TapCollector()
    xs = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 8))
    L.lstm_layer(params, cfg, xs, collector=col)
    stats = Stats()
    stats.merge(jax.device_get(col.snapshot()))
    _, spec = R.quantize_lstm_layer(params, cfg, stats)
    table = recipe_table(spec)
    assert "c" in table and "Q" in table["c"]  # POT cell format row
    assert all(f"gate_{g}" in table for g in ("i", "f", "z", "o"))
