"""runtime/fault.py: watchdog verdicts/EMA + hardened restart driver.

The watchdog's contract: first observation seeds the EMA silently, later
observations classify against ``straggler_factor`` / ``timeout_factor``
times the EMA and keep counting.  ``run_with_restarts``'s contract: only
allowlisted exceptions restart (anything else propagates immediately),
restarts back off exponentially with a cap (injectable sleep -- asserted
on the exact pause sequence), and ANY failure schedule within
``max_restarts`` completes (hypothesis property).
"""
import pytest

from repro.runtime.fault import (RESTARTABLE_EXCEPTIONS, StepWatchdog,
                                 run_with_restarts)

pytestmark = pytest.mark.fast

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# StepWatchdog
# ---------------------------------------------------------------------------


def test_watchdog_first_observation_seeds_silently():
    wd = StepWatchdog()
    assert wd.observe(10.0) == "ok"  # no EMA yet -> nothing to compare
    assert wd.ema_s == 10.0
    assert wd.stragglers == 0 and wd.hung == 0 and wd.steps == 1


def test_watchdog_verdicts_and_counters():
    wd = StepWatchdog(timeout_factor=10.0, straggler_factor=2.0, ema=0.9)
    wd.observe(1.0)  # seed
    assert wd.observe(1.5) == "ok"
    assert wd.observe(3.0) == "straggler"  # > 2x EMA, < 10x
    assert wd.observe(100.0) == "hung"  # > 10x EMA
    assert wd.stragglers == 1 and wd.hung == 1
    assert wd.last_verdict == "hung"
    assert wd.steps == 4


def test_watchdog_ema_update_rule():
    wd = StepWatchdog(ema=0.9)
    wd.observe(1.0)
    wd.observe(2.0)
    assert wd.ema_s == pytest.approx(0.9 * 1.0 + 0.1 * 2.0)


def test_watchdog_hung_step_still_updates_ema():
    """A genuinely slower regime must stop alarming once the EMA catches
    up -- the hung observation feeds the EMA like any other."""
    wd = StepWatchdog(ema=0.5)
    wd.observe(0.01)
    assert wd.observe(1.0) == "hung"
    assert wd.ema_s == pytest.approx(0.5 * 0.01 + 0.5 * 1.0)
    # same wall time again: EMA has moved, verdict relaxes
    assert wd.observe(1.0) != "hung"


def test_watchdog_validates_factors():
    with pytest.raises(ValueError):
        StepWatchdog(timeout_factor=2.0, straggler_factor=2.0)
    with pytest.raises(ValueError):
        StepWatchdog(ema=1.0)
    with pytest.raises(ValueError):
        StepWatchdog(ema=-0.1)


# ---------------------------------------------------------------------------
# run_with_restarts
# ---------------------------------------------------------------------------


class _Trainer:
    """Checkpoints every step; fails (with ``exc``) at the step indices in
    ``fail_at`` -- each index fires once."""

    def __init__(self, fail_at, exc=RuntimeError):
        self.fail_at = set(fail_at)
        self.exc = exc
        self.ckpt = None
        self.calls = 0

    def latest(self):
        return self.ckpt

    def chunk(self, start):
        self.calls += 1
        for step in range(start, start + 100):
            if step in self.fail_at:
                self.fail_at.remove(step)
                raise self.exc(f"injected at {step}")
            self.ckpt = step + 1
        return self.ckpt


def test_restarts_recover_and_count():
    tr = _Trainer(fail_at=[5, 105])
    stats = run_with_restarts(tr.chunk, ckpt_latest=tr.latest,
                              total_steps=150, backoff_s=0.0)
    assert stats.restarts == 2
    assert stats.completed_steps >= 150


def test_backoff_sequence_is_capped_exponential():
    pauses = []
    tr = _Trainer(fail_at=[1, 2, 3, 4, 5, 6])
    stats = run_with_restarts(
        tr.chunk, ckpt_latest=tr.latest, total_steps=10,
        max_restarts=10, backoff_s=0.1, backoff_cap_s=1.0,
        sleep=pauses.append)
    # restart n sleeps min(0.1 * 2**(n-1), 1.0)
    assert pauses == pytest.approx([0.1, 0.2, 0.4, 0.8, 1.0, 1.0])
    assert stats.backoff_s_total == pytest.approx(sum(pauses))


def test_non_allowlisted_exception_propagates_immediately():
    tr = _Trainer(fail_at=[3], exc=ValueError)
    with pytest.raises(ValueError):
        run_with_restarts(tr.chunk, ckpt_latest=tr.latest, total_steps=10,
                          backoff_s=0.0)
    assert tr.calls == 1  # no retry burned on a deterministic failure


def test_custom_allowlist_overrides_default():
    tr = _Trainer(fail_at=[3], exc=KeyError)
    stats = run_with_restarts(tr.chunk, ckpt_latest=tr.latest,
                              total_steps=10, restart_on=(KeyError,),
                              backoff_s=0.0)
    assert stats.restarts == 1


def test_default_allowlist_covers_infra_failures():
    for exc in RESTARTABLE_EXCEPTIONS:
        tr = _Trainer(fail_at=[2], exc=exc)
        stats = run_with_restarts(tr.chunk, ckpt_latest=tr.latest,
                                  total_steps=5, backoff_s=0.0)
        assert stats.restarts == 1, exc


def test_max_restarts_exceeded_reraises():
    tr = _Trainer(fail_at=[1, 2, 3])
    with pytest.raises(RuntimeError):
        run_with_restarts(tr.chunk, ckpt_latest=tr.latest, total_steps=10,
                          max_restarts=2, backoff_s=0.0)


def test_param_validation():
    tr = _Trainer(fail_at=[])
    with pytest.raises(ValueError):
        run_with_restarts(tr.chunk, ckpt_latest=tr.latest, total_steps=5,
                          max_restarts=-1)
    with pytest.raises(ValueError):
        run_with_restarts(tr.chunk, ckpt_latest=tr.latest, total_steps=5,
                          backoff_s=-0.1)


if HAVE_HYPOTHESIS:

    @settings(max_examples=40, deadline=None)
    @given(fail_at=st.sets(st.integers(min_value=0, max_value=299),
                           max_size=8),
           total=st.integers(min_value=1, max_value=300))
    def test_any_failure_schedule_within_budget_completes(fail_at, total):
        """Property: for ANY schedule of <= max_restarts transient
        failures, the driver reaches total_steps and never loses
        checkpointed work (checkpoint progress is monotone: a failure at
        step s restarts from a checkpoint >= the last one, never
        earlier)."""
        tr = _Trainer(fail_at=fail_at)
        stats = run_with_restarts(tr.chunk, ckpt_latest=tr.latest,
                                  total_steps=total, max_restarts=8,
                                  backoff_s=0.0)
        assert (tr.ckpt or 0) >= total  # the training goal was reached
        # every failure scheduled before the goal must have actually fired
        assert not any(f < total for f in tr.fail_at)
        assert stats.restarts <= 8
