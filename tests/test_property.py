"""Hypothesis property tests on system-wide quantization invariants."""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="install the [test] extra")
from hypothesis import given, settings, strategies as st

from repro.core import fixedpoint as fp
from repro.core import qtypes as qt


@settings(max_examples=100, deadline=None)
@given(st.lists(st.floats(-100, 100, allow_nan=False), min_size=2, max_size=64),
       st.sampled_from([8, 16]))
def test_quant_dequant_error_bound(values, bits):
    """|x - dequant(quant(x))| <= scale/2 inside the clamp range."""
    x = np.asarray(values, np.float32)
    q = qt.quantize_asymmetric(x, bits)
    back = np.asarray(q.dequantize())
    scale = q.spec.scale
    inside = (x >= (q.spec.qmin - q.spec.zero_point) * scale) & (
        x <= (q.spec.qmax - q.spec.zero_point) * scale)
    assert np.abs(back - x)[inside].max(initial=0) <= scale / 2 + 1e-6


@settings(max_examples=100, deadline=None)
@given(st.lists(st.floats(-50, 50, allow_nan=False), min_size=2, max_size=64))
def test_zero_point_nudging_exact_zero(values):
    """Paper sec 3.2.4: float 0.0 must map exactly to an integer."""
    x = np.asarray(values, np.float32)
    q = qt.quantize_asymmetric(x, 8)
    zero_q = round(0.0 / q.spec.scale) + q.spec.zero_point
    assert float((zero_q - q.spec.zero_point) * q.spec.scale) == 0.0


@settings(max_examples=100, deadline=None)
@given(st.floats(1e-4, 1e4))
def test_pot_scale_is_power_of_two(max_abs):
    s = qt.pot_scale_for(max_abs, 16)
    m = np.log2(s)
    assert abs(m - round(m)) < 1e-9
    assert s * 32768 >= max_abs  # POT extension covers the range


@settings(max_examples=200, deadline=None)
@given(st.integers(-(2**31), 2**31 - 1), st.integers(-(2**31), 2**31 - 1))
def test_srdhm_symmetry_and_range(a, b):
    r1 = int(fp.saturating_rounding_doubling_high_mul(jnp.int32(a), jnp.int32(b)))
    r2 = int(fp.saturating_rounding_doubling_high_mul(jnp.int32(b), jnp.int32(a)))
    assert r1 == r2  # commutative
    assert -(2**31) <= r1 <= 2**31 - 1
    if a >= 0 and b >= 0:
        assert r1 >= 0


@settings(max_examples=100, deadline=None)
@given(st.integers(-(2**24), 2**24), st.integers(-(2**24), 2**24),
       st.floats(1e-5, 10.0))
def test_rescale_monotonic(x, y, scale):
    """Requantization preserves order (no inversion artifacts)."""
    m0, s = fp.quantize_multiplier(scale)
    rx = int(fp.multiply_by_quantized_multiplier(jnp.int32(x), m0, s))
    ry = int(fp.multiply_by_quantized_multiplier(jnp.int32(y), m0, s))
    if x <= y:
        assert rx <= ry


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 2**15))
def test_tanh_odd_symmetry(x):
    t1 = int(fp.tanh_q15(jnp.int16(min(x, 32767)), 3))
    t2 = int(fp.tanh_q15(jnp.int16(-min(x, 32767)), 3))
    assert abs(t1 + t2) <= 1  # odd function within 1 LSB


@settings(max_examples=50, deadline=None)
@given(st.integers(-(2**15), 2**15 - 1))
def test_sigmoid_complement(x):
    """sigmoid(x) + sigmoid(-x) == 1 within 1 LSB (paper's CIFG identity)."""
    s1 = int(fp.sigmoid_q15(jnp.int16(x), 3))
    s2 = int(fp.sigmoid_q15(jnp.int16(max(-x - 1, -32768) + (1 if x < 0 else 0)
                                      if False else max(min(-x, 32767), -32768)), 3))
    assert abs((s1 + s2) - 32768) <= 2


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 12))
def test_activation_outputs_bounded(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(-32768, 32767, 512).astype(np.int16))
    t = np.asarray(fp.tanh_q15(x, 3), np.int32)
    s = np.asarray(fp.sigmoid_q15(x, 3), np.int32)
    # paper 3.2.1: outputs clamped to [-1, 32767/32768] / [0, 32767/32768]
    assert t.min() >= -32768 and t.max() <= 32767
    assert s.min() >= 0 and s.max() <= 32767
