"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import fixedpoint as fp
from repro.kernels import ops, ref

pytestmark = pytest.mark.fast


@pytest.mark.parametrize("shape", [(128, 128, 128), (256, 384, 128),
                                   (64, 64, 512), (8, 128, 256)])
@pytest.mark.parametrize("out_dtype", [jnp.int8, jnp.int16, jnp.int32])
def test_int8_matmul_kernel(shape, out_dtype):
    M, K, N = shape
    rng = np.random.default_rng(M + K + N)
    x = rng.integers(-128, 127, (M, K)).astype(np.int8)
    w = rng.integers(-127, 127, (K, N)).astype(np.int8)
    fold = rng.integers(-10000, 10000, N).astype(np.int32)
    m0v, shv = fp.quantize_multiplier(4.1e-4)
    m0 = np.full(N, m0v, np.int32)
    sh = np.full(N, shv, np.int32)
    kw = dict(out_dtype=out_dtype, zp_out=0 if out_dtype == jnp.int32 else 5)
    a = ops.int8_matmul(jnp.array(x), jnp.array(w), jnp.array(fold),
                        jnp.array(m0), jnp.array(sh),
                        backend="pallas_interpret",
                        block_m=64, block_n=64, block_k=64, **kw)
    b = ops.int8_matmul(jnp.array(x), jnp.array(w), jnp.array(fold),
                        jnp.array(m0), jnp.array(sh), backend="xla", **kw)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_int8_matmul_int32_exact_vs_numpy():
    rng = np.random.default_rng(7)
    x = rng.integers(-128, 127, (64, 256)).astype(np.int8)
    w = rng.integers(-127, 127, (256, 64)).astype(np.int8)
    fold = rng.integers(-5000, 5000, 64).astype(np.int32)
    z = np.zeros(64, np.int32)
    got = ops.int8_matmul(jnp.array(x), jnp.array(w), jnp.array(fold),
                          jnp.array(z), jnp.array(z),
                          out_dtype=jnp.int32, backend="pallas_interpret",
                          block_m=32, block_n=32, block_k=64)
    np.testing.assert_array_equal(np.asarray(got, np.int64),
                                  ref.int8_matmul_np(x, w, fold))


@pytest.mark.parametrize("B,H", [(8, 256), (16, 1024), (4, 2048)])
@pytest.mark.parametrize("cifg", [False, True])
@pytest.mark.parametrize("m_c", [0, 2, 4])
def test_quant_lstm_cell_kernel(B, H, cifg, m_c):
    rng = np.random.default_rng(B * H + m_c)
    g = lambda: jnp.asarray(
        rng.integers(-32768, 32767, (B, H)).astype(np.int16))
    i16, f16, z16, o16 = g(), g(), g(), g()
    cq = jnp.asarray(rng.integers(-20000, 20000, (B, H)).astype(np.int16))
    kw = dict(cell_int_bits=m_c, cifg=cifg,
              eff_m=fp.quantize_multiplier(2.0**-30 / 0.005), zp_m=-4)
    h1, c1 = ops.quant_lstm_cell(i16, f16, z16, o16, cq,
                                 backend="pallas_interpret",
                                 block_b=4, block_h=128, **kw)
    h2, c2 = ops.quant_lstm_cell(i16, f16, z16, o16, cq, backend="xla", **kw)
    np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))


@pytest.mark.parametrize("B,n", [(8, 512), (32, 2048), (16, 1024)])
def test_int_layernorm_kernel(B, n):
    rng = np.random.default_rng(B + n)
    q = jnp.asarray(rng.integers(-32768, 32767, (B, n)).astype(np.int16))
    lw = jnp.asarray(rng.integers(100, 32767, n).astype(np.int16))
    lb = jnp.asarray(rng.integers(-100000, 100000, n).astype(np.int32))
    m0, sh = fp.quantize_multiplier(2**-10 * 3e-5 / 2**-12)
    a = ops.int_layernorm(q, lw, lb, out_m0=m0, out_shift=sh,
                          backend="pallas_interpret", block_rows=4)
    b = ops.int_layernorm(q, lw, lb, out_m0=m0, out_shift=sh, backend="xla")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_backend_dispatch():
    prev = ops.get_backend()
    try:
        ops.set_backend("xla")
        assert ops.get_backend() == "xla"
        # plain ValueError, not assert: must survive `python -O`
        with pytest.raises(ValueError, match="valid backends"):
            ops.set_backend("cuda")
    finally:
        # restore the env-selected default (the CI backend matrix relies on
        # REPRO_KERNEL_BACKEND surviving the whole run)
        ops.set_backend(prev)


@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 64)])
def test_flash_attention_pallas_kernel(causal, window):
    from repro.kernels.flash_attention import flash_attention_pallas
    from repro.layers import attention as A

    rng = jax.random.PRNGKey(0)
    BH, S, D = 4, 256, 32
    q = jax.random.normal(jax.random.PRNGKey(1), (BH, S, D), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(2), (BH, S, D), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(3), (BH, S, D), jnp.float32)
    got = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 block_q=64, block_k=64, interpret=True)
    # oracle: the (already-validated) jnp flash path, reshaped to (B,S,H,D)
    ref = A.full_attention(q[:, :, None].swapaxes(1, 2).reshape(BH, S, 1, D),
                           k.reshape(BH, S, 1, D), v.reshape(BH, S, 1, D),
                           causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref[:, :, 0]),
                               rtol=2e-5, atol=2e-5)
