"""Hoisted-GEMM sequence executors + persistent Pallas sequence kernel.

PR-4 acceptance gates:
  * the hoisted executor (ONE time-batched input GEMM outside the scan) is
    bit-exact with the pre-hoist per-step scan (`quant_lstm_seq_stepwise`)
    for all 16 topology variants, on `xla` AND through the persistent
    Pallas sequence kernel (`interpret`);
  * the input GEMM is genuinely hoisted: the scan body of the hoisted
    executor carries ONE fewer dot_general than the stepwise body;
  * `quant_lstm_seq_masked` ragged bit-exactness holds for arbitrary
    valid-length vectors (hypothesis property) on both lowerings;
  * backend-name validation raises `ValueError` (survives `python -O`).
Goldens replay (numerics untouched) is covered by tests/test_golden_lstm.py.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import recipe as R
from repro.core.calibrate import Stats, TapCollector
from repro.kernels import ops
from repro.models import lstm as L
from repro.models import quant_lstm as QL

pytestmark = pytest.mark.fast

B, T, D_IN, D_H, D_P = 4, 6, 16, 24, 12


def _setup(variant, seed=0, b=B, t=T):
    cfg = L.LSTMConfig(D_IN, D_H, D_P if variant.use_projection else 0,
                       variant)
    params = L.init_lstm_params(jax.random.PRNGKey(seed), cfg)
    xs = 0.8 * jax.random.normal(jax.random.PRNGKey(seed + 1), (b, t, D_IN))
    col = TapCollector()
    L.lstm_layer(params, cfg, xs, collector=col)
    stats = Stats()
    stats.merge(jax.device_get(col.snapshot()))
    arrays, spec = R.quantize_lstm_layer(params, cfg, stats)
    return QL.quantize_input(xs, spec.s_x, spec.zp_x), arrays, spec


def _state(spec, b=B):
    d_out = spec.cfg_d_proj if spec.use_projection else spec.cfg_d_hidden
    h0 = jnp.full((b, d_out), spec.zp_h_out, jnp.int8)
    c0 = jnp.zeros((b, spec.cfg_d_hidden), jnp.int16)
    return h0, c0


@pytest.mark.parametrize("variant", L.ALL_VARIANTS, ids=lambda v: v.name)
def test_hoisted_matches_stepwise_and_kernel_all_variants(variant):
    """stepwise/xla == hoisted/xla == persistent-kernel/interpret, bit for
    bit, including the final (h, c) carries (the PR-4 acceptance gate)."""
    xs_q, arrays, spec = _setup(variant)
    h0, c0 = _state(spec)
    y_s, (h_s, c_s) = ops.quant_lstm_seq_stepwise(
        arrays, spec, xs_q, h0, c0, backend="xla")
    y_h, (h_h, c_h) = ops.quant_lstm_seq(
        arrays, spec, xs_q, h0, c0, backend="xla")
    y_k, (h_k, c_k) = ops.quant_lstm_seq(
        arrays, spec, xs_q, h0, c0, backend="interpret")
    for got, want in ((y_h, y_s), (h_h, h_s), (c_h, c_s),
                      (y_k, y_s), (h_k, h_s), (c_k, c_s)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def _count_dot_generals(jaxpr) -> int:
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "dot_general":
            n += 1
        for v in eqn.params.values():
            for sub in (v if isinstance(v, (list, tuple)) else (v,)):
                inner = getattr(sub, "jaxpr", None)
                if inner is not None:
                    n += _count_dot_generals(inner)
    return n


def _scan_body_dot_generals(jaxpr) -> int:
    """dot_general count inside the (single) lax.scan body of ``jaxpr``."""
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "scan":
            return _count_dot_generals(eqn.params["jaxpr"].jaxpr)
    raise AssertionError("no scan primitive found")


def test_input_gemm_hoisted_out_of_scan_body():
    """The hoisted executor's scan body runs ONLY the recurrent matmul (1
    dot_general; + projection when enabled), while the stepwise baseline
    still carries the input GEMM per step."""
    variant = L.LSTMVariant()  # no projection: gate matmuls only
    xs_q, arrays, spec = _setup(variant)
    h0, c0 = _state(spec)
    hoisted = jax.make_jaxpr(
        lambda a, x: ops.quant_lstm_seq(a, spec, x, h0, c0, backend="xla")
    )(arrays, xs_q)
    stepwise = jax.make_jaxpr(
        lambda a, x: ops.quant_lstm_seq_stepwise(
            a, spec, x, h0, c0, backend="xla")
    )(arrays, xs_q)
    assert _scan_body_dot_generals(hoisted.jaxpr) == 1
    assert _scan_body_dot_generals(stepwise.jaxpr) == 2
    # the hoisted GEMM still exists -- once, outside the scan
    assert _count_dot_generals(hoisted.jaxpr) == 2


def test_masked_hoisted_matches_prefix_feeding():
    """Deterministic ragged check on both lowerings: each row's final state
    after a masked (B, T) block == feeding only its valid prefix."""
    variant = L.LSTMVariant(use_layernorm=True, use_projection=True)
    xs_q, arrays, spec = _setup(variant)
    valid = jnp.asarray([0, 1, 4, 6], jnp.int32)
    h0, c0 = _state(spec)
    for backend in ("xla", "interpret"):
        ys_m, (h_m, c_m) = ops.quant_lstm_seq_masked(
            arrays, spec, xs_q, h0, c0, valid, backend=backend)
        for row, n in enumerate(np.asarray(valid)):
            if n == 0:
                np.testing.assert_array_equal(np.asarray(h_m)[row],
                                              np.asarray(h0)[row])
                np.testing.assert_array_equal(np.asarray(c_m)[row],
                                              np.asarray(c0)[row])
                continue
            ys_r, (h_r, c_r) = ops.quant_lstm_seq(
                arrays, spec, xs_q[row:row + 1, :n],
                h0[row:row + 1], c0[row:row + 1], backend="xla")
            np.testing.assert_array_equal(np.asarray(h_m)[row],
                                          np.asarray(h_r)[0])
            np.testing.assert_array_equal(np.asarray(c_m)[row],
                                          np.asarray(c_r)[0])
            np.testing.assert_array_equal(np.asarray(ys_m)[row, :n],
                                          np.asarray(ys_r)[0])


def test_masked_ragged_valid_lens_property():
    """Hypothesis property: for ANY per-row valid-length vector in [0, T],
    the masked hoisted executor's final state matches unmasked prefix
    feeding row by row (bitwise), and the persistent-kernel lowering
    (interpret) agrees with the xla scan on every sampled vector."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    variant = L.LSTMVariant(use_layernorm=True, use_projection=True)
    xs_q, arrays, spec = _setup(variant, seed=7)
    h0, c0 = _state(spec)
    run_masked = jax.jit(lambda v: ops.quant_lstm_seq_masked(
        arrays, spec, xs_q, h0, c0, v, backend="xla"))
    # one compile (fixed shapes); each example only re-executes the kernel
    run_masked_kernel = jax.jit(lambda v: ops.quant_lstm_seq_masked(
        arrays, spec, xs_q, h0, c0, v, backend="interpret"))
    # specializes per prefix length n (n <= T, so at most T programs)
    run_prefix = jax.jit(lambda x, h, c: ops.quant_lstm_seq(
        arrays, spec, x, h, c, backend="xla"))

    @settings(max_examples=8, deadline=None)
    @given(st.lists(st.integers(min_value=0, max_value=T),
                    min_size=B, max_size=B))
    def prop(valid_lens):
        valid = jnp.asarray(valid_lens, jnp.int32)
        ys_m, (h_m, c_m) = run_masked(valid)
        ys_k, (h_k, c_k) = run_masked_kernel(valid)
        np.testing.assert_array_equal(np.asarray(ys_m), np.asarray(ys_k))
        np.testing.assert_array_equal(np.asarray(h_m), np.asarray(h_k))
        np.testing.assert_array_equal(np.asarray(c_m), np.asarray(c_k))
        for row, n in enumerate(valid_lens):
            if n == 0:
                h_r, c_r = h0[row:row + 1], c0[row:row + 1]
            else:
                _, (h_r, c_r) = run_prefix(
                    xs_q[row:row + 1, :n], h0[row:row + 1], c0[row:row + 1])
            np.testing.assert_array_equal(np.asarray(h_m)[row],
                                          np.asarray(h_r)[0])
            np.testing.assert_array_equal(np.asarray(c_m)[row],
                                          np.asarray(c_r)[0])

    prop()


def test_empty_sequence_returns_carry_unchanged():
    """T == 0 regression: the pre-hoist executor returned the carry
    untouched; the hoisted paths (reshape + grid=(T,) kernel) must too,
    on every backend."""
    variant = L.LSTMVariant()
    xs_q, arrays, spec = _setup(variant)
    h0, c0 = _state(spec)
    empty = xs_q[:, :0]
    for backend in ("xla", "interpret"):
        ys, (h, c) = ops.quant_lstm_seq(
            arrays, spec, empty, h0, c0, backend=backend)
        assert ys.shape == (B, 0, D_H)
        np.testing.assert_array_equal(np.asarray(h), np.asarray(h0))
        np.testing.assert_array_equal(np.asarray(c), np.asarray(c0))
        ys_m, (h_m, c_m) = ops.quant_lstm_seq_masked(
            arrays, spec, empty, h0, c0,
            jnp.zeros((B,), jnp.int32), backend=backend)
        assert ys_m.shape == (B, 0, D_H)
        np.testing.assert_array_equal(np.asarray(h_m), np.asarray(h0))
        np.testing.assert_array_equal(np.asarray(c_m), np.asarray(c0))


def test_set_backend_rejects_unknown_names():
    """Bugfix regression: validation must be a plain raise (assert would be
    stripped under ``python -O``) and must name the valid backends."""
    prev = ops.get_backend()
    try:
        with pytest.raises(ValueError, match="pallas_interpret"):
            ops.set_backend("cuda")
        assert ops.get_backend() == prev  # rejected names leave it untouched
    finally:
        ops.set_backend(prev)


def test_resolve_rejects_unknown_backend_kwarg():
    """Per-call ``backend=`` goes through the same ValueError validation."""
    variant = L.LSTMVariant()
    xs_q, arrays, spec = _setup(variant)
    h0, c0 = _state(spec)
    with pytest.raises(ValueError, match="valid backends"):
        ops.quant_lstm_seq(arrays, spec, xs_q, h0, c0, backend="cuda")
