"""Serving correctness: prefill/decode agreement, int8 path, ring buffers."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import SMOKE_CONFIGS
from repro.models import model_zoo, quant_transformer

IDENT = lambda x, logical=None: x


def _greedy_from_decode(bundle, params, prompt, n_steps, max_len=64):
    state = bundle.init_state(prompt.shape[0], max_len)
    logits = None
    for i in range(prompt.shape[1]):
        logits, state = bundle.decode(params, prompt[:, i:i+1], state, IDENT)
    return logits


@pytest.mark.parametrize("name", ["qwen3-4b", "stablelm-1.6b", "internvl2-2b"])
def test_prefill_decode_consistency(name):
    """Teacher-forcing the prompt through decode must reproduce the prefill
    logits (cache write/read correctness)."""
    cfg = SMOKE_CONFIGS[name]
    bundle = model_zoo.build(cfg)
    params, _ = bundle.init(jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 0,
                                cfg.vocab_size)
    batch = {"tokens": prompt}
    if cfg.family == "vlm":
        pytest.skip("vlm prefill prepends patch embeds; decode-only path")
    lp = bundle.prefill(params, batch, IDENT)
    ld = _greedy_from_decode(bundle, params, prompt, 0)
    np.testing.assert_allclose(
        np.asarray(lp, np.float32), np.asarray(ld, np.float32),
        rtol=0.1, atol=0.15)


def test_int8_weightonly_close_to_float():
    cfg = SMOKE_CONFIGS["qwen3-4b"]
    bundle = model_zoo.build(cfg)
    params, _ = bundle.init(jax.random.PRNGKey(0))
    qb = quant_transformer.quantize_bundle(bundle)
    qparams, _ = qb.init(jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                cfg.vocab_size)
    pf = jax.nn.softmax(bundle.prefill(params, {"tokens": prompt}, IDENT))
    pq = jax.nn.softmax(qb.prefill(qparams, {"tokens": prompt}, IDENT))
    assert float(jnp.abs(pf - pq).max()) < 5e-3


def test_int8_kv_cache_decode():
    cfg = SMOKE_CONFIGS["qwen3-4b"]
    bundle = model_zoo.build(cfg)
    params, _ = bundle.init(jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                                cfg.vocab_size)
    # float cache
    sf = bundle.init_state(2, 32)
    # int8 cache
    sq = bundle.init_state(2, 32, quantized=True)
    for i in range(prompt.shape[1]):
        lf, sf = bundle.decode(params, prompt[:, i:i+1], sf, IDENT)
        lq, sq = bundle.decode(params, prompt[:, i:i+1], sq, IDENT)
    pf, pq = jax.nn.softmax(lf), jax.nn.softmax(lq)
    assert float(jnp.abs(pf - pq).max()) < 2e-2
    assert sq["main"]["k"].dtype == jnp.int8


def test_sliding_window_ring_buffer():
    """Decode past the window size must keep only the last W positions."""
    import dataclasses
    cfg = dataclasses.replace(SMOKE_CONFIGS["qwen3-4b"], attn_window=8)
    bundle = model_zoo.build(cfg)
    params, _ = bundle.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 20), 0,
                              cfg.vocab_size)
    state = bundle.init_state(1, 8)  # cache only as deep as the window
    for i in range(20):
        logits, state = bundle.decode(params, toks[:, i:i+1], state, IDENT)
    assert bool(jnp.isfinite(logits).all())
    assert int(state["len"]) == 20


def test_int8_lstm_serving_state_continuity():
    """Integer-only serving: one-shot scanned prefill must produce exactly
    the logits of step-by-step decode (integer math is deterministic, so this
    is a bitwise check on the carried int8/int16 states)."""
    from repro.models import lstm_lm

    cfg = SMOKE_CONFIGS["lstm-rnnt"]
    bundle = model_zoo.build(cfg)
    params, _ = bundle.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 7), 0,
                              cfg.vocab_size)
    qlayers = lstm_lm.quantize_stack(params, cfg, toks)
    prefill = jax.jit(lambda p, t, s: lstm_lm.quant_prefill(
        p, qlayers, cfg, t, s))
    decode = jax.jit(lambda p, t, s: lstm_lm.quant_decode_step(
        p, qlayers, cfg, t, s))
    lp, sp = prefill(params, toks, lstm_lm.init_quant_decode_state(qlayers, 2))
    state = lstm_lm.init_quant_decode_state(qlayers, 2)
    for i in range(toks.shape[1]):
        ld, state = decode(params, toks[:, i:i + 1], state)
    for k in ("h", "c"):
        for a, b in zip(sp[k], state[k]):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_allclose(np.asarray(lp, np.float32),
                               np.asarray(ld, np.float32), rtol=1e-5,
                               atol=1e-5)


def test_lstm_serving_state_continuity():
    cfg = SMOKE_CONFIGS["lstm-rnnt"]
    bundle = model_zoo.build(cfg)
    params, _ = bundle.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 9), 0, cfg.vocab_size)
    # one-shot prefill logits == step-by-step decode logits
    lp = bundle.prefill(params, {"tokens": toks}, IDENT)
    state = bundle.init_state(2, 16)
    for i in range(9):
        ld, state = bundle.decode(params, toks[:, i:i+1], state, IDENT)
    np.testing.assert_allclose(np.asarray(lp, np.float32),
                               np.asarray(ld, np.float32), rtol=2e-2,
                               atol=2e-2)
