"""Calibration edge cases (satellite): degenerate statistics must still
produce VALID Table-2 recipes -- finite, non-NaN, strictly positive scales
and representable fixed-point multipliers -- because production calibration
sets routinely contain dead activations, constant features, or a single
utterance."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import qtypes as qt
from repro.core import recipe as R
from repro.core.calibrate import Stats, TapCollector, calibrate
from repro.models import lstm as L
from repro.models import quant_lstm as QL

pytestmark = pytest.mark.fast

D_IN, D_H, D_P = 8, 12, 6


def _assert_valid_spec(spec):
    for name in ("s_x", "s_h", "s_m", "s_c"):
        s = getattr(spec, name)
        assert math.isfinite(s) and s > 0.0, f"{name}={s}"
    for zp in (spec.zp_x, spec.zp_h, spec.zp_m, spec.zp_h_out):
        assert -128 <= zp <= 127
    assert spec.cell_int_bits >= 0
    for g, gs in spec.gates:
        for pair in (gs.eff_x, gs.eff_h, gs.eff_c, gs.ln_out):
            if pair is None:
                continue
            m0, shift = pair
            assert 0 <= m0 < 2**31, (g, pair)
            assert -31 <= shift <= 31, (g, pair)
    m0, shift = spec.eff_m
    assert 0 <= m0 < 2**31 and -31 <= shift <= 31


def _recipe_from_input(xs, variant=L.LSTMVariant()):
    cfg = L.LSTMConfig(D_IN, D_H, D_P if variant.use_projection else 0,
                       variant)
    params = L.init_lstm_params(jax.random.PRNGKey(0), cfg)
    col = TapCollector()
    L.lstm_layer(params, cfg, xs, collector=col)
    stats = Stats()
    stats.merge(jax.device_get(col.snapshot()))
    return R.quantize_lstm_layer(params, cfg, stats), stats, cfg


@pytest.mark.parametrize("variant", [
    L.LSTMVariant(),
    L.LSTMVariant(use_layernorm=True, use_projection=True),
], ids=lambda v: v.name)
def test_constant_zero_activations(variant):
    """All-zero calibration input: every activation range collapses to a
    point, yet the recipe must stay finite and executable."""
    xs = jnp.zeros((2, 4, D_IN))
    (arrays, spec), stats, cfg = _recipe_from_input(xs, variant)
    _assert_valid_spec(spec)
    # and the integer executor runs on it without overflow/assert
    xs_q = QL.quantize_input(xs, spec.s_x, spec.zp_x)
    ys, (h, c) = QL.quant_lstm_layer(arrays, spec, xs_q, backend="xla")
    assert ys.dtype == jnp.int8 and c.dtype == jnp.int16


def test_constant_nonzero_activations():
    """Constant (nonzero) input: zero-range x stats, nonzero gate stats."""
    xs = 0.7 * jnp.ones((2, 4, D_IN))
    (arrays, spec), stats, _ = _recipe_from_input(xs)
    lo, hi = stats.range("x")
    assert lo == hi  # the degenerate range under test
    _assert_valid_spec(spec)


def test_single_sample_calibration():
    """One batch through ``calibrate`` (the paper: ~100 utterances suffice;
    one must at least produce a usable recipe)."""
    cfg = L.LSTMConfig(D_IN, D_H, 0, L.LSTMVariant())
    params = L.init_lstm_params(jax.random.PRNGKey(1), cfg)

    def apply_fn(p, batch, collector):
        L.lstm_layer(p, cfg, batch, collector=collector)

    one_batch = [0.5 * jax.random.normal(jax.random.PRNGKey(2), (1, 1, D_IN))]
    stats = calibrate(apply_fn, params, one_batch)
    arrays, spec = R.quantize_lstm_layer(params, cfg, stats)
    _assert_valid_spec(spec)


def test_asymmetric_ranges_nudge_zero_point():
    """Strongly one-sided ranges: scale positive, zp clamped into int8, and
    float 0.0 still maps exactly onto an integer (paper sec 3.2.4)."""
    for lo, hi in [(0.0, 5.0), (-3.0, 0.0), (0.2, 7.0), (-9.0, -0.5),
                   (0.0, 0.0), (1e-12, 1e-12)]:
        s, zp = qt.asymmetric_scale_zp(lo, hi, 8)
        assert math.isfinite(s) and s > 0.0, (lo, hi)
        assert -128 <= zp <= 127
        # the nudged zp reproduces 0.0 exactly
        assert (round(0.0 / s) + zp - zp) * s == 0.0
        # round-tripping lo lands within half a step of the representable
        # range (the scheme widens one-sided ranges to include 0.0)
        ql = np.clip(round(lo / s) + zp, -128, 127)
        lo_repr = np.clip(lo, (-128 - zp) * s, (127 - zp) * s)
        assert abs((ql - zp) * s - lo_repr) <= s / 2 + 1e-12


def test_stats_merge_and_missing_tap():
    """Stats aggregates min/max across merges; unknown taps raise a clear
    KeyError instead of silently producing NaN scales."""
    st = Stats()
    st.merge({"x": (jnp.float32(-1.0), jnp.float32(2.0))})
    st.merge({"x": (jnp.float32(-3.0), jnp.float32(0.5))})
    assert st.range("x") == (-3.0, 2.0)
    assert st.max_abs("x") == 3.0
    with pytest.raises(KeyError):
        st.range("nope")
