"""Bit-exactness and accuracy tests for the gemmlowp fixed-point core."""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="install the [test] extra")
from hypothesis import given, settings, strategies as st

from repro.core import fixedpoint as fp

I32 = st.integers(-(2**31), 2**31 - 1)


def srdhm_oracle(a: int, b: int) -> int:
    if a == -(2**31) and b == -(2**31):
        return 2**31 - 1
    ab = a * b
    nudge = (1 << 30) if ab >= 0 else (1 - (1 << 30))
    x = ab + nudge
    q = abs(x) >> 31
    return q if x >= 0 else -q


@settings(max_examples=300, deadline=None)
@given(I32, I32)
def test_srdhm_bit_exact(a, b):
    got = int(fp.saturating_rounding_doubling_high_mul(
        jnp.int32(a), jnp.int32(b)))
    assert got == srdhm_oracle(a, b)


def test_srdhm_vectorized_exact():
    rng = np.random.default_rng(0)
    a = rng.integers(-2**31, 2**31, 5000).astype(np.int32)
    b = rng.integers(-2**31, 2**31, 5000).astype(np.int32)
    got = np.asarray(fp.saturating_rounding_doubling_high_mul(
        jnp.array(a), jnp.array(b)), np.int64)
    ref = np.array([srdhm_oracle(int(x), int(y)) for x, y in zip(a, b)])
    np.testing.assert_array_equal(got, ref)


@settings(max_examples=200, deadline=None)
@given(I32, st.integers(1, 30))
def test_rounding_divide_by_pot(x, e):
    mask = (1 << e) - 1
    rem = x & mask
    thr = (mask >> 1) + (1 if x < 0 else 0)
    ref = (x >> e) + (1 if rem > thr else 0)
    assert int(fp.rounding_divide_by_pot(jnp.int32(x), e)) == ref


@settings(max_examples=200, deadline=None)
@given(st.integers(0, 2**32 - 1), st.integers(0, 2**32 - 1))
def test_u64_mul(a, b):
    hi, lo = fp.u64_from_mul_u32(jnp.uint32(a), jnp.uint32(b))
    assert (int(hi) << 32) | int(lo) == a * b


@settings(max_examples=200, deadline=None)
@given(st.integers(0, 2**63 - 1), st.integers(0, 2**62))
def test_u64_add_sub(a, b):
    ah, al = jnp.uint32(a >> 32), jnp.uint32(a & 0xFFFFFFFF)
    bh, bl = jnp.uint32(b >> 32), jnp.uint32(b & 0xFFFFFFFF)
    h, l = fp.u64_add(ah, al, bh, bl)
    assert ((int(h) << 32) | int(l)) == (a + b) % 2**64
    if a >= b:
        h, l = fp.u64_sub(ah, al, bh, bl)
        assert ((int(h) << 32) | int(l)) == a - b


def test_tanh_sigmoid_q15_accuracy():
    xs = np.arange(-32768, 32768, dtype=np.int16)
    for m, scale in ((3, 2.0**-12), (4, 2.0**-11), (0, 2.0**-15)):
        t = np.asarray(fp.tanh_q15(jnp.array(xs), m), np.float64) / 32768
        ref = np.tanh(xs.astype(np.float64) * scale)
        # paper sec 3.2.1: error bounded by ~Q0.15 resolution
        assert np.abs(t - ref).max() < 1e-4, m
    s = np.asarray(fp.sigmoid_q15(jnp.array(xs), 3), np.float64) / 32768
    refs = 1 / (1 + np.exp(-xs.astype(np.float64) * 2.0**-12))
    assert np.abs(s - refs).max() < 5e-5


def test_exp_on_negative_values():
    rng = np.random.default_rng(1)
    x = -rng.integers(0, 2**31 - 1, 5000).astype(np.int32)
    got = np.asarray(fp.exp_on_negative_values(jnp.array(x), 5), np.float64) / 2**31
    ref = np.exp(x.astype(np.float64) / 2**26)
    assert np.abs(got - ref).max() < 1e-6


def test_integer_rsqrt():
    rng = np.random.default_rng(2)
    v = rng.integers(1, 2**62, 3000).astype(np.uint64)
    hi = (v >> 32).astype(np.uint32)
    lo = (v & 0xFFFFFFFF).astype(np.uint32)
    m0, sh = fp.integer_rsqrt_multiplier(jnp.array(hi), jnp.array(lo))
    approx = np.asarray(m0, np.float64) / 2**31 * 2.0 ** np.asarray(sh, np.float64)
    ref = 1 / np.sqrt(v.astype(np.float64))
    assert (np.abs(approx - ref) / ref).max() < 1e-6


def test_integer_recip():
    rng = np.random.default_rng(3)
    x = rng.integers(1, 2**31 - 1, 3000).astype(np.int32)
    m0, sh = fp.integer_recip_multiplier(jnp.array(x))
    approx = np.asarray(m0, np.float64) / 2**31 * 2.0 ** np.asarray(sh, np.float64)
    assert (np.abs(approx * x - 1.0)).max() < 1e-6


@settings(max_examples=100, deadline=None)
@given(st.floats(1e-6, 100.0), st.integers(-(2**20), 2**20))
def test_multiply_by_quantized_multiplier(scale, x):
    m0, s = fp.quantize_multiplier(scale)
    got = int(fp.multiply_by_quantized_multiplier(jnp.int32(x), m0, s))
    assert abs(got - round(x * scale)) <= 1


def test_saturating_ops():
    assert int(fp.saturating_add_i32(jnp.int32(2**31 - 1), jnp.int32(100))) == 2**31 - 1
    assert int(fp.saturating_add_i32(jnp.int32(-(2**31)), jnp.int32(-5))) == -(2**31)
    assert int(fp.saturating_left_shift(jnp.int32(2**30), 2)) == 2**31 - 1
    assert int(fp.saturating_left_shift(jnp.int32(-(2**30)), 2)) == -(2**31)
    assert int(fp.saturate_i16(jnp.int32(40000))) == 32767
    assert int(fp.saturate_i8(jnp.int32(-300))) == -128
