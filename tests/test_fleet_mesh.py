"""Fleet tier under REAL multi-device placement.

Subprocess-isolated like ``tests/test_sharding.py``: XLA only honors
``--xla_force_host_platform_device_count`` if it lands in ``XLA_FLAGS``
before jax initializes, and the parent test process has long since
initialized jax on a single device.  Deliberately NOT in the ``fast``
subset -- it pays a full jax start + quantize per run.

The property under test is the tentpole acceptance one, on disjoint
per-shard device groups instead of the co-located default: kill 1 of 2
shards mid-flight and every stream (migrated, replayed, undisturbed)
completes bit-identical to ``decode_single``.
"""
import os
import subprocess
import sys

import pytest

_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=4")
import jax
import numpy as np
from repro.configs.registry import SMOKE_CONFIGS
from repro.launch import engine as E
from repro.launch import fleet as F
from repro.models import lstm_lm, model_zoo
from repro.runtime import sharding as shlib

assert len(jax.devices()) == 4
cfg = SMOKE_CONFIGS["lstm-rnnt"]
bundle = model_zoo.build(cfg)
params, _ = bundle.init(jax.random.PRNGKey(0))
calib = jax.random.randint(jax.random.PRNGKey(2), (4, 8), 0, cfg.vocab_size)
qlayers = lstm_lm.quantize_stack(params, cfg, calib)

meshes = shlib.fleet_meshes(2)
assert all(m is not None for m in meshes)
got = [tuple(d.id for d in np.ravel(m.devices)) for m in meshes]
assert got == [(0, 1), (2, 3)], got  # disjoint contiguous groups

rng = np.random.default_rng(7)
reqs = [E.Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, size=(p,)),
                  max_new_tokens=g)
        for i, (p, g) in enumerate([(2, 8), (3, 7), (5, 6), (2, 9)])]
inj = F.FaultInjector(kills=[dict(shard=0, at_step=5)])
router = F.FleetRouter(params, qlayers, cfg, n_shards=2, slots_per_shard=2,
                       oversubscribe=2.0, policy="srf", injector=inj,
                       meshes=meshes)
router.warmup()
router.submit_all(reqs)
results, stats = router.run()
assert stats.kills == 1 and stats.completed == len(reqs)
for r in reqs:
    ref = E.decode_single(params, qlayers, cfg, r.prompt, r.max_new_tokens)
    assert results[r.rid].tokens == ref, f"stream {r.rid} drifted"
print("MESH-FLEET-OK")
"""


@pytest.mark.skipif(os.environ.get("REPRO_SKIP_SUBPROCESS") == "1",
                    reason="subprocess tests disabled")
def test_fleet_on_disjoint_meshes_subprocess():
    """2 shards on disjoint 2-device meshes (forced host CPU devices),
    shard 0 hard-killed mid-flight: recovery across REAL device groups
    stays bit-exact."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    out = subprocess.run(
        [sys.executable, "-c", _MESH_SCRIPT], env=env, cwd=os.getcwd(),
        capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "MESH-FLEET-OK" in out.stdout
