"""Integer LayerNorm / RMSNorm / softmax / matmul vs float + int64 oracles."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import fixedpoint as fp
from repro.core import integer_ops as io
from repro.core import qtypes as qt
from repro.kernels import ref

pytestmark = pytest.mark.fast


@pytest.mark.parametrize("n", [256, 1024, 2048, 8192])
def test_integer_layernorm_vs_float(n):
    rng = np.random.default_rng(n)
    q = rng.integers(-32768, 32767, (16, n)).astype(np.int16)
    lw = rng.uniform(0.2, 1.5, n)
    lb = rng.uniform(-0.5, 0.5, n)
    s_l = qt.symmetric_scale(np.abs(lw).max(), 16)
    lq = np.round(lw / s_l).astype(np.int16)
    bq = np.round(lb / (2**-10 * s_l)).astype(np.int32)
    m0, sh = fp.quantize_multiplier(2**-10 * s_l / 2**-12)
    out = np.asarray(io.integer_layernorm(
        jnp.array(q), jnp.array(lq), jnp.array(bq), m0, sh))
    x = q.astype(np.float64)
    mu = x.mean(-1, keepdims=True)
    sig = x.std(-1, keepdims=True)
    ref_f = np.clip(((x - mu) / sig * lw + lb) / 2**-12, -32768, 32767)
    # error bounded by the paper's s'=2**-10 normalized-value resolution
    bound = np.abs(lq).max() * (2**-10 * s_l / 2**-12) + 2
    assert np.abs(out - ref_f).max() <= bound


def test_integer_layernorm_vs_int64_oracle():
    rng = np.random.default_rng(0)
    q = rng.integers(-32768, 32767, (32, 1024)).astype(np.int16)
    lw = rng.integers(1000, 32767, 1024).astype(np.int16)
    lb = rng.integers(-(2**20), 2**20, 1024).astype(np.int32)
    m0, sh = fp.quantize_multiplier(0.37)
    got = np.asarray(io.integer_layernorm(
        jnp.array(q), jnp.array(lw), jnp.array(lb), m0, sh)).astype(np.int64)
    want = ref.int_layernorm_np(q, lw, lb, m0, sh).astype(np.int64)
    # limb/Newton path within 2 LSB of the paper-exact int64 reference
    assert np.abs(got - want).max() <= 2


def test_integer_layernorm_scale_invariance():
    """Paper sec 3.2.6: any input scale cancels in the normalization."""
    rng = np.random.default_rng(5)
    q = rng.integers(-8000, 8000, (8, 512)).astype(np.int16)
    lw = np.full(512, 16000, np.int16)
    lb = np.zeros(512, np.int32)
    m0, sh = fp.quantize_multiplier(1e-2)
    a = np.asarray(io.integer_layernorm(jnp.array(q), jnp.array(lw), jnp.array(lb), m0, sh))
    b = np.asarray(io.integer_layernorm(jnp.array(q * 4), jnp.array(lw), jnp.array(lb), m0, sh))
    assert np.abs(a.astype(int) - b.astype(int)).max() <= 2


def test_integer_rmsnorm():
    rng = np.random.default_rng(1)
    q = rng.integers(-32768, 32767, (16, 2048)).astype(np.int16)
    w = rng.uniform(0.5, 1.5, 2048)
    s_w = qt.symmetric_scale(np.abs(w).max(), 16)
    wq = np.round(w / s_w).astype(np.int16)
    m0, sh = fp.quantize_multiplier(2**-10 * s_w / 2**-12)
    out = np.asarray(io.integer_rmsnorm(jnp.array(q), jnp.array(wq), m0, sh))
    x = q.astype(np.float64)
    rms = np.sqrt((x**2).mean(-1, keepdims=True))
    ref_f = np.clip(x / rms * w / 2**-12, -32768, 32767)
    bound = np.abs(wq).max() * (2**-10 * s_w / 2**-12) + 2
    assert np.abs(out - ref_f).max() <= bound


@pytest.mark.parametrize("seq", [64, 512, 4096])
def test_integer_softmax(seq):
    rng = np.random.default_rng(seq)
    s_in = 1 / 128.0
    logits = rng.integers(-4000, 4000, (4, seq)).astype(np.int16)
    m0, sh = fp.quantize_multiplier(s_in * 2**26)
    p = np.asarray(io.integer_softmax(jnp.array(logits), m0, sh)).astype(np.float64) / 32768
    x = logits.astype(np.float64) * s_in
    e = np.exp(x - x.max(-1, keepdims=True))
    want = e / e.sum(-1, keepdims=True)
    assert np.abs(p - want).max() < 1e-4
    assert np.abs(p.sum(-1) - 1.0).max() < 1e-3


def test_zero_point_folding_exact():
    """Deployment optimization (paper sec 6) is arithmetic-identity exact."""
    rng = np.random.default_rng(2)
    W = rng.integers(-127, 127, (64, 32)).astype(np.int8)
    x = rng.integers(-128, 127, (4, 64)).astype(np.int8)
    b = rng.integers(-1000, 1000, 32).astype(np.int32)
    zp = -11
    folded = np.asarray(io.fold_zero_point(jnp.array(W), zp, jnp.array(b)))
    got = np.asarray(io.matmul_i8_i32(jnp.array(x), jnp.array(W))) + folded
    # runtime convention: x = s * (x_q - zp), so the fold undoes the zp
    want = (x.astype(np.int64) - zp) @ W.astype(np.int64) + b
    np.testing.assert_array_equal(got, want)


def test_matmul_accumulation_depth():
    """sec 3.1.1: int8 x int8 -> int32 safe to depth 2**15."""
    k = 2**15
    x = np.full((1, k), 127, np.int8)
    w = np.full((k, 1), 127, np.int8)
    acc = np.asarray(io.matmul_i8_i32(jnp.array(x), jnp.array(w)))
    assert acc[0, 0] == 127 * 127 * k  # < 2**31, no overflow
