"""Scheduler/executor split: policies, the paged state pool, preemption.

Three layers of guarantees, mirroring the layering itself:

* **StatePool** (pure host): page allocation order, row recycling, and the
  loud ``ValueError`` misuse contract (double swap-out / double resume /
  double free) -- silent state fabrication would break bit-exactness
  invisibly.
* **Scheduler policies** (pure host): each policy's slot elections on
  fabricated :class:`StreamView` lists, with no model in sight.
* **Engine integration**: FIFO reproduces the pre-split engine's admission
  schedule step-exactly (locked against a reference simulation of the old
  per-slot admission loop); user eviction routes through the pool and
  records ``state_preserved``; and -- the PR acceptance gate -- every
  policy × oversubscription ratio emits per-stream tokens bit-identical to
  ``decode_single`` and to the FIFO/no-oversubscription engine.
"""
import numpy as np
import pytest

import jax

from repro.configs.registry import SMOKE_CONFIGS
from repro.launch import engine as E
from repro.launch import scheduler as S
from repro.launch.state_pool import StatePool
from repro.models import lstm_lm, model_zoo

pytestmark = pytest.mark.fast


# ---------------------------------------------------------------------------
# StatePool (pure host, no model)
# ---------------------------------------------------------------------------


def _fake_state(fill: int):
    return {
        "h": [np.full((1, 4), fill, np.int8),
              np.full((1, 6), fill + 1, np.int8)],
        "c": [np.full((1, 4), fill + 2, np.int16),
              np.full((1, 6), fill + 3, np.int16)],
        "len": np.asarray([fill], np.int32),
    }


def _assert_state_equal(a, b):
    for k in ("h", "c"):
        for x, y in zip(a[k], b[k]):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    np.testing.assert_array_equal(np.asarray(a["len"]),
                                  np.asarray(b["len"]))


def test_pool_pages_allocate_lazily_and_rows_recycle():
    pool = StatePool(page_size=2)
    assert pool.n_pages == 0 and pool.state_bytes_per_stream == 0
    pool.put("a", _fake_state(1))
    pool.put("b", _fake_state(2))
    assert pool.n_pages == 1 and pool.capacity == 2
    assert pool.location("a") == (0, 0) and pool.location("b") == (0, 1)
    pool.put("c", _fake_state(3))  # page 0 full -> page 1 allocates
    assert pool.n_pages == 2 and pool.location("c") == (1, 0)
    # rows recycle LIFO: freeing b makes (0, 1) the next allocation
    _assert_state_equal(pool.take("b"), _fake_state(2))
    pool.put("d", _fake_state(4))
    assert pool.location("d") == (0, 1)
    assert pool.n_pages == 2  # no growth while a row is free
    # round trips are bitwise: every parked stream reads back exactly
    _assert_state_equal(pool.take("a"), _fake_state(1))
    _assert_state_equal(pool.take("c"), _fake_state(3))
    _assert_state_equal(pool.take("d"), _fake_state(4))
    assert len(pool) == 0 and pool.peak_live == 3
    assert pool.state_bytes_per_stream == 4 + 6 + 2 * (4 + 6) + 4


def test_pool_misuse_raises_not_fabricates():
    pool = StatePool(page_size=2)
    pool.put("a", _fake_state(1))
    with pytest.raises(ValueError, match="double swap-out"):
        pool.put("a", _fake_state(1))
    with pytest.raises(ValueError, match="double resume"):
        pool.take("missing")
    pool.take("a")
    with pytest.raises(ValueError, match="double resume"):
        pool.take("a")
    with pytest.raises(ValueError, match="double free"):
        pool.free("a")
    with pytest.raises(ValueError, match="batch-1"):
        pool.put("bad", {"h": [np.zeros((2, 4), np.int8)],
                         "c": [np.zeros((2, 4), np.int16)],
                         "len": np.zeros((2,), np.int32)})
    with pytest.raises(ValueError, match="page_size"):
        StatePool(page_size=0)


# ---------------------------------------------------------------------------
# Scheduler policies (pure host, fabricated views)
# ---------------------------------------------------------------------------


def _view(rid, *, prio=0, arrival=0.0, sub=None, p_rem=0, g_rem=4,
          resident=False, slot=None, plen=4):
    return S.StreamView(
        rid=rid, priority=prio, arrival=arrival,
        submit_idx=rid if sub is None else sub, prompt_len=plen,
        prompt_remaining=p_rem, gen_remaining=g_rem, resident=resident,
        slot=slot)


def test_fifo_keeps_residents_then_pool_then_queue():
    sch = S.get_scheduler("fifo")
    resident = [_view(0, resident=True, slot=0)]
    pooled = [_view(1)]
    pending = [_view(2), _view(3), _view(4)]
    d = sch.schedule(0, resident, pooled, pending, 3, 5)
    assert d.run == [0, 1, 2] and d.reject == []
    # start budget caps NEW streams only; live (pooled) always placeable
    d = sch.schedule(0, resident, pooled, pending, 3, 0)
    assert d.run == [0, 1]


def test_fifo_reject_refuses_unplaced_arrivals():
    sch = S.get_scheduler("fifo-reject")
    d = sch.schedule(0, [_view(0, resident=True, slot=0)], [],
                     [_view(1), _view(2)], 2, 8)
    assert d.run == [0, 1] and d.reject == [2]


def test_priority_preempts_lowest_resident():
    sch = S.get_scheduler("priority")
    resident = [_view(0, prio=0, resident=True, slot=0),
                _view(1, prio=2, resident=True, slot=1)]
    d = sch.schedule(3, resident, [], [_view(2, prio=5)], 2, 2)
    assert d.run == [2, 1]  # prio 5 and 2 hold slots; prio 0 parks
    # equal priorities degrade to FIFO: both residents outrank the later
    # arrival (list order ranks by priority, residents keep their slots)
    d = sch.schedule(3, resident, [], [_view(2, prio=0)], 2, 2)
    assert d.run == [1, 0]


def test_srf_ranks_by_total_remaining_work():
    sch = S.get_scheduler("srf")
    resident = [_view(0, g_rem=9, resident=True, slot=0)]
    pending = [_view(1, p_rem=2, g_rem=2), _view(2, p_rem=1, g_rem=1)]
    d = sch.schedule(0, resident, [], pending, 2, 4)
    assert d.run == [2, 1]  # 2 and 4 tokens left beat the 9-token resident
    d = sch.schedule(0, resident, [], pending, 2, 0)  # no start budget
    assert d.run == [0]


def test_round_robin_rotates_on_quantum_expiry():
    sch = S.RoundRobinFairScheduler(quantum=2)
    # single slot, two streams: a runs its 2-step quantum, then b, then a...
    runs = []
    for step in range(6):
        av = _view(0, g_rem=9, resident=(runs and runs[-1] == [0]) or False,
                   slot=0 if runs and runs[-1] == [0] else None)
        bv = _view(1, g_rem=9, resident=bool(runs and runs[-1] == [1]),
                   slot=0 if runs and runs[-1] == [1] else None)
        resident = [v for v in (av, bv) if v.resident]
        others = [v for v in (av, bv) if not v.resident]
        # after first sight both are live (pooled when not resident)
        pooled = others if step else []
        pending = [] if step else others
        d = sch.schedule(step, resident, pooled, pending, 1, 2)
        runs.append(d.run)
    assert runs == [[0], [0], [1], [1], [0], [0]]
    with pytest.raises(ValueError, match="quantum"):
        S.RoundRobinFairScheduler(quantum=0)


def test_get_scheduler_registry():
    assert S.get_scheduler("srf").name == "srf"
    inst = S.FIFOScheduler()
    assert S.get_scheduler(inst) is inst
    with pytest.raises(ValueError, match="unknown scheduling policy"):
        S.get_scheduler("lifo")


# ---------------------------------------------------------------------------
# Engine integration (shared quantized smoke model)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def qlm():
    cfg = SMOKE_CONFIGS["lstm-rnnt"]
    bundle = model_zoo.build(cfg)
    params, _ = bundle.init(jax.random.PRNGKey(0))
    calib = jax.random.randint(jax.random.PRNGKey(2), (4, 8), 0,
                               cfg.vocab_size)
    qlayers = lstm_lm.quantize_stack(params, cfg, calib)
    return params, qlayers, cfg


def _requests(cfg, spec, *, seed=7):
    """spec: list of (prompt_len, gen[, priority[, arrival]])."""
    rng = np.random.default_rng(seed)
    out = []
    for i, entry in enumerate(spec):
        p, g = entry[0], entry[1]
        prio = entry[2] if len(entry) > 2 else 0
        arrival = entry[3] if len(entry) > 3 else 0
        out.append(E.Request(
            rid=i, prompt=rng.integers(0, cfg.vocab_size, size=(p,)),
            max_new_tokens=g, priority=prio, arrival=arrival))
    return out


def _reference(params, qlayers, cfg, requests):
    return {r.rid: E.decode_single(params, qlayers, cfg, r.prompt,
                                   r.max_new_tokens) for r in requests}


def _old_engine_admission_schedule(spec, n_slots):
    """Reference simulation of the PRE-SPLIT engine's admission loop: each
    step, slots are scanned in increasing index and every free slot pops the
    queue head.  A chunk=1 stream occupies its slot for exactly
    ``prompt_len - 1 + gen`` steps (one token fed per step; generation
    starts on the step consuming the last prompt token).  Returns the
    [(step, rid, slot)] admission trail the refactored engine must
    reproduce verbatim under the default FIFO policy.
    """
    queue = list(range(len(spec)))
    slots = [None] * n_slots  # rid or None
    left = {}  # rid -> resident steps remaining
    admissions = []
    step = 0
    while queue or any(r is not None for r in slots):
        for i in range(n_slots):
            if slots[i] is None and queue:
                rid = queue.pop(0)
                slots[i] = rid
                p, g = spec[rid][0], spec[rid][1]
                left[rid] = p - 1 + g
                admissions.append((step, rid, i))
        for i in range(n_slots):
            if slots[i] is not None:
                left[slots[i]] -= 1
                if left[slots[i]] == 0:
                    slots[i] = None
        step += 1
    return admissions


def test_fifo_reproduces_pre_split_admission_schedule(qlm):
    """The acceptance-criteria regression: default FIFO at oversubscribe=1
    makes the same step-by-step slot assignments as the monolithic engine's
    admission loop -- verified against a host-side simulation of that loop,
    and with zero preemptions/resumes/pool traffic."""
    params, qlayers, cfg = qlm
    spec = [(2, 4), (3, 2), (1, 6), (2, 2), (4, 3), (1, 1), (2, 5)]
    requests = _requests(cfg, spec)
    eng = E.ContinuousBatchingEngine(params, qlayers, cfg, n_slots=3)
    eng.submit_all(requests)
    results, stats = eng.run()
    got = [(step, rid, slot) for step, ev, rid, slot in eng.schedule_log
           if ev == "admit"]
    assert got == _old_engine_admission_schedule(spec, 3)
    assert [ev for _, ev, _, _ in eng.schedule_log
            if ev != "admit"] == []  # FIFO never preempts/resumes/rejects
    assert stats.preemptions == 0 and stats.resumes == 0
    assert stats.rejected == 0 and len(eng.pool) == 0
    assert len(results) == len(spec)


def test_evict_preserve_resume_is_bitexact(qlm):
    """Satellite regression: user eviction routes through the pool.
    ``evict(preserve=True)`` records state_preserved and ``resume`` then
    continues the stream BIT-exactly (including its drafter-free partial
    output); ``preserve=False`` keeps the old discard semantics."""
    params, qlayers, cfg = qlm
    requests = _requests(cfg, [(2, 10), (3, 8)], seed=5)
    ref = _reference(params, qlayers, cfg, requests)
    eng = E.ContinuousBatchingEngine(params, qlayers, cfg, n_slots=2)
    eng.submit_all(requests)
    _, _ = eng.run(max_steps=5, keep_live=True)
    partial = eng.evict(0, preserve=True)
    assert partial.truncated and partial.state_preserved
    assert partial.tokens == ref[0][:len(partial.tokens)]
    assert len(partial.tokens) < len(ref[0])
    assert 0 in eng.pool  # the state physically lives in the pool
    with pytest.raises(ValueError, match="not live"):
        eng.evict(0)  # parked streams left the live set
    eng.resume(0)
    with pytest.raises(ValueError, match="double resume"):
        eng.resume(0)
    results, stats = eng.run()
    assert results[0].tokens == ref[0]  # resumed stream: full bit-exact
    assert results[1].tokens == ref[1]  # co-tenant undisturbed
    assert results[0].preemptions >= 1
    assert stats.resumes >= 1

    # preserve=False keeps the pre-split discard semantics, visibly
    eng2 = E.ContinuousBatchingEngine(params, qlayers, cfg, n_slots=2)
    eng2.submit_all(_requests(cfg, [(2, 10)], seed=5))
    eng2.run(max_steps=4, keep_live=True)
    dropped = eng2.evict(0, preserve=False)
    assert dropped.truncated and not dropped.state_preserved
    assert len(eng2.pool) == 0
    with pytest.raises(ValueError, match="not parked"):
        eng2.resume(0)


def test_priority_policy_preempts_and_stays_bitexact(qlm):
    """A high-priority arrival preempts a low-priority resident to the pool
    mid-generation; both still emit bit-exact tokens."""
    params, qlayers, cfg = qlm
    spec = [(2, 8, 0, 0), (3, 8, 0, 0), (2, 4, 5, 3)]
    requests = _requests(cfg, spec, seed=9)
    eng = E.ContinuousBatchingEngine(params, qlayers, cfg, n_slots=2,
                                     policy="priority", oversubscribe=2.0)
    eng.submit_all(requests)
    results, stats = eng.run()
    assert stats.policy == "priority"
    assert stats.preemptions >= 1 and stats.resumes >= 1
    assert stats.peak_live == 3  # lived over-subscribed: 3 streams, 2 slots
    ref = _reference(params, qlayers, cfg, requests)
    for r in requests:
        assert results[r.rid].tokens == ref[r.rid], f"stream {r.rid} drifted"
    # the preempted stream knows it bounced
    assert max(res.preemptions for res in results.values()) >= 1


def test_rr_policy_time_slices_one_slot_bitexact(qlm):
    """Round-robin on ONE slot with two long streams forces repeated
    preempt/resume swaps through the pool -- the stress case for bit-exact
    state round trips."""
    params, qlayers, cfg = qlm
    requests = _requests(cfg, [(2, 8), (2, 8)], seed=3)
    eng = E.ContinuousBatchingEngine(
        params, qlayers, cfg, n_slots=1,
        policy=S.RoundRobinFairScheduler(quantum=3), oversubscribe=2.0)
    eng.submit_all(requests)
    results, stats = eng.run()
    assert stats.preemptions >= 2 and stats.resumes >= 2
    ref = _reference(params, qlayers, cfg, requests)
    for r in requests:
        assert results[r.rid].tokens == ref[r.rid], f"stream {r.rid} drifted"
    assert stats.pool_state_bytes > 0


def test_fifo_reject_policy_drops_overflow_loudly(qlm):
    """The rejection baseline: arrivals that find no free slot are refused
    with an explicit rejected result, never silently dropped."""
    params, qlayers, cfg = qlm
    requests = _requests(cfg, [(2, 6), (2, 6), (2, 6)], seed=1)
    eng = E.ContinuousBatchingEngine(params, qlayers, cfg, n_slots=2,
                                     policy="fifo-reject")
    eng.submit_all(requests)
    results, stats = eng.run()
    assert stats.rejected == 1
    rej = [r for r in results.values() if r.rejected]
    assert len(rej) == 1 and rej[0].tokens == [] and rej[0].truncated
    served = [r for r in results.values() if not r.rejected]
    ref = _reference(params, qlayers, cfg, requests)
    for res in served:
        assert res.tokens == ref[res.rid]


def test_arrival_gates_admission(qlm):
    """A request with a future arrival step must not be admitted before it;
    the engine idles (empty steps) when nothing else is runnable."""
    params, qlayers, cfg = qlm
    requests = _requests(cfg, [(2, 2, 0, 4)], seed=2)
    eng = E.ContinuousBatchingEngine(params, qlayers, cfg, n_slots=2)
    eng.submit_all(requests)
    results, stats = eng.run()
    admit = [(s, rid) for s, ev, rid, _ in eng.schedule_log if ev == "admit"]
    assert admit == [(4, 0)]
    assert results[0].admitted_step == 4
    assert results[0].tokens == _reference(params, qlayers, cfg,
                                           requests)[0]


def test_trace_schema_priority_and_arrival(tmp_path, qlm):
    """Satellite: the shared trace schema carries priority/arrival, with
    loud ValueError validation in both load_trace and Request."""
    import json

    _, _, cfg = qlm
    path = tmp_path / "t.json"
    path.write_text(json.dumps([
        {"prompt_len": 3, "gen": 2, "priority": 2, "arrival": 5},
        {"prompt": [1, 2], "gen": 1},
    ]))
    reqs = E.load_trace(str(path), cfg.vocab_size)
    assert reqs[0].priority == 2 and reqs[0].arrival == 5.0
    assert reqs[1].priority == 0 and reqs[1].arrival == 0.0

    def write(payload):
        path.write_text(json.dumps(payload))
        return str(path)

    with pytest.raises(ValueError, match="'priority' must be an int"):
        E.load_trace(write([{"prompt_len": 2, "gen": 1,
                             "priority": "high"}]), cfg.vocab_size)
    with pytest.raises(ValueError, match="'arrival' must be a number"):
        E.load_trace(write([{"prompt_len": 2, "gen": 1, "arrival": -3}]),
                     cfg.vocab_size)
    with pytest.raises(ValueError, match="arrival"):
        E.Request(rid=0, prompt=np.array([1]), max_new_tokens=1,
                  arrival=-1.0)
    with pytest.raises(ValueError, match="oversubscribe"):
        E.ContinuousBatchingEngine(*qlm, n_slots=1, oversubscribe=0.5)
    # synthetic_trace threads the new fields through
    reqs = E.synthetic_trace(8, cfg.vocab_size, seed=0,
                             priority_levels=(0, 1, 2), arrival_span=6)
    assert any(r.arrival > 0 for r in reqs)
    assert {r.priority for r in reqs} <= {0, 1, 2}


@pytest.mark.parametrize("policy", ["fifo", "priority", "srf", "rr"])
@pytest.mark.parametrize("oversubscribe", [1.0, 2.0])
def test_policy_sweep_bitexact_deterministic(qlm, policy, oversubscribe):
    """Deterministic slice of the acceptance gate (runs even without
    hypothesis): a fixed mixed workload -- staggered arrivals, inverted
    priorities, short and long streams -- under every preempting policy x
    oversubscription must emit tokens bit-identical to decode_single AND to
    the FIFO/no-oversubscription engine.  Policies may only change WHEN
    tokens come out, never WHICH tokens."""
    params, qlayers, cfg = qlm
    spec = [(2, 5, 0, 0), (3, 2, 2, 0), (1, 6, 1, 1), (4, 3, 3, 4),
            (2, 1, 0, 4), (1, 4, 2, 9)]
    requests = _requests(cfg, spec, seed=11)
    ref = _reference(params, qlayers, cfg, requests)

    fifo = E.ContinuousBatchingEngine(params, qlayers, cfg, n_slots=3)
    fifo.submit_all([E.Request(rid=r.rid, prompt=r.prompt,
                               max_new_tokens=r.max_new_tokens,
                               priority=r.priority, arrival=r.arrival)
                     for r in requests])
    fifo_results, _ = fifo.run()

    eng = E.ContinuousBatchingEngine(
        params, qlayers, cfg, n_slots=3, policy=policy,
        oversubscribe=oversubscribe)
    eng.submit_all(requests)
    results, stats = eng.run()

    assert len(results) == len(requests)
    for r in requests:
        assert results[r.rid].tokens == ref[r.rid], \
            f"{policy}@{oversubscribe}: stream {r.rid} drifted vs single"
        assert results[r.rid].tokens == fifo_results[r.rid].tokens, \
            f"{policy}@{oversubscribe}: stream {r.rid} drifted vs fifo"
    assert stats.peak_live <= eng.max_live
    assert len(eng.pool) == 0  # drained pool: nothing leaks across runs


# ---------------------------------------------------------------------------
# Property: random workloads x policy x oversubscription stay bit-exact
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    # (prompt_len, gen, priority, arrival) per request
    _WORKLOAD = st.lists(
        st.tuples(st.integers(1, 4), st.integers(1, 5),
                  st.integers(0, 3), st.integers(0, 6)),
        min_size=1, max_size=6,
    )

    @settings(max_examples=8, deadline=None)
    @given(workload=_WORKLOAD,
           policy=st.sampled_from(["fifo", "priority", "srf", "rr"]),
           oversubscribe=st.sampled_from([1.0, 1.5, 2.0]),
           seed=st.integers(0, 2**16))
    def test_property_policies_bitexact_vs_single_and_fifo(
            qlm, workload, policy, oversubscribe, seed):
        """The PR acceptance gate: for random workloads (mixed lengths,
        priorities, arrival steps) x every policy x oversubscription in
        {1, 1.5, 2}, EVERY stream's tokens are bit-identical to decoding it
        alone AND to the FIFO/no-oversubscription engine.  Policies may only
        change WHEN tokens come out, never WHICH tokens."""
        params, qlayers, cfg = qlm
        requests = _requests(cfg, workload, seed=seed)
        ref = _reference(params, qlayers, cfg, requests)

        fifo = E.ContinuousBatchingEngine(params, qlayers, cfg, n_slots=3)
        fifo.submit_all([E.Request(rid=r.rid, prompt=r.prompt,
                                   max_new_tokens=r.max_new_tokens,
                                   priority=r.priority, arrival=r.arrival)
                         for r in requests])
        fifo_results, _ = fifo.run()

        eng = E.ContinuousBatchingEngine(
            params, qlayers, cfg, n_slots=3, policy=policy,
            oversubscribe=oversubscribe)
        eng.submit_all(requests)
        results, stats = eng.run()

        assert len(results) == len(requests)
        for r in requests:
            assert results[r.rid].tokens == ref[r.rid], \
                f"{policy}@{oversubscribe}: stream {r.rid} drifted vs single"
            assert results[r.rid].tokens == fifo_results[r.rid].tokens, \
                f"{policy}@{oversubscribe}: stream {r.rid} drifted vs fifo"
        assert stats.peak_live <= eng.max_live
