"""Sharding rules unit tests + multi-device integration via subprocess
(device count must be set before jax initializes, so spawn fresh workers)."""
import json
import subprocess
import sys
import textwrap

import pytest

from repro.runtime import sharding as shlib


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape


def test_resolve_divisibility():
    mesh = _FakeMesh({"data": 16, "model": 16})
    rules = shlib.rules_for("dense_small")
    # heads=56 not divisible by 16 -> replicated (legal input sharding)
    spec = shlib.resolve(("embed", "heads"), (128, 56), rules, mesh)
    assert spec == shlib.P(None, None)
    spec = shlib.resolve(("embed", "heads"), (128, 64), rules, mesh)
    assert spec == shlib.P(None, "model")


def test_resolve_no_duplicate_axes():
    mesh = _FakeMesh({"data": 4, "model": 4})
    rules = {"a": ("model",), "b": ("model",)}
    spec = shlib.resolve(("a", "b"), (16, 16), rules, mesh)
    # "model" must be used at most once across dims
    axes = [s for s in spec if s is not None]
    assert axes.count("model") <= 1


def test_resolve_multi_axis_dp():
    mesh = _FakeMesh({"pod": 2, "data": 16, "model": 16})
    rules = shlib.rules_for("dense_fsdp")
    spec = shlib.resolve(("batch", None), (256, 128), rules, mesh)
    assert spec == shlib.P(("pod", "data"), None)
    # batch=8 not divisible by 32 -> only pod*? 8 % 2 == 0 so pod applies
    spec = shlib.resolve(("batch",), (8,), rules, mesh)
    assert spec == shlib.P(("pod", "data")) or spec == shlib.P("pod")


def test_engine_state_shardings_slot_axis():
    """Continuous-batching slot state: the slot dim resolves to the DP mesh
    axes (h/c/len all shard on dim 0), with divisibility degradation."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh

    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    state = {
        "h": [jnp.zeros((4, 20), jnp.int8)],
        "c": [jnp.zeros((4, 64), jnp.int16)],
        "len": jnp.zeros((4,), jnp.int32),
    }
    shardings = shlib.engine_state_shardings(
        state, shlib.rules_for("tiny"), mesh)
    assert shardings["h"][0].spec == shlib.P("data", None)
    assert shardings["c"][0].spec == shlib.P("data", None)
    assert shardings["len"].spec == shlib.P("data")
    # default rules (None) and odd slot counts still resolve legally
    state5 = {"h": [jnp.zeros((5, 20), jnp.int8)], "c": [],
              "len": jnp.zeros((5,), jnp.int32)}
    sh5 = shlib.engine_state_shardings(state5, None, mesh)
    assert sh5["h"][0].spec in (shlib.P("data", None), shlib.P(None, None))


_WORKER = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import Mesh

    from repro.configs.registry import SMOKE_CONFIGS
    from repro.models import model_zoo
    from repro.optim.optimizers import OptConfig
    from repro.runtime.train_loop import make_train_step

    cfg = SMOKE_CONFIGS["%(arch)s"]
    mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4), ("data", "model"))
    bundle = model_zoo.build(cfg)
    params, _ = bundle.init(jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab_size)
    batch = {"tokens": tok, "labels": tok}
    art = make_train_step(
        bundle, mesh, OptConfig(lr=1e-3, warmup_steps=1, total_steps=10),
        batch_example=jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch))
    params = jax.device_put(params, art.param_shardings)
    opt = jax.device_put(art.init_opt(params), art.opt_shardings)
    batch = jax.device_put(batch, art.batch_shardings)
    losses = []
    for _ in range(3):
        params, opt, m = art.step_fn(params, opt, batch)
        losses.append(float(m["loss"]))
    assert all(np.isfinite(l) for l in losses), losses
    assert losses[-1] < losses[0] + 1.0
    print("OK", losses)
""")


@pytest.mark.parametrize("arch", ["qwen3-4b", "grok-1-314b", "falcon-mamba-7b"])
def test_sharded_train_step_8dev(arch):
    """Real 8-device (2x4 mesh) sharded training steps, incl. MoE shard_map."""
    code = _WORKER % {"arch": arch}
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=600, cwd=".")
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "OK" in proc.stdout


def test_sharded_decode_8dev():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, "src")
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.configs.registry import SMOKE_CONFIGS
        from repro.models import model_zoo
        from repro.runtime.train_loop import make_serve_fns

        cfg = SMOKE_CONFIGS["qwen1.5-0.5b"]
        mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4), ("data", "model"))
        bundle = model_zoo.build(cfg)
        params, _ = bundle.init(jax.random.PRNGKey(0))
        prefill, decode, state_sh, param_sh = make_serve_fns(
            bundle, mesh, batch=4, max_len=32)
        params = jax.device_put(params, param_sh)
        state = jax.device_put(bundle.init_state(4, 32), state_sh)
        tok = jax.random.randint(jax.random.PRNGKey(1), (4, 1), 0, cfg.vocab_size)
        for _ in range(4):
            logits, state = decode(params, tok, state)
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        assert bool(jnp.isfinite(logits).all())
        print("OK")
    """)
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=600, cwd=".")
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "OK" in proc.stdout
