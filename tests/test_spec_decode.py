"""Speculative decoding lockdown: drafts may only change HOW FAST tokens
come out, never WHICH tokens.

The verify step feeds ``[last_token, d_1..d_k]`` through the masked ragged
executor, accepts the longest draft prefix the per-position greedy argmax
confirms, and rolls each row's state back to exactly its accepted length.
Every emitted token is therefore the greedy argmax at its position -- so
``speculate=k`` must be bit-identical to ``speculate=0`` and to
``decode_single`` for every k, workload, admission order, chunk size, and
eviction/truncation pattern.  These tests pin that invariant
deterministically (k in {2, 4, 8}) and -- when hypothesis is installed --
over randomized traces and admission orders, plus the drafter's own
contract (drafts come only from the stream's observed history; an empty
history drafts nothing).
"""
import jax
import numpy as np
import pytest

from repro.configs.registry import SMOKE_CONFIGS
from repro.launch import engine as E
from repro.launch.spec_decode import NGramDrafter
from repro.models import lstm_lm, model_zoo

pytestmark = pytest.mark.fast


@pytest.fixture(scope="module")
def qlm():
    """Quantized smoke LSTM LM shared by every test in this module (the
    engine/reference jit caches key on qlayers identity)."""
    cfg = SMOKE_CONFIGS["lstm-rnnt"]
    bundle = model_zoo.build(cfg)
    params, _ = bundle.init(jax.random.PRNGKey(0))
    calib = jax.random.randint(jax.random.PRNGKey(2), (4, 8), 0,
                               cfg.vocab_size)
    qlayers = lstm_lm.quantize_stack(params, cfg, calib)
    return params, qlayers, cfg


def _repetitive_requests(cfg, specs, *, seed=0, motif_len=3):
    """Requests whose prompts tile a short motif -- the self-repetitive
    regime where the n-gram drafter has signal from the first step."""
    rng = np.random.default_rng(seed)
    out = []
    for rid, (plen, gen) in enumerate(specs):
        motif = rng.integers(0, cfg.vocab_size, size=(motif_len,))
        prompt = np.tile(motif, -(-plen // motif_len))[:plen]
        out.append(E.Request(rid=rid, prompt=prompt, max_new_tokens=gen))
    return out


def _run(qlm, requests, *, speculate, chunk=1, n_slots=3, max_steps=None,
         drafter_factory=None):
    params, qlayers, cfg = qlm
    eng = E.ContinuousBatchingEngine(
        params, qlayers, cfg, n_slots=n_slots, chunk=chunk,
        speculate=speculate, drafter_factory=drafter_factory)
    eng.submit_all([E.Request(rid=r.rid, prompt=r.prompt,
                              max_new_tokens=r.max_new_tokens)
                    for r in requests])
    return eng.run(max_steps=max_steps)


def _reference(qlm, requests):
    params, qlayers, cfg = qlm
    return {r.rid: E.decode_single(params, qlayers, cfg, r.prompt,
                                   r.max_new_tokens) for r in requests}


# ---------------------------------------------------------------------------
# The n-gram drafter's own contract
# ---------------------------------------------------------------------------


def test_ngram_drafter_basics():
    d = NGramDrafter(max_n=3)
    assert d.draft(4) == []  # empty history drafts nothing
    d.observe([7])
    assert d.draft(4) == []  # one token: no earlier occurrence to continue
    d.observe([8, 9, 7, 8])
    # suffix [7, 8] last occurred at positions 0-1; continuation was [9, 7]
    assert d.draft(2) == [9, 7]
    assert d.draft(1) == [9]
    assert d.draft(0) == []
    d.reset()
    assert d.history == [] and d.draft(4) == []
    with pytest.raises(ValueError, match="max_n"):
        NGramDrafter(max_n=0)


def test_ngram_drafter_prefers_longest_suffix():
    d = NGramDrafter(max_n=3)
    # "1 2 3 | 9 2 3 | 1 2 3" -- the trigram [9, 2, 3] beats the bigram
    # [2, 3] (which also occurred earlier with a different continuation)
    d.observe([1, 2, 3, 9, 2, 3, 5, 9, 2, 3])
    assert d.draft(1) == [5]  # trigram [9,2,3] -> 5, not bigram [2,3] -> 9


def test_engine_validates_speculate(qlm):
    params, qlayers, cfg = qlm
    with pytest.raises(ValueError, match="speculate"):
        E.ContinuousBatchingEngine(params, qlayers, cfg, n_slots=1,
                                   speculate=-1)


# ---------------------------------------------------------------------------
# Engine bit-exactness under speculation
# ---------------------------------------------------------------------------


def test_spec_decode_bitexact_k_2_4_8(qlm):
    """Acceptance gate: k in {2, 4, 8} emits bit-identical per-stream
    tokens to speculate=0 and to decoding each stream alone, on a workload
    mixing repetitive prompts (drafts accept) with random ones (drafts
    mostly reject) and mixed generation budgets."""
    params, qlayers, cfg = qlm
    rng = np.random.default_rng(11)
    requests = _repetitive_requests(
        cfg, [(6, 10), (4, 7), (9, 12)], seed=1)
    for i, (p, g) in enumerate([(3, 8), (2, 5), (5, 9)]):
        requests.append(E.Request(
            rid=len(requests),
            prompt=rng.integers(0, cfg.vocab_size, size=(p,)),
            max_new_tokens=g))
    out0, s0 = _run(qlm, requests, speculate=0)
    assert s0.speculate == 0 and s0.spec_steps == 0
    ref = _reference(qlm, requests)
    for r in requests:
        assert out0[r.rid].tokens == ref[r.rid]
    for k in (2, 4, 8):
        outk, sk = _run(qlm, requests, speculate=k)
        assert sk.speculate == k
        assert sk.spec_steps > 0 and sk.drafted_tokens > 0
        for r in requests:
            assert outk[r.rid].tokens == ref[r.rid], \
                f"stream {r.rid} drifted at speculate={k}"
            assert len(outk[r.rid].tokens) == r.max_new_tokens


def test_spec_decode_goes_multi_token_on_repetitive_text(qlm):
    """On a purely repetitive trace speculation must actually pay: fewer
    engine steps than greedy and > 1 accepted token per verify step (the
    deterministic step-count core of the benchmark gate).  The trace
    mirrors benchmarks/spec_decode.py's committed baseline (motif-4 tiled
    prompts, 32-token generations, seed 3: long enough for the stream's
    own history to carry draft signal -- short generations mostly pre-date
    the cycles the drafter feeds on)."""
    requests = _repetitive_requests(
        cfg=qlm[2], specs=[(12, 32)] * 3, seed=3, motif_len=4)
    _, s0 = _run(qlm, requests, speculate=0)
    _, s4 = _run(qlm, requests, speculate=4)
    assert s4.steps < s0.steps
    assert s4.accepted_tokens_per_spec_step > 1.0
    assert s4.accepted_draft_tokens > 0
    assert 0.0 < s4.accept_rate <= 1.0


def test_spec_decode_with_chunked_prefill(qlm):
    """chunk > 1 and speculate > 0 compose: chunked prefill feeds prompts,
    the verify program takes over generation, tokens stay bit-exact."""
    requests = _repetitive_requests(
        cfg=qlm[2], specs=[(9, 6), (5, 8), (12, 4), (2, 6)], seed=5)
    ref = _reference(qlm, requests)
    out, stats = _run(qlm, requests, speculate=2, chunk=4)
    assert stats.chunk == 4 and stats.speculate == 2
    for r in requests:
        assert out[r.rid].tokens == ref[r.rid], f"stream {r.rid} drifted"


def test_spec_metrics_accounting(qlm):
    """Per-stream draft accounting sums to the engine totals, accept_rate
    is None exactly for streams that never drafted, and speculate=0 engines
    report all-zero speculation fields."""
    requests = _repetitive_requests(
        cfg=qlm[2], specs=[(6, 8), (4, 10)], seed=7)
    out, stats = _run(qlm, requests, speculate=3)
    assert stats.drafted_tokens == sum(
        r.drafted_tokens for r in out.values())
    assert stats.accepted_draft_tokens == sum(
        r.accepted_draft_tokens for r in out.values())
    assert stats.accepted_draft_tokens <= stats.drafted_tokens
    assert stats.spec_slot_steps >= stats.spec_steps  # >= 1 drafting slot
    for r in out.values():
        if r.drafted_tokens:
            assert 0.0 <= r.accept_rate <= 1.0
        else:
            assert r.accept_rate is None
    _, s0 = _run(qlm, requests, speculate=0)
    assert (s0.spec_steps, s0.spec_slot_steps, s0.drafted_tokens,
            s0.accepted_draft_tokens) == (0, 0, 0, 0)
    assert s0.accept_rate == 0.0
    assert s0.accepted_tokens_per_spec_step == 0.0


def test_eviction_midspec_never_leaks_state_between_slots(qlm):
    """A stream that finishes mid-verify-step (budget lands inside an
    accepted block) is evicted and its slot re-admits a pending request:
    the successor -- and every co-tenant -- must still match decoding it
    alone, i.e. no accepted-length or drafter state survives the slot
    handoff."""
    cfg = qlm[2]
    # short budgets + repetitive prompts force multi-token acceptance to
    # land exactly on (and spill over) budget boundaries; 9 requests
    # through 2 slots exercises repeated eviction/re-admission
    requests = _repetitive_requests(
        cfg, [(6, 3), (6, 2), (4, 5), (5, 3), (6, 4), (4, 2), (6, 3),
              (5, 2), (4, 4)], seed=9)
    ref = _reference(qlm, requests)
    out, stats = _run(qlm, requests, speculate=4, n_slots=2)
    assert len(out) == len(requests)
    assert stats.spec_steps > 0  # speculation actually exercised
    for r in requests:
        assert out[r.rid].tokens == ref[r.rid], f"stream {r.rid} drifted"


def test_truncation_midspec_returns_greedy_prefix(qlm):
    """run(max_steps) cutting a speculating engine off mid-flight returns
    partial generations that are exact PREFIXES of the greedy reference
    (a verify step emits its tokens atomically: accepted state and emitted
    tokens can never disagree), with truncation bookkeeping intact."""
    requests = _repetitive_requests(
        cfg=qlm[2], specs=[(4, 40), (6, 40)], seed=13)
    ref = _reference(qlm, requests)
    out, stats = _run(qlm, requests, speculate=4, max_steps=6)
    assert stats.steps == 6
    assert out, "nothing truncated -- workload too short for the test"
    for r in requests:
        res = out[r.rid]
        assert res.truncated
        assert res.finished_step == stats.steps - 1
        got = res.tokens
        assert 0 < len(got) < r.max_new_tokens
        assert got == ref[r.rid][:len(got)], f"stream {r.rid} drifted"


def test_null_drafter_degrades_to_greedy(qlm):
    """A drafter with no signal (always empty drafts) must leave the
    engine exactly on the greedy program path: no verify steps, same
    tokens, zero draft accounting."""

    class NullDrafter(NGramDrafter):
        def draft(self, k):
            return []

    requests = _repetitive_requests(cfg=qlm[2], specs=[(4, 6), (6, 5)],
                                    seed=15)
    ref = _reference(qlm, requests)
    out, stats = _run(qlm, requests, speculate=4,
                      drafter_factory=NullDrafter)
    assert stats.spec_steps == 0 and stats.drafted_tokens == 0
    for r in requests:
        assert out[r.rid].tokens == ref[r.rid]


# ---------------------------------------------------------------------------
# Hypothesis properties (optional dependency, like tests/test_engine.py)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:  # the rest of the module must still run without it
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(history=st.lists(st.integers(0, 9), max_size=40),
           k=st.integers(0, 8))
    def test_property_ngram_drafts_come_from_history(history, k):
        """Drafter contract: every draft token was previously observed by
        THAT stream, drafts never exceed k, and an empty history (or k=0)
        drafts nothing."""
        d = NGramDrafter(max_n=3)
        d.observe(history)
        drafts = d.draft(k)
        assert len(drafts) <= k
        if not history or k == 0:
            assert drafts == []
        assert set(drafts) <= set(history)

    _SPEC_WORKLOAD = st.lists(
        st.tuples(st.integers(1, 5), st.integers(1, 6),
                  st.booleans()),  # (prompt_len, gen, repetitive?)
        min_size=1, max_size=5,
    )

    @settings(max_examples=4, deadline=None)
    @given(workload=_SPEC_WORKLOAD, k=st.integers(1, 4),
           seed=st.integers(0, 2**16), order_seed=st.integers(0, 2**16))
    def test_property_spec_decode_equals_greedy(qlm, workload, k, seed,
                                                order_seed):
        """For random draft budgets, workloads (mixing repetitive and
        random prompts) and admission orders, every stream's speculative
        tokens are bit-identical to decoding it alone (slots fixed at 3 so
        each verify width compiles once per module)."""
        params, qlayers, cfg = qlm
        rng = np.random.default_rng(seed)
        requests = []
        for i, (p, g, rep) in enumerate(workload):
            if rep:
                motif = rng.integers(0, cfg.vocab_size, size=(2,))
                prompt = np.tile(motif, -(-p // 2))[:p]
            else:
                prompt = rng.integers(0, cfg.vocab_size, size=(p,))
            requests.append(E.Request(rid=i, prompt=prompt,
                                      max_new_tokens=g))
        order = np.random.default_rng(order_seed).permutation(len(requests))
        out, _ = _run(qlm, [requests[i] for i in order], speculate=k)
        for r in requests:
            ref = E.decode_single(params, qlayers, cfg, r.prompt,
                                  r.max_new_tokens)
            assert out[r.rid].tokens == ref, \
                f"stream {r.rid} drifted at speculate={k}"
