"""Per-arch reduced-config smoke: one train grad + decode steps, no NaNs."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import SHAPES, applicable_shapes
from repro.configs.registry import ASSIGNED, CONFIGS, SMOKE_CONFIGS
from repro.models import model_zoo

IDENT = lambda x, logical=None: x
B, S = 2, 16


def _batch(cfg, key):
    tok = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tok, "labels": tok}
    if cfg.family == "vlm":
        batch["frontend_embeds"] = jax.random.normal(
            key, (B, cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.family == "encdec":
        batch["frontend_embeds"] = jax.random.normal(
            key, (B, 16, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("name", sorted(SMOKE_CONFIGS), ids=str)
def test_arch_smoke_train_and_decode(name, monkeypatch):
    cfg = SMOKE_CONFIGS[name]
    if cfg.family == "encdec":
        import repro.models.whisper as W
        monkeypatch.setattr(W, "N_FRAMES", 16)
    bundle = model_zoo.build(cfg)
    key = jax.random.PRNGKey(0)
    params, specs = bundle.init(key)
    batch = _batch(cfg, key)
    loss, grads = jax.value_and_grad(
        lambda p: bundle.loss(p, batch, IDENT))(params)
    assert np.isfinite(float(loss))
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree_util.tree_leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0

    state = bundle.init_state(B, 64)
    tok = batch["tokens"][:, :1]
    for _ in range(3):
        logits, state = bundle.decode(params, tok, state, IDENT)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    assert bool(jnp.isfinite(logits).all())
    assert logits.shape == (B, cfg.vocab_size)


@pytest.mark.parametrize("name", sorted(ASSIGNED), ids=str)
def test_full_configs_match_assignment(name):
    """The FULL configs carry the exact assigned hyperparameters."""
    cfg = CONFIGS[name]
    table = {
        "qwen3-4b": (36, 2560, 32, 8, 9728, 151936),
        "stablelm-1.6b": (24, 2048, 32, 32, 5632, 100352),
        "yi-34b": (60, 7168, 56, 8, 20480, 64000),
        "qwen1.5-0.5b": (24, 1024, 16, 16, 2816, 151936),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "internvl2-2b": (24, 2048, 16, 8, 8192, 92553),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
        "falcon-mamba-7b": (64, 4096, 0, 0, 0, 65024),
    }
    L, d, H, KV, ff, V = table[name]
    assert cfg.n_layers == L and cfg.d_model == d and cfg.vocab_size == V
    assert cfg.n_heads == H and cfg.n_kv_heads == KV
    assert (cfg.d_ff == ff or cfg.moe_d_ff == ff)


def test_shape_cells_cover_assignment():
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k", "long_500k"}
    assert SHAPES["train_4k"].seq_len == 4096 and SHAPES["train_4k"].global_batch == 256
    assert SHAPES["long_500k"].seq_len == 524288
    # long_500k only for sub-quadratic archs
    for name in ASSIGNED:
        shapes = [s.name for s in applicable_shapes(CONFIGS[name])]
        if name in ("falcon-mamba-7b", "recurrentgemma-9b"):
            assert "long_500k" in shapes
        else:
            assert "long_500k" not in shapes


def test_moe_param_accounting():
    cfg = CONFIGS["kimi-k2-1t-a32b"]
    from repro.launch import roofline as rl
    from repro.runtime.train_loop import abstract_init
    bundle = model_zoo.build(cfg)
    shapes, _ = abstract_init(bundle)
    n = sum(int(x.size) for x in jax.tree_util.tree_leaves(shapes))
    assert 0.9e12 < n < 1.3e12, n  # ~1T total
    act = rl.active_params(cfg, n)
    assert 20e9 < act < 45e9, act  # ~32B active
