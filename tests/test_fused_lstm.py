"""Fused packed [i|f|z|o] LSTM executor vs the per-gate reference.

Covers the PR-1 acceptance gates:
  * backend="interpret" (Pallas interpreter on CPU) is bit-exact with
    backend="xla" across all 16 topology variants;
  * the packed matmul path runs 2 dot_general calls per step where the
    reference executor runs 8 (jaxpr inspection);
  * ops.quant_lstm_cell (interpret) matches models.quant_lstm.quant_lstm_cell
    over CIFG/LayerNorm/peephole variants, including the o-gate-peephole-
    inside-the-fusion contract;
  * non-divisible (B, H) shapes tile via the largest-divisor block fix.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import fixedpoint as fp
from repro.core import recipe as R
from repro.core.calibrate import Stats, TapCollector
from repro.kernels import ops
from repro.kernels.quant_lstm_cell import largest_divisor, quant_lstm_cell_pallas
from repro.models import lstm as L
from repro.models import quant_lstm as QL

pytestmark = pytest.mark.fast

B, T, D_IN, D_H, D_P = 4, 6, 16, 24, 12


def _setup(variant, seed=0, d_h=D_H, b=B):
    cfg = L.LSTMConfig(D_IN, d_h, D_P if variant.use_projection else 0,
                       variant)
    params = L.init_lstm_params(jax.random.PRNGKey(seed), cfg)
    xs = 0.8 * jax.random.normal(jax.random.PRNGKey(seed + 1), (b, T, D_IN))
    col = TapCollector()
    L.lstm_layer(params, cfg, xs, collector=col)
    stats = Stats()
    stats.merge(jax.device_get(col.snapshot()))
    arrays, spec = R.quantize_lstm_layer(params, cfg, stats)
    return QL.quantize_input(xs, spec.s_x, spec.zp_x), arrays, spec


@pytest.mark.parametrize("variant", L.ALL_VARIANTS, ids=lambda v: v.name)
def test_fused_layer_bitexact_all_variants(variant):
    """packed/xla == packed/interpret == per-gate reference, bit for bit."""
    xs_q, arrays, spec = _setup(variant)
    y_ref, (h_ref, c_ref) = QL.quant_lstm_layer_ref(arrays, spec, xs_q)
    y_x, (h_x, c_x) = QL.quant_lstm_layer(arrays, spec, xs_q, backend="xla")
    y_i, (h_i, c_i) = QL.quant_lstm_layer(arrays, spec, xs_q,
                                          backend="interpret")
    np.testing.assert_array_equal(np.asarray(y_ref), np.asarray(y_x))
    np.testing.assert_array_equal(np.asarray(c_ref), np.asarray(c_x))
    np.testing.assert_array_equal(np.asarray(y_x), np.asarray(y_i))
    np.testing.assert_array_equal(np.asarray(h_x), np.asarray(h_i))
    np.testing.assert_array_equal(np.asarray(c_x), np.asarray(c_i))


def _count_dot_generals(jaxpr) -> int:
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "dot_general":
            n += 1
        for v in eqn.params.values():
            for sub in (v if isinstance(v, (list, tuple)) else (v,)):
                inner = getattr(sub, "jaxpr", None)
                if inner is not None:
                    n += _count_dot_generals(inner)
    return n


def test_packed_step_runs_two_dot_generals():
    """Acceptance: the packed path cuts per-step dot_general calls 8 -> 2."""
    variant = L.LSTMVariant()  # no projection: gate matmuls only
    xs_q, arrays, spec = _setup(variant)
    h0 = jnp.full((B, D_H), spec.zp_h_out, jnp.int8)
    c0 = jnp.zeros((B, D_H), jnp.int16)

    fused = jax.make_jaxpr(
        lambda a, x, h, c: ops.quant_lstm_step(a, spec, x, h, c,
                                               backend="xla")
    )(arrays, xs_q[:, 0], h0, c0)
    reference = jax.make_jaxpr(
        lambda a, x, h, c: QL.quant_lstm_cell(a, spec, x, h, c)
    )(arrays, xs_q[:, 0], h0, c0)
    assert _count_dot_generals(fused.jaxpr) == 2
    assert _count_dot_generals(reference.jaxpr) == 8


@pytest.mark.parametrize("variant", [
    L.LSTMVariant(),
    L.LSTMVariant(use_cifg=True),
    L.LSTMVariant(use_peephole=True),
    L.LSTMVariant(use_layernorm=True),
    L.LSTMVariant(use_layernorm=True, use_peephole=True),
    L.LSTMVariant(use_layernorm=True, use_peephole=True, use_cifg=True),
], ids=lambda v: v.name)
def test_ops_cell_interpret_matches_model_cell(variant):
    """ops.quant_lstm_cell (interpret) vs the per-gate model step: one
    timestep, CIFG/LayerNorm/peephole coverage (satellite)."""
    xs_q, arrays, spec = _setup(variant)
    h0 = jnp.full((B, D_H), spec.zp_h_out, jnp.int8)
    c0 = jnp.asarray(
        np.random.default_rng(0).integers(-9000, 9000, (B, D_H)), jnp.int16)
    h_ref, c_ref = QL.quant_lstm_cell(arrays, spec, xs_q[:, 0], h0, c0)
    h_fus, c_fus = ops.quant_lstm_step(arrays, spec, xs_q[:, 0], h0, c0,
                                       backend="interpret")
    np.testing.assert_array_equal(np.asarray(h_ref), np.asarray(h_fus))
    np.testing.assert_array_equal(np.asarray(c_ref), np.asarray(c_fus))


def test_o_gate_peephole_contract():
    """The o-gate peephole MUST be finished against c_new inside the fusion
    (eq 5).  Pre-activating o against the OLD cell state diverges, proving
    the contract is load-bearing; the kernel also rejects a peephole request
    without the int32 accumulator."""
    variant = L.LSTMVariant(use_peephole=True)
    xs_q, arrays, spec = _setup(variant, seed=3)
    h0 = jnp.full((B, D_H), spec.zp_h_out, jnp.int8)
    c0 = jnp.asarray(
        np.random.default_rng(1).integers(-9000, 9000, (B, D_H)), jnp.int16)
    h_good, _ = ops.quant_lstm_step(arrays, spec, xs_q[:, 0], h0, c0,
                                    backend="interpret")
    h_ref, _ = QL.quant_lstm_cell(arrays, spec, xs_q[:, 0], h0, c0)
    np.testing.assert_array_equal(np.asarray(h_good), np.asarray(h_ref))

    # wrong usage: o finished OUTSIDE the fusion against the stale cell c0
    from repro.models.quant_lstm import _gate

    o16_stale = _gate(arrays, spec, "o", xs_q[:, 0], h0, c0)
    i16 = _gate(arrays, spec, "i", xs_q[:, 0], h0, c0)
    f16 = _gate(arrays, spec, "f", xs_q[:, 0], h0, c0)
    z16 = _gate(arrays, spec, "z", xs_q[:, 0], h0, None)
    h_bad, _ = ops.quant_lstm_cell(
        i16, f16, z16, o16_stale, c0,
        cell_int_bits=spec.cell_int_bits, cifg=False,
        eff_m=spec.eff_m, zp_m=spec.zp_m, backend="interpret")
    assert not np.array_equal(np.asarray(h_bad), np.asarray(h_good))

    with pytest.raises(AssertionError):
        quant_lstm_cell_pallas(
            i16, f16, z16, o16_stale, c0,  # int16 o + peephole: contract
            cell_int_bits=spec.cell_int_bits, cifg=False,
            eff_m=spec.eff_m, zp_m=spec.zp_m,
            p_o=arrays["P"]["o"], eff_c_o=spec.gate_spec("o").eff_c,
            interpret=True)


def test_largest_divisor():
    assert largest_divisor(12, 8) == 6
    assert largest_divisor(40, 512) == 40
    assert largest_divisor(7, 4) == 1
    assert largest_divisor(16, 8) == 8


@pytest.mark.parametrize("b,h", [(12, 40), (7, 48), (5, 33)])
def test_cell_kernel_non_divisible_shapes(b, h):
    """B=12 with default block_b=8 used to trip `B % bb == 0`; the kernel now
    picks the largest dividing block."""
    rng = np.random.default_rng(b * h)
    g = lambda: jnp.asarray(rng.integers(-32768, 32767, (b, h)).astype(np.int16))
    i16, f16, z16, o16 = g(), g(), g(), g()
    cq = jnp.asarray(rng.integers(-20000, 20000, (b, h)).astype(np.int16))
    kw = dict(cell_int_bits=2, cifg=False,
              eff_m=fp.quantize_multiplier(2.0**-30 / 0.005), zp_m=-4)
    h1, c1 = ops.quant_lstm_cell(i16, f16, z16, o16, cq,
                                 backend="interpret", **kw)
    h2, c2 = ops.quant_lstm_cell(i16, f16, z16, o16, cq, backend="xla", **kw)
    np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))


def test_fused_layer_odd_batch():
    """End-to-end layer with a batch the default block size doesn't divide."""
    variant = L.LSTMVariant(use_layernorm=True)
    xs_q, arrays, spec = _setup(variant, b=12)
    y_x, _ = QL.quant_lstm_layer(arrays, spec, xs_q, backend="xla")
    y_i, _ = QL.quant_lstm_layer(arrays, spec, xs_q, backend="interpret")
    np.testing.assert_array_equal(np.asarray(y_x), np.asarray(y_i))


def test_masked_seq_executor_matches_prefix_feeding():
    """Ragged masked executor (the chunked-prefill workhorse): each row's
    final (h, c) after a (B, T) block with per-row valid lengths must be
    bitwise the state from feeding ONLY that row's valid prefix through the
    unmasked executor, and rows with valid_len == 0 must stay frozen."""
    variant = L.LSTMVariant(use_layernorm=True, use_projection=True)
    xs_q, arrays, spec = _setup(variant)  # (B=4, T=6)
    valid = jnp.asarray([0, 1, 4, 6], jnp.int32)
    h0 = jnp.full((B, D_P), spec.zp_h_out, jnp.int8)
    c0 = jnp.zeros((B, D_H), jnp.int16)

    ys_m, (h_m, c_m) = ops.quant_lstm_seq_masked(
        arrays, spec, xs_q, h0, c0, valid, backend="xla")
    ys_i, (h_i, c_i) = ops.quant_lstm_seq_masked(
        arrays, spec, xs_q, h0, c0, valid, backend="interpret")
    np.testing.assert_array_equal(np.asarray(h_m), np.asarray(h_i))
    np.testing.assert_array_equal(np.asarray(c_m), np.asarray(c_i))
    np.testing.assert_array_equal(np.asarray(ys_m), np.asarray(ys_i))

    for row, n in enumerate(np.asarray(valid)):
        if n == 0:  # frozen: initial state untouched
            np.testing.assert_array_equal(np.asarray(h_m)[row],
                                          np.asarray(h0)[row])
            np.testing.assert_array_equal(np.asarray(c_m)[row],
                                          np.asarray(c0)[row])
            continue
        ys_r, (h_r, c_r) = ops.quant_lstm_seq(
            arrays, spec, xs_q[row:row + 1, :n],
            h0[row:row + 1], c0[row:row + 1], backend="xla")
        np.testing.assert_array_equal(np.asarray(h_m)[row],
                                      np.asarray(h_r)[0])
        np.testing.assert_array_equal(np.asarray(c_m)[row],
                                      np.asarray(c_r)[0])
        # the output sequence over the valid prefix matches too
        np.testing.assert_array_equal(np.asarray(ys_m)[row, :n],
                                      np.asarray(ys_r)[0])
