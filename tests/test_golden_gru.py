"""GRU golden bit-exactness + engine-vs-single-stream regression tests.

``tests/golden/gru_goldens.json`` pins the integer outputs of both GRU
variants (LN x), the greedy tokens of the smoke ``gru-rnnt`` LM decode, and
the per-stream tokens of a fixed workload served through the
continuous-batching engine under ``{fifo, srf} x oversubscription`` -- the
PR-8 acceptance gate that the cell-agnostic engine serves a second cell
with zero serving-layer changes.  Every engine case is additionally
asserted bit-identical to ``decode_single`` (the scheduler-free oracle), so
chunked prefill, preemption through the paged state pool, and resume all
hold for a single-leaf (``h``-only) recurrent state.  Regenerate only for
intentional numerics changes:
``PYTHONPATH=src python tests/golden/regen_goldens.py``.
"""
import os

import pytest

import jax

from repro.launch import engine as E
from repro.models import gru as GR
from repro.testing import golden

pytestmark = pytest.mark.fast

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "golden",
                           "gru_goldens.json")
GOLDENS = golden.load_goldens(GOLDEN_PATH)

BACKENDS = ("xla", "interpret")


@pytest.fixture(scope="module")
def lm_case():
    return golden.build_lm_case("gru-rnnt")


@pytest.mark.parametrize("variant", GR.ALL_VARIANTS, ids=lambda v: v.name)
def test_gru_variant_layer_matches_golden(variant):
    """Both backends must reproduce the checked-in integers exactly (only
    two GRU variants, so no interpret subset is needed)."""
    want = GOLDENS["variants"][golden.gru_variant_key(variant)]
    case = golden.build_gru_variant_case(variant)
    for backend in BACKENDS:
        got = golden.execute_case(case, backend)
        for key in ("ys", "h"):
            assert got[key] == want[key], \
                f"{variant.name}/{backend}: {key} drifted"


def test_gru_goldens_cover_all_variants():
    assert set(GOLDENS["variants"]) == {
        golden.gru_variant_key(v) for v in GR.ALL_VARIANTS}
    # single-leaf state: the layer golden is {ys, h}, no cell carry
    for case in GOLDENS["variants"].values():
        assert set(case) == {"ys", "h"}


@pytest.mark.parametrize("backend", BACKENDS)
def test_gru_lm_decode_matches_golden(backend):
    """End-to-end stacked GRU LM greedy decode: tokens AND final h."""
    got = golden.run_lm_case(backend=backend, arch="gru-rnnt")
    want = GOLDENS["lm"]
    assert got["tokens"] == want["tokens"], f"{backend}: tokens drifted"
    assert got["h"] == want["h"], f"{backend}: final h drifted"
    assert "c" not in got


@pytest.mark.parametrize("policy,ratio", golden.ENGINE_GOLDEN_CASES,
                         ids=lambda p: str(p))
def test_gru_engine_matches_golden_and_decode_single(lm_case, policy, ratio):
    """The fixed workload through the engine: tokens must match BOTH the
    checked-in golden and a fresh ``decode_single`` of every stream --
    preemption/resume of the single-leaf GRU state is bit-exact."""
    params, qlayers, cfg, _ = lm_case
    got = golden.run_engine_case("gru-rnnt", policy, ratio, backend="xla",
                                 built=lm_case)
    want = GOLDENS["engine"][f"{policy}-{ratio}"]
    assert got == want, f"{policy}-{ratio}: engine tokens drifted"
    for req in golden.engine_trace(cfg):
        single = E.decode_single(params, qlayers, cfg, req.prompt,
                                 req.max_new_tokens, backend="xla")
        assert got[str(req.rid)] == single, \
            f"{policy}-{ratio}: stream {req.rid} != decode_single"


def test_gru_engine_chunked_prefill_matches_plain(lm_case):
    """Chunked prefill (one masked (S, K) dispatch) must not change any
    GRU stream's tokens -- the ragged masked executor freezes the
    single-leaf state exactly like the LSTM's two leaves."""
    params, qlayers, cfg, _ = lm_case
    plain = golden.run_engine_case("gru-rnnt", "fifo", 1.0, built=lm_case)
    requests = golden.engine_trace(cfg)
    eng = E.ContinuousBatchingEngine(
        params, qlayers, cfg, n_slots=golden.ENGINE_SLOTS, backend="xla",
        chunk=4, policy="fifo", oversubscribe=1.0)
    eng.submit_all(requests)
    results, _ = eng.run()
    assert {str(r): list(res.tokens) for r, res in results.items()} == plain


def test_gru_pool_reports_single_leaf_bytes(lm_case):
    """Generic bytes-per-stream: a parked GRU stream is one int8 h row per
    layer + the int32 len counter -- no phantom cell-state bytes."""
    from repro.launch.state_pool import StatePool
    from repro.models import lstm_lm

    params, qlayers, cfg, _ = lm_case
    state = lstm_lm.init_quant_decode_state(qlayers, 1)
    pool = StatePool()
    pool.put("s", jax.device_get(lstm_lm.slice_state(state, 0)))
    want = sum(spec.cfg_d_hidden for _, spec in qlayers) + 4
    assert pool.state_bytes_per_stream == want
