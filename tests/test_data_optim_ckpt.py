"""Data pipeline, optimizers, gradient compression, checkpointing, fault."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.optim import grad_compress
from repro.optim.optimizers import OptConfig, make_optimizer
from repro.runtime.fault import RestartStats, StepWatchdog, run_with_restarts


def test_data_deterministic_and_seekable():
    cfg = DataConfig(vocab_size=64, seq_len=32, global_batch=4)
    src = SyntheticLM(cfg)
    b1 = src.batch_at(17)
    b2 = src.batch_at(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # iterate() resumes exactly at any step (O(1) checkpointable state)
    it = src.iterate(start_step=17)
    step, b3 = next(it)
    assert step == 17
    np.testing.assert_array_equal(b1["labels"], b3["labels"])


def test_data_learnable_structure():
    cfg = DataConfig(vocab_size=64, seq_len=32, global_batch=4, noise=0.0)
    b = SyntheticLM(cfg).batch_at(0)
    # affine rule: labels are a deterministic function of tokens per row
    assert (b["labels"][:, :-1] == b["tokens"][:, 1:]).all()


@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizers_reduce_loss(name):
    opt_cfg = OptConfig(name=name, lr=0.1, warmup_steps=1, total_steps=100,
                        weight_decay=0.0)
    init, update = make_optimizer(opt_cfg)
    target = jnp.asarray(np.random.default_rng(0).standard_normal((8, 8)),
                         jnp.float32)
    params = {"w": jnp.zeros((8, 8), jnp.float32)}
    state = init(params)

    def loss_fn(p):
        return jnp.mean(jnp.square(p["w"] - target))

    losses = []
    for _ in range(60):
        g = jax.grad(loss_fn)(params)
        params, state, m = update(g, state, params)
        losses.append(float(loss_fn(params)))
    assert losses[-1] < 0.05 * losses[0], (name, losses[0], losses[-1])


def test_grad_compression_error_feedback_converges():
    """int8 EF compression must not prevent convergence (distributed-opt)."""
    opt_cfg = OptConfig(name="adamw", lr=0.05, warmup_steps=1,
                        total_steps=200, weight_decay=0.0)
    init, update = make_optimizer(opt_cfg)
    target = jnp.asarray(np.random.default_rng(1).standard_normal((16, 16)))
    params = {"w": jnp.zeros((16, 16), jnp.float32)}
    state = init(params)
    resid = grad_compress.ef_init(params)

    def loss_fn(p):
        return jnp.mean(jnp.square(p["w"] - target))

    for _ in range(100):
        g = jax.grad(loss_fn)(params)
        g, resid = grad_compress.ef_compress_tree(g, resid)
        params, state, _ = update(g, state, params)
    assert float(loss_fn(params)) < 0.02


def test_compressed_psum_single_device_exact():
    from jax.sharding import Mesh
    from jax.sharding import PartitionSpec as P

    from repro.runtime.sharding import shard_map_compat

    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    g = jnp.asarray(np.random.default_rng(2).standard_normal((64,)),
                    jnp.float32)

    def body(x):
        mean, resid = grad_compress.compressed_psum(x, "data")
        return mean

    out = shard_map_compat(body, mesh=mesh, in_specs=P("data"),
                           out_specs=P("data"))(g)
    assert float(jnp.abs(out - g).max()) < float(jnp.abs(g).max()) / 120


def test_checkpoint_roundtrip_and_keepk(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_k=2, async_save=False)
    tree = {"a": jnp.arange(8, dtype=jnp.float32),
            "b": {"c": jnp.ones((3, 3), jnp.bfloat16)}}
    for step in (10, 20, 30):
        mgr.save(step, tree, extra_meta={"data_step": step})
    assert sorted(mgr.steps()) == [20, 30]  # keep_k GC'd step 10
    restored, meta = mgr.restore(30, tree)
    assert meta["data_step"] == 30
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    assert restored["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_crash_safety(tmp_path):
    """A tmp dir left by a crashed save must not count as a checkpoint."""
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    os.makedirs(tmp_path / "tmp_step_99")
    assert mgr.latest_step() is None
    mgr.save(5, {"x": jnp.zeros(2)})
    assert mgr.latest_step() == 5


def test_run_with_restarts_recovers(tmp_path):
    """Simulated node failures: the driver resumes from durable steps."""
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    state = {"failures_left": 2}

    def train_chunk(start):
        for step in range(start, start + 10):
            if step == 15 and state["failures_left"] > 0:
                state["failures_left"] -= 1
                raise RuntimeError("node lost")
            if (step + 1) % 5 == 0:
                mgr.save(step + 1, {"p": jnp.full(4, float(step))})
        return start + 10

    stats = run_with_restarts(
        train_chunk, ckpt_latest=mgr.latest_step, total_steps=30)
    assert stats.restarts == 2
    assert mgr.latest_step() >= 30  # recovered and finished the run


def test_watchdog_classification():
    wd = StepWatchdog(timeout_factor=10, straggler_factor=2)
    for _ in range(5):
        assert wd.observe(1.0) == "ok"
    assert wd.observe(3.0) == "straggler"
    assert wd.observe(100.0) == "hung"
