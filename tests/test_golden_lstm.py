"""Golden bit-exactness regression tests (the lockdown harness).

``tests/golden/lstm_goldens.json`` pins the integer outputs (int8 output
sequence + final ``(h, c)`` carries) of all 16 topology variants and the
greedy tokens of the smoke LM decode.  The fused executor must reproduce
them EXACTLY on both the ``xla`` and ``interpret`` backends, so a future
refactor of the recipe / fused executor / engine cannot silently drift by
even one low bit.  Regenerate only for intentional numerics changes:
``PYTHONPATH=src python tests/golden/regen_goldens.py``.
"""
import os

import pytest

from repro.models import lstm as L
from repro.testing import golden

pytestmark = pytest.mark.fast

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "golden",
                           "lstm_goldens.json")
GOLDENS = golden.load_goldens(GOLDEN_PATH)

BACKENDS = ("xla", "interpret")

# xla runs against the goldens for ALL 16 variants; the (slow-to-compile)
# Pallas interpreter re-checks a feature-covering subset here because
# test_fused_lstm already asserts xla == interpret bit-exactness for all 16
# -- transitively every variant is golden-pinned on every backend.
_INTERPRET_SUBSET = {
    L.LSTMVariant().name,
    L.LSTMVariant(use_layernorm=True, use_projection=True,
                  use_peephole=True).name,
    L.LSTMVariant(use_layernorm=True, use_projection=True, use_peephole=True,
                  use_cifg=True).name,
    L.LSTMVariant(use_projection=True, use_peephole=True,
                  use_cifg=True).name,
}


@pytest.mark.parametrize("variant", L.ALL_VARIANTS, ids=lambda v: v.name)
def test_variant_layer_matches_golden(variant):
    """Every backend must reproduce the checked-in integers exactly."""
    want = GOLDENS["variants"][golden.variant_key(variant)]
    case = golden.build_variant_case(variant)
    backends = ("xla",) if variant.name not in _INTERPRET_SUBSET else BACKENDS
    for backend in backends:
        got = golden.execute_case(case, backend)
        for key in ("ys", "h", "c"):
            assert got[key] == want[key], \
                f"{variant.name}/{backend}: {key} drifted"


def test_goldens_cover_all_16_variants():
    assert len(GOLDENS["variants"]) == 16
    assert set(GOLDENS["variants"]) == {
        golden.variant_key(v) for v in L.ALL_VARIANTS}


def test_default_backend_matches_golden():
    """Run with ``backend=None`` so the env-selected global default
    (``REPRO_KERNEL_BACKEND``, what the CI backend matrix varies) is the
    lowering under test -- this is the test that makes the matrix legs
    actually execute different code."""
    from repro.kernels import ops

    variant = L.LSTMVariant(use_layernorm=True, use_projection=True,
                            use_peephole=True)
    want = GOLDENS["variants"][golden.variant_key(variant)]
    got = golden.execute_case(golden.build_variant_case(variant), None)
    backend = ops.get_backend()
    for key in ("ys", "h", "c"):
        assert got[key] == want[key], f"default[{backend}]: {key} drifted"


@pytest.mark.parametrize("backend", BACKENDS)
def test_lm_decode_matches_golden(backend):
    """End-to-end stacked-LM greedy decode: tokens AND final (h, c)."""
    got = golden.run_lm_case(backend=backend)
    want = GOLDENS["lm"]
    assert got["tokens"] == want["tokens"], f"{backend}: tokens drifted"
    assert got["h"] == want["h"], f"{backend}: final h drifted"
    assert got["c"] == want["c"], f"{backend}: final c drifted"
