"""Integer LSTM vs float across all 16 topology variants (paper sec 3.2)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import recipe as R
from repro.core.calibrate import Stats, TapCollector
from repro.models import lstm as L
from repro.models import quant_lstm as QL

B, T, D_IN, D_H, D_P = 4, 12, 32, 48, 24


def _setup(variant, seed=0):
    cfg = L.LSTMConfig(D_IN, D_H, D_P if variant.use_projection else 0, variant)
    params = L.init_lstm_params(jax.random.PRNGKey(seed), cfg)
    xs = 0.8 * jax.random.normal(jax.random.PRNGKey(1), (B, T, D_IN))
    col = TapCollector()
    ys_f, _ = L.lstm_layer(params, cfg, xs, collector=col)
    stats = Stats()
    stats.merge(jax.device_get(col.snapshot()))
    arrays, spec = R.quantize_lstm_layer(params, cfg, stats)
    return cfg, params, xs, ys_f, arrays, spec


@pytest.mark.parametrize("variant", L.ALL_VARIANTS, ids=lambda v: v.name)
def test_integer_matches_float(variant):
    cfg, params, xs, ys_f, arrays, spec = _setup(variant)
    xs_q = QL.quantize_input(xs, spec.s_x, spec.zp_x)
    ys_q, _ = QL.quant_lstm_layer(arrays, spec, xs_q)
    ys_i = QL.dequantize_output(ys_q, spec.s_h, spec.zp_h_out)
    rel = float(jnp.abs(ys_i - ys_f).max() / (jnp.abs(ys_f).max() + 1e-9))
    assert rel < 0.05, f"{variant.name}: rel err {rel}"


def test_integer_only_dtypes():
    """No float appears anywhere in the integer execution graph."""
    variant = L.LSTMVariant(True, True, True, False)
    cfg, params, xs, _, arrays, spec = _setup(variant)
    xs_q = QL.quantize_input(xs, spec.s_x, spec.zp_x)
    jaxpr = jax.make_jaxpr(
        lambda a, x: QL.quant_lstm_layer(a, spec, x))(arrays, xs_q)
    float_ops = [
        eqn for eqn in jaxpr.jaxpr.eqns
        for v in eqn.outvars
        if hasattr(v, "aval") and v.aval.dtype in (jnp.float32, jnp.bfloat16)
    ]
    assert not float_ops, f"float ops leaked: {float_ops[:3]}"


def test_long_sequence_stability():
    """Error must not blow up over long sequences (the paper's YouTube
    long-utterance robustness claim, sec 5)."""
    variant = L.LSTMVariant(use_layernorm=True, use_projection=False)
    cfg = L.LSTMConfig(16, 32, 0, variant)
    params = L.init_lstm_params(jax.random.PRNGKey(2), cfg)
    xs = 0.5 * jax.random.normal(jax.random.PRNGKey(3), (2, 200, 16))
    col = TapCollector()
    ys_f, _ = L.lstm_layer(params, cfg, xs[:, :50], collector=col)
    stats = Stats()
    stats.merge(jax.device_get(col.snapshot()))
    arrays, spec = R.quantize_lstm_layer(params, cfg, stats)
    ys_f_full, _ = L.lstm_layer(params, cfg, xs)
    xs_q = QL.quantize_input(xs, spec.s_x, spec.zp_x)
    ys_q, _ = QL.quant_lstm_layer(arrays, spec, xs_q)
    ys_i = QL.dequantize_output(ys_q, spec.s_h, spec.zp_h_out)
    err_early = float(jnp.abs(ys_i[:, :20] - ys_f_full[:, :20]).mean())
    err_late = float(jnp.abs(ys_i[:, -20:] - ys_f_full[:, -20:]).mean())
    assert err_late < 5 * max(err_early, 1e-3), (err_early, err_late)


def test_cifg_coupling_integer():
    """i = 1 - f in Q0.15 with the paper's clamping (sec 3.2.9)."""
    f = jnp.array([0, 1, 16384, 32767], jnp.int32)
    i = jnp.minimum(jnp.int32(32768) - f, jnp.int32(32767))
    assert i.tolist() == [32767, 32767, 16384, 1]


def test_hybrid_matmul_close_to_float():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((64, 32)), jnp.float32)
    wq, scales = QL.hybrid_weights(
        {"W": {"i": w}, "R": {}, "b": {}})
    y = QL.hybrid_matmul(x, wq["W"]["i"], scales["W_i"])
    ref = x @ w
    # dynamic int8 activations: error ~ s_x * sum|w| per output element
    assert float(jnp.abs(y - ref).max()) < 0.02 * float(jnp.abs(ref).max()) + 0.05


def test_sparsity_pruning():
    variant = L.LSTMVariant()
    cfg = L.LSTMConfig(32, 32, 0, variant)
    params = L.init_lstm_params(jax.random.PRNGKey(0), cfg)
    sparse = L.sparsify_params(params, 0.5)
    w = np.asarray(sparse["W"]["i"])
    assert 0.45 <= (w == 0).mean() <= 0.55


def test_qat_gradients_flow():
    variant = L.LSTMVariant(use_layernorm=True)
    cfg = L.LSTMConfig(16, 24, 0, variant)
    params = L.init_lstm_params(jax.random.PRNGKey(0), cfg)
    xs = jax.random.normal(jax.random.PRNGKey(1), (2, 6, 16))

    def loss(p):
        ys, _ = L.lstm_layer(p, cfg, xs, qat=True)
        return jnp.mean(jnp.square(ys))

    grads = jax.grad(loss)(params)
    gnorm = float(jnp.sqrt(sum(
        jnp.sum(jnp.square(g)) for g in jax.tree_util.tree_leaves(grads))))
    assert np.isfinite(gnorm) and gnorm > 0
