#!/usr/bin/env python
"""Regenerate tests/golden/lstm_goldens.json + gru_goldens.json.

    PYTHONPATH=src python tests/golden/regen_goldens.py

Only run this after an INTENTIONAL integer-numerics change (recipe, fused
executor, fixed-point primitives) and call the change out in the commit
message -- the whole point of the goldens is that accidental drift fails CI.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

from repro.testing import golden  # noqa: E402

LSTM_OUT = os.path.join(os.path.dirname(__file__), "lstm_goldens.json")
GRU_OUT = os.path.join(os.path.dirname(__file__), "gru_goldens.json")

if __name__ == "__main__":
    golden.write_goldens(LSTM_OUT)
    data = golden.load_goldens(LSTM_OUT)
    print(f"wrote {LSTM_OUT}: {len(data['variants'])} layer variants + "
          f"lm tokens {data['lm']['tokens']}")
    golden.write_goldens(GRU_OUT, generate=golden.generate_gru_goldens)
    data = golden.load_goldens(GRU_OUT)
    print(f"wrote {GRU_OUT}: {len(data['variants'])} layer variants + "
          f"lm tokens {data['lm']['tokens']} + "
          f"{len(data['engine'])} engine cases")
